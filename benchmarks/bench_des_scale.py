"""Million-event DES scale benchmark: simulator speed as a perf surface.

Drives the *real* ``EdgeToCloudPipeline`` under ``SimExecutor`` with
open-loop arrival processes (Poisson / diurnal / flash-crowd) and raw
``bytes`` payloads, so the measured cost is the event loop itself —
scheduler heap, actor stepping, broker fan-out, poll/wake — not numpy
serialization.  The headline cell is a 1M-message, 1000-consumer
Poisson run; the sweep adds diurnal and flash-crowd cells at a tenth
the size so every arrival process stays on the tracked surface.

Two kinds of numbers per row:

* **deterministic** (virtual time, event counts, latency percentiles,
  bytes) — bit-identical for a given seed, gated by
  ``--check-determinism`` (three full sweeps must agree);
* **wall-clock** (``wall_s``, ``events_per_s``, ``rss_mb``) — the perf
  trajectory.  These are excluded from the determinism comparison.

The committed ``BENCH_des_scale.json`` records the pre-rework baseline
(measured on this machine before the event-loop fixes) next to the
headline events/s, so the speedup is auditable::

    PYTHONPATH=src python benchmarks/bench_des_scale.py \\
        --check-determinism --out BENCH_des_scale.json

Row shape is pinned by ``benchmarks/BENCH_des_scale.schema.json``
(validated in CI by ``tools/check_bench_schema.py``; the file is
uploaded as the ``BENCH_des_scale`` artifact on every run).
"""
from __future__ import annotations

import argparse
import json
import resource
import sys
import time

from repro.core import ComputeResource, EdgeToCloudPipeline, PilotManager
from repro.core.executor import SimExecutor
from repro.core.monitoring import MetricsRegistry
from repro.sim.clock import SimClock
from repro.sim.scenarios import (DiurnalArrivals, FlashCrowdArrivals,
                                 PoissonArrivals)

# Pre-rework event-loop throughput, measured on the commit just before
# the compacting-heap / actor-slot-reuse / waiter-index changes (same
# machine, same SimExecutor surface).  Kept in the committed JSON so the
# headline speedup is anchored to a recorded number, not folklore.
BASELINE = {
    "events_per_s": 3188.0,
    "config": ("20000 msgs / 100 devices / 1000 consumers, kmeans cloud "
               "100mbit closed-loop (pre-rework event loop: O(n) "
               "cancelled-event sweeps, per-step event allocation, "
               "O(all-tasks) append scans, per-join wake-all)"),
}

# row keys compared by --check-determinism (wall-clock keys excluded)
DETERMINISTIC_KEYS = (
    "arrival", "messages", "devices", "consumers", "payload_bytes",
    "seed", "processed", "duplicates", "events", "makespan_s",
    "lat_p50_s", "lat_p95_s", "wan_bytes",
)


def _arrival(kind: str, rate_hz: float):
    if kind == "poisson":
        return PoissonArrivals(rate_hz=rate_hz)
    if kind == "diurnal":
        return DiurnalArrivals(base_rate_hz=rate_hz / 4.0,
                               peak_rate_hz=rate_hz, period_s=20.0)
    if kind == "flash":
        return FlashCrowdArrivals(base_rate_hz=rate_hz / 4.0,
                                  burst_rate_hz=rate_hz * 4.0,
                                  burst_at_s=2.0, burst_duration_s=2.0)
    raise ValueError(f"unknown arrival kind {kind!r}")


def run_cell(*, arrival: str, messages: int, devices: int, consumers: int,
             rate_hz: float, payload_bytes: int, service_s: float,
             seed: int) -> dict:
    """One open-loop run on the genuine pipeline; returns a bench row."""
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=devices))
    cloud = mgr.submit_pilot(
        ComputeResource(tier="cloud", n_workers=consumers))
    payload = bytes(payload_bytes)   # raw bytes: passthrough serialization
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: payload,
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=devices, n_partitions=devices,
        cloud_consumers=consumers, topic_name="des-scale",
        metrics=metrics, clock=clock)
    times = _arrival(arrival, rate_hz).times(messages, seed)
    plan = [times[i::devices] for i in range(devices)]
    ex = SimExecutor(
        clock,
        service_model=((lambda stage, ctx, data: service_s)
                       if service_s > 0.0 else None))

    t0 = time.perf_counter()
    res = pipe.run(timeout_s=float(times[-1]) + 120.0,
                   collect_results=False, scheduler=ex, arrival_plan=plan)
    wall = time.perf_counter() - t0
    mgr.release_all()

    m = res.metrics
    lat = m.latencies("produced", "processed")
    lat.sort()
    n = len(lat)
    first = m.first_stamp("produced") or 0.0
    last = m.last_stamp("processed") or first
    events = ex.sched.executed
    # ru_maxrss is the process-lifetime high-water mark (KB on Linux):
    # monotone across cells, so the largest cell owns the reported peak
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    return {
        "arrival": arrival, "messages": messages, "devices": devices,
        "consumers": consumers, "payload_bytes": payload_bytes,
        "seed": seed,
        "processed": res.n_processed,
        "duplicates": int(m.counter("pipeline.duplicates_dropped")),
        "events": events,
        "makespan_s": max(last - first, 1e-9),
        "lat_p50_s": lat[n // 2] if n else 0.0,
        "lat_p95_s": lat[min(n - 1, int(0.95 * n))] if n else 0.0,
        "wan_bytes": m.counter("topic.des-scale.bytes_in"),
        "wall_s": wall,
        "events_per_s": events / max(wall, 1e-9),
        "rss_mb": rss_mb,
    }


def run_sweep(args) -> list:
    cells = [
        # headline: full size, Poisson
        dict(arrival="poisson", messages=args.messages),
        # arrival-process coverage at a tenth the size
        dict(arrival="diurnal", messages=max(args.messages // 10, 1000)),
        dict(arrival="flash", messages=max(args.messages // 10, 1000)),
    ]
    rows = []
    for cell in cells:
        row = run_cell(arrival=cell["arrival"], messages=cell["messages"],
                       devices=args.devices, consumers=args.consumers,
                       rate_hz=args.rate_hz,
                       payload_bytes=args.payload_bytes,
                       service_s=args.service_s, seed=args.seed)
        print(f"  {row['arrival']:>8}  {row['messages']:>9,} msgs  "
              f"{row['events']:>9,} events  {row['wall_s']:6.1f} s wall  "
              f"{row['events_per_s']:>9,.0f} ev/s  "
              f"{row['rss_mb']:6.0f} MB rss")
        rows.append(row)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--messages", type=int, default=1_000_000,
                    help="messages in the headline Poisson cell "
                         "(diurnal/flash cells run a tenth of this)")
    ap.add_argument("--devices", type=int, default=100)
    ap.add_argument("--consumers", type=int, default=1000)
    ap.add_argument("--rate-hz", type=float, default=20_000.0,
                    help="aggregate open-loop arrival rate")
    ap.add_argument("--payload-bytes", type=int, default=64)
    ap.add_argument("--service-s", type=float, default=0.001,
                    help="deterministic per-message service charge")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-determinism", action="store_true",
                    help="run the sweep three times; fail unless every "
                         "deterministic column is identical")
    ap.add_argument("--out", default=None, help="write the report as JSON")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    rows = run_sweep(args)
    total_wall = time.perf_counter() - t0
    headline = rows[0]
    speedup = headline["events_per_s"] / BASELINE["events_per_s"]
    print(f"\nheadline: {headline['events_per_s']:,.0f} events/s at "
          f"{headline['messages']:,} msgs x {headline['consumers']} "
          f"consumers ({speedup:.1f}x the recorded "
          f"{BASELINE['events_per_s']:,.0f} ev/s pre-rework baseline)")

    rc = 0
    if args.check_determinism:
        def det(rs):
            return [[r[k] for k in DETERMINISTIC_KEYS] for r in rs]
        reruns = [run_sweep(args) for _ in range(2)]
        if all(det(rows) == det(rn) for rn in reruns):
            print("determinism: OK (identical deterministic columns "
                  "across three full sweeps)")
        else:
            print("determinism: FAILED — deterministic columns differ")
            rc = 1

    if args.out:
        report = {
            "config": {"messages": args.messages, "devices": args.devices,
                       "consumers": args.consumers, "rate_hz": args.rate_hz,
                       "payload_bytes": args.payload_bytes,
                       "service_s": args.service_s, "seed": args.seed},
            "baseline": BASELINE,
            "headline": {"events_per_s": headline["events_per_s"],
                         "speedup_vs_baseline": speedup},
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, default=float)
        print(f"wrote {args.out} ({total_wall:.1f} s total)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
