"""Million/ten-million-event DES scale benchmark: simulator speed *and
memory* as tracked perf surfaces.

Drives the *real* ``EdgeToCloudPipeline`` under ``SimExecutor`` with
open-loop arrival processes (Poisson / diurnal / flash-crowd / recorded
trace replay) and raw ``bytes`` payloads, so the measured cost is the
event loop itself — scheduler heap, actor stepping, broker fan-out,
poll/wake — not numpy serialization.  The headline cell is the
full-size Poisson run; the sweep adds diurnal, flash-crowd, and (with
``--trace``) trace-replay cells at a tenth the size so every arrival
process stays on the tracked surface.

Memory mode (the 10M-event configuration)::

    PYTHONPATH=src python benchmarks/bench_des_scale.py \\
        --messages 2500000 --streaming-metrics --truncate-logs 4096 \\
        --rss --trace benchmarks/traces/azure_functions_like.txt \\
        --out BENCH_des_scale.json

``--streaming-metrics`` folds message traces into fixed-memory latency
sketches (``MetricsRegistry(streaming=True)``), ``--truncate-logs N``
reclaims broker-log prefixes below the committed offsets in batches of
``N``, and ``--rss`` measures *per-cell* peak RSS (``VmHWM`` reset via
``/proc/self/clear_refs`` before each cell) instead of the process-
lifetime high-water mark — together they hold peak RSS flat in run
length.  ``--max-rss-mb`` turns the headline cell's peak RSS into a
hard gate (CI's memory ceiling).

Two kinds of numbers per row:

* **deterministic** (virtual time, event counts, latency percentiles,
  bytes, truncation counters) — bit-identical for a given seed, gated
  by ``--check-determinism`` (three full sweeps must agree);
* **wall-clock** (``wall_s``, ``events_per_s``, ``rss_mb``,
  ``peak_rss_mb``) — the perf trajectory.  Excluded from the
  determinism comparison.

The committed ``BENCH_des_scale.json`` records the pre-rework baseline
(measured on this machine before the event-loop fixes) next to the
headline events/s, so the speedup is auditable.

Row shape is pinned by ``benchmarks/BENCH_des_scale.schema.json``
(validated in CI by ``tools/check_bench_schema.py``; the file is
uploaded as a CI artifact on every run).
"""
from __future__ import annotations

import argparse
import cProfile
import io
import json
import pstats
import resource
import sys
import time

from repro.core import ComputeResource, EdgeToCloudPipeline, PilotManager
from repro.core.executor import SimExecutor
from repro.core.monitoring import MetricsRegistry
from repro.sim.clock import SimClock
from repro.sim.scenarios import arrival_process
from repro.sim.shard import run_scale_sharded

# Pre-rework event-loop throughput, measured on the commit just before
# the compacting-heap / actor-slot-reuse / waiter-index changes (same
# machine, same SimExecutor surface).  Kept in the committed JSON so the
# headline speedup is anchored to a recorded number, not folklore.
BASELINE = {
    "events_per_s": 3188.0,
    "config": ("20000 msgs / 100 devices / 1000 consumers, kmeans cloud "
               "100mbit closed-loop (pre-rework event loop: O(n) "
               "cancelled-event sweeps, per-step event allocation, "
               "O(all-tasks) append scans, per-join wake-all)"),
}

# row keys compared by --check-determinism (wall-clock keys excluded)
DETERMINISTIC_KEYS = (
    "arrival", "messages", "devices", "consumers", "payload_bytes",
    "seed", "streaming_metrics", "processed", "duplicates", "events",
    "truncated_msgs", "makespan_s", "lat_p50_s", "lat_p95_s", "wan_bytes",
)


# row keys that must be bit-identical between the single-process and
# sharded runs of the same cell (--shard-parity); "events" is excluded:
# each shard runs its own monitor ticks, so the *scheduler* event count
# differs even though every message-level column is identical
PARITY_KEYS = (
    "processed", "duplicates", "truncated_msgs", "makespan_s",
    "lat_p50_s", "lat_p95_s", "wan_bytes",
)


def _arrival(kind: str, rate_hz: float, trace: str = None):
    # the bench's arrival parameters live in repro.sim.scenarios so the
    # sharded runner draws the *same* streams (shard parity depends on
    # bit-identical arrival times)
    return arrival_process(kind, rate_hz, trace)


def _reset_peak_rss() -> bool:
    """Reset the kernel's per-process RSS high-water mark (``VmHWM``).
    Returns False where unsupported (non-Linux/procfs)."""
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
        return True
    except OSError:
        return False


def _peak_rss_mb() -> float:
    """Peak RSS in MB since the last ``_reset_peak_rss`` (``VmHWM``),
    falling back to the process-lifetime ``ru_maxrss``."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except (OSError, ValueError, IndexError):
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_cell(*, arrival: str, messages: int, devices: int, consumers: int,
             rate_hz: float, payload_bytes: int, service_s: float,
             seed: int, streaming: bool = False, truncate_logs=None,
             trace: str = None, per_cell_rss: bool = False) -> dict:
    """One open-loop run on the genuine pipeline; returns a bench row."""
    if per_cell_rss:
        _reset_peak_rss()
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock, streaming=streaming)
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=devices))
    cloud = mgr.submit_pilot(
        ComputeResource(tier="cloud", n_workers=consumers))
    payload = bytes(payload_bytes)   # raw bytes: passthrough serialization
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: payload,
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=devices, n_partitions=devices,
        cloud_consumers=consumers, topic_name="des-scale",
        truncate_logs=truncate_logs, metrics=metrics, clock=clock)
    times = _arrival(arrival, rate_hz, trace).times(messages, seed)
    plan = [times[i::devices] for i in range(devices)]
    ex = SimExecutor(
        clock,
        service_model=((lambda stage, ctx, data: service_s)
                       if service_s > 0.0 else None))

    t0 = time.perf_counter()
    res = pipe.run(timeout_s=float(times[-1]) + 120.0,
                   collect_results=False, scheduler=ex, arrival_plan=plan)
    wall = time.perf_counter() - t0
    topic_name = pipe._topics[0].name
    truncated = sum(t.truncated_msgs for t in pipe._topics)
    mgr.release_all()

    m = res.metrics
    if streaming:
        p50 = m.percentile(0.50, "produced", "processed")
        p95 = m.percentile(0.95, "produced", "processed")
    else:
        lat = m.latencies("produced", "processed")
        lat.sort()
        n = len(lat)
        p50 = lat[n // 2] if n else 0.0
        p95 = lat[min(n - 1, int(0.95 * n))] if n else 0.0
    first = m.first_stamp("produced") or 0.0
    last = m.last_stamp("processed") or first
    events = ex.sched.executed
    # ru_maxrss is the process-lifetime high-water mark (KB on Linux):
    # monotone across cells, so the largest cell owns the reported peak.
    # peak_rss_mb is the per-cell VmHWM when --rss reset it above,
    # otherwise it duplicates the lifetime mark.
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    return {
        "arrival": arrival, "messages": messages, "devices": devices,
        "consumers": consumers, "payload_bytes": payload_bytes,
        "seed": seed,
        "streaming_metrics": streaming,
        "processed": res.n_processed,
        "duplicates": int(m.counter("pipeline.duplicates_dropped")),
        "events": events,
        "truncated_msgs": truncated,
        "makespan_s": max(last - first, 1e-9),
        "lat_p50_s": p50,
        "lat_p95_s": p95,
        "wan_bytes": m.counter(f"topic.{topic_name}.bytes_in"),
        "wall_s": wall,
        "events_per_s": events / max(wall, 1e-9),
        "rss_mb": rss_mb,
        "peak_rss_mb": _peak_rss_mb() if per_cell_rss else rss_mb,
    }


def run_sweep(args) -> list:
    cells = [
        # headline: full size, Poisson
        dict(arrival="poisson", messages=args.messages),
        # arrival-process coverage at a tenth the size
        dict(arrival="diurnal", messages=max(args.messages // 10, 1000)),
        dict(arrival="flash", messages=max(args.messages // 10, 1000)),
    ]
    if args.trace:
        cells.append(
            dict(arrival="trace", messages=max(args.messages // 10, 1000)))
    rows = []
    for cell in cells:
        row = run_cell(arrival=cell["arrival"], messages=cell["messages"],
                       devices=args.devices, consumers=args.consumers,
                       rate_hz=args.rate_hz,
                       payload_bytes=args.payload_bytes,
                       service_s=args.service_s, seed=args.seed,
                       streaming=args.streaming_metrics,
                       truncate_logs=args.truncate_logs,
                       trace=args.trace, per_cell_rss=args.rss)
        print(f"  {row['arrival']:>8}  {row['messages']:>9,} msgs  "
              f"{row['events']:>9,} events  {row['wall_s']:6.1f} s wall  "
              f"{row['events_per_s']:>9,.0f} ev/s  "
              f"{row['peak_rss_mb']:6.0f} MB peak rss  "
              f"{row['truncated_msgs']:>9,} truncated")
        rows.append(row)
    return rows


def run_profile(args, out_path: str = "PROFILE_des.txt") -> None:
    """cProfile a reduced headline cell and report the top-25 functions
    by cumulative time — the single-thread hot-loop map that guided the
    lock-elision / attribute-hoisting squeeze.  Prints to stdout and
    writes the same table to ``out_path`` (a CI artifact)."""
    messages = min(args.messages, 30_000)
    prof = cProfile.Profile()
    prof.enable()
    run_cell(arrival="poisson", messages=messages, devices=args.devices,
             consumers=args.consumers, rate_hz=args.rate_hz,
             payload_bytes=args.payload_bytes, service_s=args.service_s,
             seed=args.seed, streaming=args.streaming_metrics,
             truncate_logs=args.truncate_logs)
    prof.disable()
    buf = io.StringIO()
    stats = pstats.Stats(prof, stream=buf)
    stats.sort_stats("cumulative").print_stats(25)
    table = buf.getvalue()
    header = (f"cProfile of one reduced headline cell "
              f"({messages:,} msgs / {args.devices} devices / "
              f"{args.consumers} consumers), top 25 by cumulative time\n")
    print(f"\n{header}{table}")
    with open(out_path, "w") as f:
        f.write(header + table)
    print(f"wrote {out_path}")


def run_sharded(args) -> dict:
    """The sharded headline cell: same messages/seed/arrival as the
    single-process headline, split ``--shards`` ways."""
    row = run_scale_sharded(
        arrival="poisson", messages=args.messages, devices=args.devices,
        consumers=args.consumers, rate_hz=args.rate_hz,
        payload_bytes=args.payload_bytes, service_s=args.service_s,
        seed=args.seed, shards=args.shards,
        streaming=args.streaming_metrics,
        truncate_logs=args.truncate_logs, mode=args.shard_mode)
    print(f"  sharded x{row['shards']} ({row['mode']}):  "
          f"{row['messages']:>9,} msgs  {row['events']:>9,} events  "
          f"{row['wall_s']:6.1f} s wall  "
          f"{row['agg_events_per_s']:>9,.0f} ev/s aggregate  "
          f"({row['cpu_critical_s']:.1f} s critical-path cpu, "
          f"{row['windows']} window(s))")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--messages", type=int, default=1_000_000,
                    help="messages in the headline Poisson cell "
                         "(diurnal/flash/trace cells run a tenth of this)")
    ap.add_argument("--devices", type=int, default=100)
    ap.add_argument("--consumers", type=int, default=1000)
    ap.add_argument("--rate-hz", type=float, default=20_000.0,
                    help="aggregate open-loop arrival rate")
    ap.add_argument("--payload-bytes", type=int, default=64)
    ap.add_argument("--service-s", type=float, default=0.001,
                    help="deterministic per-message service charge")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="also run a trace-replay cell from this "
                         "timestamp file (see benchmarks/traces/)")
    ap.add_argument("--streaming-metrics", action="store_true",
                    help="MetricsRegistry(streaming=True): sketch-backed "
                         "percentiles, memory independent of run length")
    ap.add_argument("--truncate-logs", type=int, default=None, metavar="N",
                    help="reclaim broker-log prefixes below the committed "
                         "offsets in batches of N messages")
    ap.add_argument("--rss", action="store_true",
                    help="measure per-cell peak RSS (VmHWM reset before "
                         "each cell) instead of the process-lifetime mark")
    ap.add_argument("--max-rss-mb", type=float, default=None,
                    help="fail unless the headline cell's peak RSS stays "
                         "under this ceiling (CI memory gate)")
    ap.add_argument("--check-determinism", action="store_true",
                    help="run the sweep three times; fail unless every "
                         "deterministic column is identical")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile a reduced headline cell first: top-25 "
                         "cumulative functions to stdout + PROFILE_des.txt")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="also run the headline cell sharded N ways "
                         "(conservative time-window parallel DES)")
    ap.add_argument("--shard-mode", choices=("mp", "inline"), default="mp",
                    help="sharded run backend: one OS process per shard "
                         "(mp) or sequential in-process (inline)")
    ap.add_argument("--shard-parity", action="store_true",
                    help="fail unless the sharded run's deterministic "
                         "columns are bit-identical to the single-process "
                         "headline cell")
    ap.add_argument("--out", default=None, help="write the report as JSON")
    args = ap.parse_args(argv)

    if args.profile:
        run_profile(args)

    t0 = time.perf_counter()
    rows = run_sweep(args)
    total_wall = time.perf_counter() - t0
    headline = rows[0]
    speedup = headline["events_per_s"] / BASELINE["events_per_s"]
    print(f"\nheadline: {headline['events_per_s']:,.0f} events/s at "
          f"{headline['messages']:,} msgs x {headline['consumers']} "
          f"consumers ({speedup:.1f}x the recorded "
          f"{BASELINE['events_per_s']:,.0f} ev/s pre-rework baseline)")

    rc = 0
    sharded = None
    if args.shards > 0:
        sharded = run_sharded(args)
        sharded["parity_vs_single"] = all(
            sharded[k] == headline[k] for k in PARITY_KEYS)
        sharded["speedup_vs_single"] = (
            sharded["agg_events_per_s"] / max(headline["events_per_s"],
                                              1e-9))
        print(f"  sharded aggregate speedup: "
              f"{sharded['speedup_vs_single']:.1f}x the single-process "
              f"headline rate")
        if args.shard_parity:
            if sharded["parity_vs_single"]:
                print("shard parity: OK (deterministic columns "
                      "bit-identical to the single-process headline)")
            else:
                diffs = [f"{k}: single={headline[k]!r} "
                         f"sharded={sharded[k]!r}"
                         for k in PARITY_KEYS
                         if sharded[k] != headline[k]]
                print("shard parity: FAILED — " + "; ".join(diffs))
                rc = 1
    if args.max_rss_mb is not None:
        peak = headline["peak_rss_mb"]
        if peak > args.max_rss_mb:
            print(f"peak RSS gate: FAILED — headline cell peaked at "
                  f"{peak:.0f} MB > {args.max_rss_mb:.0f} MB ceiling")
            rc = 1
        else:
            print(f"peak RSS gate: OK ({peak:.0f} MB <= "
                  f"{args.max_rss_mb:.0f} MB ceiling)")
    if args.check_determinism:
        def det(rs):
            return [[r[k] for k in DETERMINISTIC_KEYS] for r in rs]
        reruns = [run_sweep(args) for _ in range(2)]
        if all(det(rows) == det(rn) for rn in reruns):
            print("determinism: OK (identical deterministic columns "
                  "across three full sweeps)")
        else:
            print("determinism: FAILED — deterministic columns differ")
            rc = 1

    if args.out:
        report = {
            "config": {"messages": args.messages, "devices": args.devices,
                       "consumers": args.consumers, "rate_hz": args.rate_hz,
                       "payload_bytes": args.payload_bytes,
                       "service_s": args.service_s, "seed": args.seed,
                       "trace": args.trace,
                       "streaming_metrics": args.streaming_metrics,
                       "truncate_logs": args.truncate_logs,
                       "shards": args.shards},
            "baseline": BASELINE,
            "headline": {"events_per_s": headline["events_per_s"],
                         "speedup_vs_baseline": speedup},
            "rows": rows,
        }
        if sharded is not None:
            report["sharded"] = sharded
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, default=float)
        print(f"wrote {args.out} ({total_wall:.1f} s total)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
