"""Paper Fig 3 (right): geographic distribution. The data source sits on
"XSEDE (US)" and processing on "LRZ (Germany)"; the WAN between them is
the paper's measured band (140–160 ms RTT, 60–100 Mbit/s). We sweep the
WAN parameters across that band and compare against the local baseline
for the light (baseline/k-means) vs heavy (auto-encoder) workloads —
reproducing the paper's finding that intercontinental transfer caps the
light models while the compute-bound models don't notice the network.

Measured on the DES, not the wall clock: this bench reuses the scale
benchmark's open-loop driver — a Poisson arrival plan on a ``SimClock``
under ``SimExecutor``, per-message compute priced by the *calibrated*
cost model (``CostModel.service_model``) and the WAN as a deterministic
``sleep=False`` shaper — so a cell takes milliseconds of wall time and
every number is bit-reproducible for a given seed.  (The seed-era
version ran threaded consumers with real ``time.sleep`` shaping and real
kernel compute on the driver: minutes of wall clock per sweep, numbers
that moved with host load.)

Throughput is ``processed / makespan`` in *virtual* seconds: offered
load (``--rate-hz``) is set above the WAN band's drain rate, so a
network-capped cell shows up as a stretched makespan, exactly like the
paper's saturated pipeline.
"""
from __future__ import annotations

import argparse
import json

from repro.core import (ComputeResource, EdgeToCloudPipeline, PilotManager,
                        WanShaper)
from repro.core.executor import SimExecutor
from repro.core.monitoring import MetricsRegistry
from repro.cost.model import default_cost_model
from repro.ml.datagen import message_nbytes
from repro.sim.clock import SimClock
from repro.sim.scenarios import arrival_process


def run(model_name: str, n_points: int, n_messages: int,
        band: tuple | None, *, rate_hz: float, partitions: int = 4,
        seed: int = 0):
    # fresh shaper per run: its token bucket (_available_at) is absolute
    # virtual time, and every run starts a new clock at zero
    wan = (None if band is None else
           WanShaper(bandwidth_bps=band[0], rtt_s=band[1], sleep=False))
    cost = default_cost_model()
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=partitions))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=partitions))
    nbytes = message_nbytes(n_points)
    payload = bytes(nbytes)     # raw bytes: compute is *priced*, not run
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: payload,
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=partitions, n_partitions=partitions,
        cloud_consumers=partitions, topic_name="geo",
        wan_shaper=wan, metrics=metrics, clock=clock)
    # calibrated per-stage charges: edge pre-aggregation next to the
    # generator, the full model on the cloud consumers ("baseline" is
    # the old raw-mean pass: effectively free, pure network)
    if model_name == "baseline":
        stage_times = {}
    else:
        stage_times = {
            "produce": cost.preprocess_s(model_name, n_points, "edge"),
            "process_cloud": cost.model_compute_s(model_name, n_points,
                                                  "cloud"),
        }
    times = arrival_process("poisson", rate_hz).times(n_messages, seed)
    plan = [times[i::partitions] for i in range(partitions)]
    ex = SimExecutor(clock, service_model=cost.service_model(stage_times))
    res = pipe.run(scheduler=ex, timeout_s=float(times[-1]) + 1200.0,
                   collect_results=False, arrival_plan=plan)
    m = res.metrics
    first = m.first_stamp("produced") or 0.0
    last = m.last_stamp("processed") or first
    makespan = max(last - first, 1e-9)
    lat = m.latencies("produced", "processed")
    lat.sort()
    mgr.release_all()
    return {"model": model_name, "n_points": n_points,
            "wan": "none" if wan is None else
            f"{wan.bandwidth_bps/1e6:.0f}Mbit/{wan.rtt_s*1e3:.0f}ms",
            "processed": res.n_processed,
            "msgs_per_s": res.n_processed / makespan,
            "mb_per_s": res.n_processed * nbytes / makespan / 1e6,
            "latency_mean_ms": (sum(lat) / len(lat) * 1e3) if lat else 0.0,
            "latency_p95_ms": (lat[min(len(lat) - 1,
                                       int(0.95 * len(lat)))] * 1e3)
                              if lat else 0.0}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--messages", type=int, default=200)
    ap.add_argument("--points", type=int, default=2_500)
    ap.add_argument("--rate-hz", type=float, default=40.0,
                    help="aggregate open-loop offered rate (set above the "
                         "WAN band's drain rate so a network cap shows as "
                         "a stretched makespan)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--models", nargs="*",
                    default=["baseline", "kmeans", "autoencoder"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    # the paper's iPerf band endpoints + local baseline
    bands = [None, (100e6, 0.140), (60e6, 0.160)]
    rows = []
    print(f"message: {message_nbytes(args.points)/1e3:.0f} KB, "
          f"{args.messages} msgs at {args.rate_hz:.0f} Hz offered")
    print(f"{'model':>12} {'wan':>15} {'msg/s':>9} {'MB/s':>8} "
          f"{'lat ms':>9} {'p95 ms':>9}")
    for model in args.models:
        for band in bands:
            r = run(model, args.points, args.messages, band,
                    rate_hz=args.rate_hz, seed=args.seed)
            rows.append(r)
            print(f"{r['model']:>12} {r['wan']:>15} "
                  f"{r['msgs_per_s']:9.2f} {r['mb_per_s']:8.2f} "
                  f"{r['latency_mean_ms']:9.1f} {r['latency_p95_ms']:9.1f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
