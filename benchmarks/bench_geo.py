"""Paper Fig 3 (right): geographic distribution. The data source sits on
"XSEDE (US)" and processing on "LRZ (Germany)"; the WAN between them is the
paper's measured band (140–160 ms RTT, 60–100 Mbit/s). We sweep the WAN
parameters across that band and compare against the local baseline for the
light (k-means/baseline) vs heavy (auto-encoder) workloads — reproducing the
paper's finding that intercontinental transfer caps the light models while
the compute-bound models don't notice the network.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import (ComputeResource, EdgeToCloudPipeline, PilotManager,
                        WanShaper)
from repro.ml import AutoEncoder, KMeans, MiniAppGenerator
from repro.ml.datagen import message_nbytes


def run(model_name: str, n_points: int, n_messages: int,
        wan: WanShaper | None, partitions: int = 4):
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=partitions))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=partitions))
    gen = MiniAppGenerator(n_points=n_points, seed=0)
    if model_name == "baseline":
        proc = lambda ctx, data=None: float(np.mean(data))
    elif model_name == "kmeans":
        proc = KMeans(n_clusters=25).make_processor()
    else:
        proc = AutoEncoder().make_processor()
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=gen.make_producer(),
        process_cloud_function_handler=proc,
        n_edge_devices=partitions, wan_shaper=wan)
    res = pipe.run(n_messages=n_messages, timeout_s=1200)
    tp = res.throughput()
    mgr.release_all()
    return {"model": model_name, "n_points": n_points,
            "wan": "none" if wan is None else
            f"{wan.bandwidth_bps/1e6:.0f}Mbit/{wan.rtt_s*1e3:.0f}ms",
            "processed": res.n_processed,
            "msgs_per_s": tp["msgs_per_s"],
            "mb_per_s": tp["bytes_per_s"] / 1e6,
            "latency_mean_ms": res.latency().get("mean_s", 0) * 1e3}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--messages", type=int, default=32)
    ap.add_argument("--points", type=int, default=2_500)
    ap.add_argument("--models", nargs="*",
                    default=["baseline", "kmeans", "autoencoder"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    # the paper's iPerf band endpoints + local baseline
    wans = [None,
            WanShaper(bandwidth_bps=100e6, rtt_s=0.140, sleep=True),
            WanShaper(bandwidth_bps=60e6, rtt_s=0.160, sleep=True)]
    rows = []
    print(f"message: {message_nbytes(args.points)/1e3:.0f} KB")
    print(f"{'model':>12} {'wan':>15} {'msg/s':>9} {'MB/s':>8} "
          f"{'lat ms':>9}")
    for model in args.models:
        for wan in wans:
            r = run(model, args.points, args.messages, wan)
            rows.append(r)
            print(f"{r['model']:>12} {r['wan']:>15} "
                  f"{r['msgs_per_s']:9.2f} {r['mb_per_s']:8.2f} "
                  f"{r['latency_mean_ms']:9.1f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
