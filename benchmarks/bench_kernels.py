"""Fused k-means kernel microbench: the two-pass one-hot baseline vs the
fused assign+update lowering, across the precision axis (fp32 / bf16 /
int8) — the kernel-level half of the "precision as a placement axis"
story (``bench_placement.py`` sweeps the system-level half)::

    PYTHONPATH=src python benchmarks/bench_kernels.py --min-speedup 1.5

Per (shape, precision) cell the bench times one streaming k-means
message under both hot paths: the *seed's* two-pass path (an outlier-
scoring distance pass, then the historical update — a second distance
pass plus the ``(N,K)`` one-hot materialization and ``(K,N)@(N,F)``
matmul) vs the fused single pass (``impl='fused'``: one distance pass
yields scores *and* the scatter-add membership stats — the formulation
the Pallas kernel implements on TPU).  It also checks the fused Pallas
kernel (interpret mode on CPU) against the jnp lowering on a small
probe, and records assignment agreement vs the fp32 reference.

``--check-determinism`` re-runs everything three times and fails unless
the *deterministic* columns (checksums, agreement, parity — everything
except wall times, speedup and the host-dependent autotuned ``block_n``)
are bit-identical.  ``--out`` writes rows as JSON; the row shape is
pinned by ``benchmarks/BENCH_kernels.schema.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels.kmeans import autotune_block_n
from repro.ml.kmeans import PRECISIONS, _assign, _assign_update

# shapes fixed apart from the headline point count: (n_points, f, k)
SECONDARY_SHAPES = ((100_000, 32, 25),)
PARITY_SHAPE = (2_048, 32, 25)   # small enough for interpret-mode Pallas


def _make_data(n: int, f: int, k: int):
    """Deterministic clustered blob: k centers, gaussian spread."""
    kc, kn, ki = jax.random.split(jax.random.key(0), 3)
    centers = jax.random.normal(kc, (k, f)) * 10.0
    ids = jax.random.randint(ki, (n,), 0, k)
    pts = centers[ids] + jax.random.normal(kn, (n, f))
    # seed centroids from the first k points (distinct enough post-noise)
    return jnp.asarray(pts, jnp.float32), jnp.asarray(pts[:k], jnp.float32)


def _time(fn, repeats: int) -> float:
    fn()                                       # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _checksum_ids(ids) -> int:
    # host-side numpy: jax x64 is disabled, int32 would overflow at 1M rows
    import numpy as np
    ids = np.asarray(ids, np.int64)
    w = np.arange(ids.shape[0], dtype=np.int64) % 1_009
    return int(np.sum(ids * (w + 1)) % (2 ** 31))


def _pallas_parity(precision: str) -> bool:
    """Fused Pallas kernel vs the fused jnp lowering on a small probe:
    ids exact, counts exact, updated centroids allclose (accumulation
    order inside the kernel's per-block dots differs from segment_sum)."""
    n, f, k = PARITY_SHAPE
    pts, cent = _make_data(n, f, k)
    counts0 = jnp.zeros((k,), jnp.float32)
    jcent, jc, jids, _ = _assign_update(cent, counts0, pts, impl="fused",
                                        precision=precision)
    pcent, pc, pids, _ = _assign_update(cent, counts0, pts, impl="pallas",
                                        precision=precision)
    return (bool(jnp.all(pids == jids)) and bool(jnp.all(pc == jc))
            and bool(jnp.allclose(pcent, jcent, rtol=1e-5, atol=1e-4)))


def run_rows(args):
    rows = []
    shapes = [(args.headline_points, 32, 25)] + list(SECONDARY_SHAPES)
    shapes = [s for s in shapes if s[0] <= args.headline_points] or shapes[:1]
    for n, f, k in shapes:
        pts, cent = _make_data(n, f, k)
        counts0 = jnp.zeros((k,), jnp.float32)
        fp32_ids = None
        for precision in PRECISIONS:

            def step_two_pass(precision=precision):
                # the seed's per-message hot path: outlier scoring (one
                # full distance pass), then the two-pass update (a second
                # distance pass + the one-hot matmul)
                s = _assign(cent, pts, impl="jnp", precision=precision)
                u = _assign_update(cent, counts0, pts, impl="jnp",
                                   precision=precision)
                jax.block_until_ready((s, u))
                return u

            def step_fused(precision=precision):
                out = _assign_update(cent, counts0, pts, impl="fused",
                                     precision=precision)
                jax.block_until_ready(out)
                return out

            two_pass = _time(step_two_pass, args.repeats)
            fused = _time(step_fused, args.repeats)
            new_cent, new_counts, ids, _ = step_fused()
            if precision == "fp32":
                fp32_ids = ids
                agreement = 1.0
            else:
                agreement = float(jnp.mean(
                    (ids == fp32_ids).astype(jnp.float32)))
            parity = (_pallas_parity(precision)
                      if not args.skip_parity else None)
            block_n = (autotune_block_n(n, f, k, precision=precision)
                       if not args.skip_autotune else None)
            rows.append({
                "n_points": n, "n_features": f, "n_clusters": k,
                "precision": precision,
                "two_pass_wall_s": two_pass, "fused_wall_s": fused,
                "speedup": two_pass / max(fused, 1e-12),
                "ids_checksum": _checksum_ids(ids),
                "counts_total": int(jnp.sum(new_counts)),
                "centroid_l2": float(jnp.sqrt(jnp.sum(
                    jnp.asarray(new_cent) ** 2))),
                "agreement_vs_fp32": agreement,
                "pallas_parity": parity,
                "block_n": block_n,
            })
    return rows


# wall times, speedup and the autotuned block size are host/run dependent
NONDETERMINISTIC = ("two_pass_wall_s", "fused_wall_s", "speedup", "block_n")


def _deterministic(rows):
    return [{k: v for k, v in r.items() if k not in NONDETERMINISTIC}
            for r in rows]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--headline-points", type=int, default=1_000_000,
                    help="N of the headline 1M x 32 x 25 cell (CI runs "
                         "a reduced size)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats (min-of wins)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless fused beats two-pass by this factor "
                         "on the headline fp32 cell")
    ap.add_argument("--skip-parity", action="store_true",
                    help="skip the interpret-mode Pallas parity probe")
    ap.add_argument("--skip-autotune", action="store_true",
                    help="skip the block_n autotune sweep")
    ap.add_argument("--check-determinism", action="store_true",
                    help="run three times; fail unless the deterministic "
                         "columns are identical across runs")
    ap.add_argument("--out", default=None, help="write rows as JSON")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    rows = run_rows(args)
    wall = time.perf_counter() - t0
    hdr = (f"{'n':>9} {'prec':>5} {'two-pass':>10} {'fused':>10} "
           f"{'speedup':>8} {'agree':>7} {'parity':>6} {'block_n':>7}")
    print(hdr)
    for r in rows:
        print(f"{r['n_points']:>9} {r['precision']:>5} "
              f"{r['two_pass_wall_s'] * 1e3:>8.1f}ms "
              f"{r['fused_wall_s'] * 1e3:>8.1f}ms "
              f"{r['speedup']:>7.2f}x {r['agreement_vs_fp32']:>7.4f} "
              f"{str(r['pallas_parity']):>6} {str(r['block_n']):>7}")
    print(f"{len(rows)} cells in {wall:.1f} s of wall time")

    rc = 0
    if args.min_speedup is not None:
        head = rows[0]
        assert head["precision"] == "fp32"
        if head["speedup"] < args.min_speedup:
            print(f"speedup check: FAILED — headline fp32 fused speedup "
                  f"{head['speedup']:.2f}x < {args.min_speedup:.2f}x")
            rc = 1
        else:
            print(f"speedup check: OK ({head['speedup']:.2f}x >= "
                  f"{args.min_speedup:.2f}x)")
    if rc == 0 and any(r["pallas_parity"] is False for r in rows):
        print("parity check: FAILED — Pallas kernel diverges from the "
              "fused jnp lowering")
        rc = 1
    if args.check_determinism:
        ref = _deterministic(rows)
        reruns = [_deterministic(run_rows(args)) for _ in range(2)]
        if all(ref == other for other in reruns):
            print("determinism: OK (identical checksums/agreement/parity "
                  "across three runs)")
        else:
            print("determinism: FAILED — deterministic columns differ "
                  "across runs")
            rc = 1
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    return rc


if __name__ == "__main__":
    sys.exit(main())
