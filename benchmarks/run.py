"""Benchmark aggregator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full]

Sections:
  fig2   — bench_pipeline: throughput/latency × message size × partitions
  fig3l  — bench_models:   throughput/latency × model type (kmeans/iforest/AE)
  fig3r  — bench_geo:      local vs WAN-shaped geo distribution
  claims — validates the paper's relative claims on the measured rows
Emits ``name,value,unit`` CSV lines at the end for machine parsing.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import bench_geo, bench_models, bench_pipeline


def validate_claims(model_rows):
    """The paper's §V quantitative claims we can hold our implementation
    to: (a) k-means strictly outperforms both other models at every
    message size; (b) k-means/iforest ≈ 5x at 10k points (same order of
    magnitude expected — absolute ratios are implementation-specific);
    (c) the heavy models' relative cost grows with message size.

    The paper's iforest > AE ordering is NOT asserted: it reflects
    sklearn-C iforest vs Keras-AE-with-GC-trouble speeds; our vectorized
    JAX AE (11.5k params, jitted Adam) is faster than our vectorized
    iforest (100 trees refit/message). Both orderings are
    implementation-dependent; k-means dominance is the structural claim.
    """
    def tput(model, pts):
        xs = [r["msgs_per_s"] for r in model_rows
              if r["model"] == model and r["n_points"] == pts]
        return float(np.mean(xs)) if xs else float("nan")

    out = {}
    for pts in sorted({r["n_points"] for r in model_rows}):
        km, iso, ae = (tput("kmeans", pts), tput("iforest", pts),
                       tput("autoencoder", pts))
        out[pts] = {"kmeans": km, "iforest": iso, "autoencoder": ae,
                    "km_over_iso": km / iso if iso == iso and iso else
                    float("nan"),
                    "km_over_ae": km / ae if ae == ae and ae else
                    float("nan")}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small message counts (CI-sized)")
    ap.add_argument("--full", action="store_true",
                    help="paper-sized 512-message runs")
    args = ap.parse_args(argv)

    msgs = 512 if args.full else (24 if args.quick else 64)
    mm = 512 if args.full else (12 if args.quick else 32)
    csv = []

    print("=" * 72)
    print("fig2: baseline throughput/latency by message size × partitions")
    print("=" * 72)
    rows2 = bench_pipeline.main(["--messages", str(msgs),
                                 "--repeats", "1" if args.quick else "2"])
    for r in rows2:
        csv.append((f"fig2.p{r['n_points']}.part{r['partitions']}"
                    f".rep{r['rep']}.msgs_per_s", r["msgs_per_s"], "msg/s"))

    print()
    print("=" * 72)
    print("fig3-left: throughput/latency by model type × message size")
    print("=" * 72)
    rows3 = bench_models.main(["--messages", str(mm),
                               "--points", "250", "2500", "10000",
                               "--fused"])
    for r in rows3:
        csv.append((f"fig3l.{r['model']}.p{r['n_points']}.msgs_per_s",
                    r["msgs_per_s"], "msg/s"))

    print()
    print("=" * 72)
    print("fig3-right: geographic distribution (WAN-shaped)")
    print("=" * 72)
    rowsg = bench_geo.main(["--messages", str(mm), "--points", "2500"])
    for r in rowsg:
        csv.append((f"fig3r.{r['model']}.{r['wan']}.msgs_per_s",
                    r["msgs_per_s"], "msg/s"))

    print()
    print("=" * 72)
    print("paper-claim validation (§V: model-complexity ordering)")
    print("=" * 72)
    claims = validate_claims([r for r in rows3 if "fused" not in r["model"]])
    ok = True
    for pts, c in claims.items():
        km_dominates = (c["kmeans"] > c["iforest"]
                        and c["kmeans"] > c["autoencoder"])
        statum = "OK " if km_dominates else "VIOLATED"
        print(f"  {pts:6d} pts: kmeans {c['kmeans']:8.2f} msg/s > "
              f"iforest {c['iforest']:8.2f} & AE {c['autoencoder']:8.2f} "
              f"[{statum}]  km/iso={c['km_over_iso']:.1f}x "
              f"km/AE={c['km_over_ae']:.1f}x (paper: km/iso ~5x at 10k)")
        csv.append((f"claims.p{pts}.km_over_iso", c["km_over_iso"], "x"))
        csv.append((f"claims.p{pts}.km_over_ae", c["km_over_ae"], "x"))
        ok = ok and km_dominates
    print("  note: the paper's iforest>AE sub-ordering is "
          "implementation-specific (sklearn-C vs Keras); our JAX AE "
          "outruns our JAX iforest — k-means dominance is the structural "
          "claim and holds.")

    print()
    print("name,value,unit")
    for name, value, unit in csv:
        print(f"{name},{value:.4f},{unit}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
