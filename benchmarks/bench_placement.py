"""DES-backed placement-advisor sweep: for each calibrated workload the
:class:`~repro.cost.advisor.PlacementAdvisor` emulates the *real*
pipeline under ``SimExecutor`` across
{edge, cloud, hybrid, fog} × {10/50/100 Mbit/s WAN} — the fog cells run
a genuine 3-stage edge→fog→cloud ``ContinuumPipeline`` and every row
carries its per-stage tier vector — each cell with the
workload's calibrated lognormal service noise — and ranks the placements
multi-objectively (throughput + p50/p95/p99 latency tail + WAN bytes,
optionally under ``--latency-budget`` / ``--wan-budget`` constraints and
a ``--hybrid-reduce`` sweep, with ``--speculative-factor`` straggler
speculation in the loop) — the paper's "evaluate task placement based on
multiple factors" claim as a reproducible benchmark::

    PYTHONPATH=src python benchmarks/bench_placement.py --check-determinism

``--check-determinism`` runs the whole advisory three times and fails
(non-zero exit) unless every ranked row is identical. ``--out`` writes the
rows as JSON; the row shape is pinned by
``benchmarks/BENCH_placement.schema.json`` (CI validates and uploads the
file as the ``BENCH_placement`` artifact on every run).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.cost.advisor import PlacementAdvisor
from repro.sim.scenarios import MODELS, PLACEMENTS, WAN_BANDS


def run_advisories(args):
    adv = PlacementAdvisor(n_messages=args.messages,
                           n_devices=args.devices,
                           n_points=args.points, seed=args.seed,
                           service_sigma=args.service_sigma,
                           speculative_factor=args.speculative_factor)
    reports = [adv.advise(m, placements=args.placements, bands=args.bands,
                          latency_budget=args.latency_budget,
                          wan_budget=args.wan_budget,
                          hybrid_reduce=args.hybrid_reduce,
                          metro_bands=args.metro_bands)
               for m in args.models]
    rows = [row for rep in reports for row in rep.rows()]
    return reports, rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--messages", type=int, default=32)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--points", type=int, default=2_500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--service-sigma", type=float, default=None,
                    help="lognormal service-noise sigma (default: each "
                         "workload's calibrated sigma from "
                         "calibration.json; 0 = noise-free)")
    ap.add_argument("--speculative-factor", type=float, default=0.0,
                    help="DES straggler speculation: launch a backup for "
                         "any service charge running past factor x the "
                         "trailing median (0 = off)")
    ap.add_argument("--latency-budget", type=float, default=None,
                    help="cap predicted p95 latency (s): cells over "
                         "budget are flagged infeasible and ranked last")
    ap.add_argument("--wan-budget", type=float, default=None,
                    help="cap advisory WAN megabytes per cell (same "
                         "filter-then-rank semantics)")
    ap.add_argument("--metro-bands", nargs="+", default=None,
                    help="sweep the fog placement's edge->fog metro band "
                         "(profile metro_bands names), the way --bands "
                         "sweeps the WAN hop")
    ap.add_argument("--hybrid-reduce", type=int, nargs="+", default=None,
                    help="sweep the hybrid placement's edge "
                         "pre-aggregation factor over these values")
    # nargs='+': an empty list would make --check-determinism pass
    # vacuously on zero advisory cells
    ap.add_argument("--models", nargs="+", default=sorted(MODELS),
                    choices=sorted(MODELS))
    ap.add_argument("--placements", nargs="+", default=list(PLACEMENTS),
                    choices=list(PLACEMENTS))
    ap.add_argument("--bands", nargs="+",
                    default=sorted(WAN_BANDS,
                                   key=lambda b: WAN_BANDS[b][0]),
                    choices=sorted(WAN_BANDS))
    ap.add_argument("--check-determinism", action="store_true",
                    help="run the advisory three times; fail unless the "
                         "ranked rows are identical across all runs")
    ap.add_argument("--out", default=None, help="write rows as JSON")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    reports, rows = run_advisories(args)
    wall = time.perf_counter() - t0
    for rep in reports:
        print(rep.table())
        for band in args.bands:
            best = rep.best(band)
            flag = "" if best.feasible else " [over budget]"
            print(f"  -> {rep.model} @ {band}: place on "
                  f"{best.placement} ({best.throughput_msgs_s:.2f} msg/s, "
                  f"p95 {best.latency_p95_s:.3f} s, "
                  f"p99 {best.latency_p99_s:.3f} s){flag}")
        print()
    print(f"{len(rows)} advisory cells in {wall*1e3:.0f} ms of wall time")

    rc = 0
    if args.check_determinism:
        reruns = [run_advisories(args)[1] for _ in range(2)]
        if all(rows == other for other in reruns):
            print("determinism: OK (identical advisories across three "
                  "runs of the real pipeline under SimExecutor)")
        else:
            print("determinism: FAILED — advisories differ across runs")
            rc = 1

    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    return rc


if __name__ == "__main__":
    sys.exit(main())
