"""Band-drop golden: mid-run WAN degradation, online re-advisory, live
placement hot-swap — static vs. re-advised, unsharded vs. tier-cut
sharded, all bit-reproducible.

The cell is a cloud placement on the 100 Mbit/s WAN whose link drops to
10 Mbit/s at t=8 s virtual (a :class:`~repro.sim.scenarios.DriftSpec`
scheduled as an ordinary DES event).  The *static* run rides out the
degraded band; the *re-advised* run has a
:class:`~repro.cost.readvisor.ReAdvisor` watching the observed hop
delay, which re-places the processing stage cloud→fog mid-run
(``rebind_stage`` + epoch-based consumer migration) and recovers the
tail.  The same re-advised scenario then runs under the 2-shard tier
cut (:func:`~repro.sim.shard.run_drift_sharded`, decisions shipped over
the window-sync control channel) and must match the unsharded run
bit-for-bit on the :data:`~repro.sim.shard.DRIFT_PARITY_COLS`.

The report (``--out``) is pinned by ``benchmarks/BENCH_drift.schema.json``
and committed at the repo root as ``BENCH_drift.json``; CI re-runs the
golden end-to-end with ``--check-determinism`` (three sweeps, identical
rows required) and validates the fresh report against the schema::

    PYTHONPATH=src python benchmarks/bench_drift.py --check-determinism \\
        --out BENCH_drift.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import replace

from repro.cost.readvisor import ReAdviseSpec
from repro.sim.scenarios import DriftSpec, Scenario, run_scenario
from repro.sim.shard import DRIFT_PARITY_COLS, run_drift_sharded


def golden(args) -> Scenario:
    """The re-advised band-drop cell (static variant: ``readvise=None``).

    Producers are paced (``gen_s_per_point``) to ~64 % utilisation of
    the healthy 100 Mbit/s WAN, so the pre-drift baseline is stable and
    the advisor's quiet period is a real property, not an accident of
    saturation.  After the drop to 10 Mbit/s the observed hop delay
    (~5 s+ per message) dwarfs the fog prediction by far more than the
    3x hysteresis, so the swap decision is unambiguous."""
    return Scenario(
        placement="cloud", wan_band="100mbit",
        n_messages=args.messages, n_points=args.points,
        gen_s_per_point=1.28e-4, seed=args.seed,
        speculative_factor=2.0,
        drift=(DriftSpec(at_s=args.drift_at, kind="band",
                         band=args.drift_band),),
        readvise=ReAdviseSpec(interval_s=2.0, min_samples=2,
                              hysteresis=3.0),
    )


def run_cell(sc: Scenario, *, shard_mode: str) -> dict:
    """One full golden evaluation: static row, re-advised row, and the
    shards=1 vs shards=2 parity projections.  Everything in the
    returned dict is deterministic (virtual-time) data."""
    static_sc = replace(sc, readvise=None)
    static = run_scenario(static_sc).row()
    readvised = run_scenario(sc).row()
    parity1 = run_drift_sharded(sc, shards=1)
    parity2 = run_drift_sharded(sc, shards=2, mode=shard_mode)
    return {"static": static, "readvised": readvised,
            "parity1": parity1, "parity2": parity2}


def check_cell(cell: dict) -> list:
    """Golden acceptance: swap happened, tail recovered, shards agree.
    Returns a list of violation strings (empty = pass)."""
    bad = []
    static, readvised = cell["static"], cell["readvised"]
    if static["swaps"]:
        bad.append(f"static run swapped: {static['swaps']}")
    swaps = readvised["swaps"]
    if len(swaps) != 1 or swaps[0]["from"] != "cloud" \
            or swaps[0]["to"] != "fog":
        bad.append(f"expected exactly one cloud->fog swap, got {swaps}")
    if not readvised["lat_p95_s"] < static["lat_p95_s"]:
        bad.append(f"re-advised p95 {readvised['lat_p95_s']:.3f} s did "
                   f"not beat static {static['lat_p95_s']:.3f} s")
    if readvised["processed"] != readvised["messages"]:
        bad.append(f"re-advised run processed {readvised['processed']} "
                   f"of {readvised['messages']} (exactly-once broke "
                   f"across the migration)")
    for col in DRIFT_PARITY_COLS:
        if cell["parity1"][col] != cell["parity2"][col]:
            bad.append(f"shard parity: {col} differs — "
                       f"shards=1 {cell['parity1'][col]!r} vs "
                       f"shards=2 {cell['parity2'][col]!r}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--messages", type=int, default=60)
    ap.add_argument("--points", type=int, default=25_000)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--drift-at", type=float, default=8.0,
                    help="virtual time of the WAN band drop")
    ap.add_argument("--drift-band", default="10mbit",
                    help="degraded WAN band name (profile wan_bands)")
    ap.add_argument("--shard-mode", default="inline",
                    choices=["inline", "mp"],
                    help="transport for the shards=2 parity run")
    ap.add_argument("--check-determinism", action="store_true",
                    help="run the golden three times; fail unless all "
                         "deterministic columns are identical")
    ap.add_argument("--out", default=None, help="write the report as JSON")
    args = ap.parse_args(argv)

    sc = golden(args)
    t0 = time.perf_counter()
    cell = run_cell(sc, shard_mode=args.shard_mode)
    wall = time.perf_counter() - t0

    static, readvised = cell["static"], cell["readvised"]
    speedup = static["lat_p95_s"] / readvised["lat_p95_s"]
    print(f"static:     p95 {static['lat_p95_s']:8.3f} s   makespan "
          f"{static['makespan_s']:7.2f} s   swaps {len(static['swaps'])}")
    print(f"re-advised: p95 {readvised['lat_p95_s']:8.3f} s   makespan "
          f"{readvised['makespan_s']:7.2f} s   swaps "
          f"{len(readvised['swaps'])}")
    for s in readvised["swaps"]:
        print(f"  swap {s['stage']}: {s['from']} -> {s['to']} "
              f"(decided t={s['t_decided']:.2f} s, applied "
              f"t={s['t_applied']:.2f} s, observed hop "
              f"{s['observed_hop_s']:.2f} s)")
    print(f"tail recovery: {speedup:.1f}x on p95; shards=2 "
          f"({cell['parity2']['mode']}) synced "
          f"{cell['parity2']['windows']} windows "
          f"[{wall*1e3:.0f} ms wall]")

    rc = 0
    bad = check_cell(cell)
    for b in bad:
        print(f"golden violation: {b}")
        rc = 1

    if args.check_determinism and rc == 0:
        reruns = [run_cell(sc, shard_mode=args.shard_mode)
                  for _ in range(2)]
        if all(cell == other for other in reruns):
            print("determinism: OK (identical static/re-advised/sharded "
                  "metrics — swap timestamps included — across three "
                  "runs)")
        else:
            print("determinism: FAILED — metrics differ across runs")
            rc = 1

    if args.out:
        report = {
            "config": {
                "messages": args.messages, "points": args.points,
                "seed": args.seed, "drift_at_s": args.drift_at,
                "drift_band": args.drift_band,
                "shard_mode": args.shard_mode,
            },
            "headline": {
                "static_p95_s": static["lat_p95_s"],
                "readvised_p95_s": readvised["lat_p95_s"],
                "p95_speedup": speedup,
                "parity_ok": not any("parity" in b for b in bad),
            },
            "static": static,
            "readvised": readvised,
            "parity": {"shards1": cell["parity1"],
                       "shards2": cell["parity2"]},
        }
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, default=float)
    return rc


if __name__ == "__main__":
    sys.exit(main())
