"""Emulated Fig-3 sweep: {k-means, autoencoder} × {edge, cloud, hybrid,
fog} × {10/50/100 Mbit/s WAN} in virtual time — on the *real* pipeline
(fog cells run the genuine 3-stage edge→fog→cloud ``ContinuumPipeline``;
every row carries its per-stage tier vector).

Each cell runs a genuine ``EdgeToCloudPipeline`` under
``run(scheduler=SimExecutor(...))`` (no harness replica): broker offsets,
consumer groups, dedup and metrics are the production code paths, only
time is virtual. The real-time version of this table
(benchmarks/bench_geo.py) needs minutes of wall clock per cell because
the WAN shaper actually sleeps; this grid finishes in about a second,
bit-reproducibly::

    PYTHONPATH=src python benchmarks/bench_sim.py --check-determinism

``--check-determinism`` runs the sweep three times and fails (non-zero
exit) unless all three produce identical rows. ``--out`` writes the rows
as JSON; the row shape is pinned by ``benchmarks/BENCH_sim.schema.json``
(CI uploads the file as the ``BENCH_sim.json`` artifact on every run).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.sim.scenarios import (AUTOENCODER, KMEANS, MODELS, PLACEMENTS,
                                 FailureSpec, Scenario, format_table,
                                 run_scenario, sweep)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--messages", type=int, default=64)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--points", type=int, default=2_500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--models", nargs="*", default=list(MODELS),
                    choices=list(MODELS))
    ap.add_argument("--placements", nargs="*", default=list(PLACEMENTS),
                    choices=list(PLACEMENTS))
    from repro.sim.scenarios import WAN_BANDS
    ap.add_argument("--bands", nargs="*",
                    default=["10mbit", "50mbit", "100mbit"],
                    choices=list(WAN_BANDS))
    ap.add_argument("--with-failures", action="store_true",
                    help="crash consumer 0 mid-run (restart after 1 s) "
                         "in every scenario")
    ap.add_argument("--service-sigma", type=float, default=0.0,
                    help="lognormal service-noise sigma for every model "
                         "(0 = the noise-free Fig-3 pins)")
    ap.add_argument("--calibrated-sigma", action="store_true",
                    help="use each model's calibrated sigma from "
                         "calibration.json instead of --service-sigma")
    ap.add_argument("--speculative-factor", type=float, default=0.0,
                    help="DES straggler speculation: backup any service "
                         "charge past factor x trailing median (0 = off)")
    ap.add_argument("--check-determinism", action="store_true",
                    help="run the sweep three times; fail unless metrics "
                         "are identical across all runs")
    ap.add_argument("--out", default=None, help="write rows as JSON")
    args = ap.parse_args(argv)

    failures = (FailureSpec(at_s=2.0, consumer_idx=0,
                            restart_after_s=1.0),) \
        if args.with_failures else ()
    kw = dict(models=[MODELS[m] for m in args.models],
              placements=args.placements, bands=args.bands,
              n_messages=args.messages, n_devices=args.devices,
              n_points=args.points, seed=args.seed, failures=failures,
              service_sigma=(None if args.calibrated_sigma
                             else args.service_sigma),
              speculative_factor=args.speculative_factor)

    t0 = time.perf_counter()
    results = sweep(**kw)
    wall = time.perf_counter() - t0
    print(format_table(results))
    total_virtual = sum(r.makespan_s for r in results)
    print(f"\n{len(results)} scenarios · {total_virtual:.1f} s of virtual "
          f"pipeline time emulated in {wall*1e3:.0f} ms of wall time")

    rc = 0
    if args.check_determinism:
        rows_a = [r.row() for r in results]
        reruns = [[r.row() for r in sweep(**kw)] for _ in range(2)]
        if all(rows_a == rows_n for rows_n in reruns):
            print("determinism: OK (identical metrics across three runs "
                  "of the real pipeline under SimExecutor)")
        else:
            print("determinism: FAILED — metrics differ across runs")
            rc = 1

    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.row() for r in results], f, indent=1, default=float)
    return rc


if __name__ == "__main__":
    sys.exit(main())
