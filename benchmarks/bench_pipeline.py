"""Paper Fig 2: baseline throughput + latency by message size and partition
count. Edge data source, broker and processing in one "cloud" (this host);
message sizes 25–10,000 points × 32 features (7 KB–2.6 MB); partitions
1/2/4 with one partition per simulated edge device; 512 messages per run in
the paper — scaled by --messages for CPU time budgets.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import ComputeResource, EdgeToCloudPipeline, PilotManager
from repro.ml import MiniAppGenerator, message_nbytes
from repro.ml.datagen import PAPER_POINTS


def run_cell(n_points: int, n_partitions: int, n_messages: int,
             repeats: int = 3, process=None):
    rows = []
    for rep in range(repeats):
        mgr = PilotManager()
        edge = mgr.submit_pilot(
            ComputeResource(tier="edge", n_workers=n_partitions))
        cloud = mgr.submit_pilot(
            ComputeResource(tier="cloud", n_workers=n_partitions))
        gen = MiniAppGenerator(n_points=n_points, seed=rep)
        proc = process or (lambda ctx, data=None: float(np.mean(data)))
        pipe = EdgeToCloudPipeline(
            pilot_cloud_processing=cloud, pilot_edge=edge,
            produce_function_handler=gen.make_producer(),
            process_cloud_function_handler=proc,
            n_edge_devices=n_partitions, n_partitions=n_partitions)
        res = pipe.run(n_messages=n_messages, timeout_s=600)
        tp = res.throughput()
        lat = res.latency()
        rows.append({
            "n_points": n_points, "partitions": n_partitions, "rep": rep,
            "msg_bytes": message_nbytes(n_points),
            "processed": res.n_processed,
            "msgs_per_s": tp["msgs_per_s"],
            "mb_per_s": tp["bytes_per_s"] / 1e6,
            "latency_mean_ms": lat.get("mean_s", 0) * 1e3,
            "latency_p95_ms": lat.get("p95_s", 0) * 1e3,
        })
        mgr.release_all()
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--messages", type=int, default=128,
                    help="messages per run (paper: 512)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--points", type=int, nargs="*",
                    default=list(PAPER_POINTS))
    ap.add_argument("--partitions", type=int, nargs="*", default=[1, 2, 4])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    all_rows = []
    print(f"{'points':>7} {'parts':>5} {'KB/msg':>8} {'msg/s':>9} "
          f"{'MB/s':>8} {'lat ms':>8}")
    for n_points in args.points:
        for parts in args.partitions:
            rows = run_cell(n_points, parts, args.messages, args.repeats)
            m = np.mean([r["msgs_per_s"] for r in rows])
            mb = np.mean([r["mb_per_s"] for r in rows])
            lat = np.mean([r["latency_mean_ms"] for r in rows])
            print(f"{n_points:7d} {parts:5d} "
                  f"{message_nbytes(n_points)/1e3:8.0f} {m:9.1f} "
                  f"{mb:8.1f} {lat:8.1f}")
            all_rows.extend(rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(all_rows, f, indent=1)
    # paper's qualitative claim: throughput (MB/s) grows with message size
    # and with partition count
    return all_rows


if __name__ == "__main__":
    main()
