"""Roofline report generator: reads dry-run JSON rows (launch/dryrun.py
--out) and renders the EXPERIMENTS.md §Roofline table with the three terms,
bottleneck, useful-FLOP ratio, and per-cell one-line recommendation."""
from __future__ import annotations

import argparse
import json
import sys


def recommendation(row) -> str:
    b = row["bottleneck"]
    if b == "collective":
        return ("shrink collective bytes: overlap grad all-reduce with "
                "microbatch compute, int8-compress the DCN hop, or move "
                "batch axes")
    if b == "memory":
        return ("cut HBM traffic: fuse attention (flash kernel), raise "
                "arithmetic intensity with larger per-chip batch, revisit "
                "remat policy")
    return "compute-bound — at the roofline; only kernel-level wins remain"


def render_table(rows, fmt="md"):
    cols = ["arch", "shape", "mesh", "chips", "t_compute_s", "t_memory_s",
            "t_collective_s", "bottleneck", "useful_flop_ratio",
            "roofline_fraction"]
    if fmt == "md":
        head = ("| " + " | ".join(cols) + " |\n" +
                "|" + "---|" * len(cols))
        lines = [head]
        for r in rows:
            vals = []
            for c in cols:
                v = r[c]
                vals.append(f"{v:.2e}" if isinstance(v, float) and c.startswith("t_")
                            else (f"{v:.3f}" if isinstance(v, float) else str(v)))
            lines.append("| " + " | ".join(vals) + " |")
        return "\n".join(lines)
    # csv
    out = [",".join(cols)]
    for r in rows:
        out.append(",".join(str(r[c]) for c in cols))
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("results", nargs="+", help="dry-run JSON files")
    ap.add_argument("--fmt", default="md", choices=["md", "csv"])
    args = ap.parse_args(argv)
    rows = []
    for path in args.results:
        with open(path) as f:
            data = json.load(f)
        rows.extend(data["rows"])
        for fail in data.get("failures", []):
            print(f"FAILURE: {fail}", file=sys.stderr)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(render_table(rows, args.fmt))
    print()
    for r in rows:
        print(f"- {r['arch']} × {r['shape']} [{r['mesh']}]: "
              f"{r['bottleneck']}-bound → {recommendation(r)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
