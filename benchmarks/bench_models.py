"""Paper Fig 3 (left): throughput/latency by model type and message size.

Streams each message-size sweep through the three outlier detectors
(k-means / isolation forest / auto-encoder) on the cloud pilot and reports
throughput + latency per model — the paper's model-complexity trade-off
(k-means ≫ isolation forest ≫ auto-encoder; ~5× at 10k points).

``--fused`` additionally runs the beyond-paper variant: instead of the
paper-faithful per-message python loop, consumers batch k messages and run
one jitted vectorized call — the §Perf "batched consumer" optimization.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ComputeResource, EdgeToCloudPipeline, PilotManager
from repro.ml import AutoEncoder, IsolationForest, KMeans, MiniAppGenerator
from repro.ml.datagen import message_nbytes


def make_processor(model_name: str, train: bool = True):
    if model_name == "kmeans":
        return KMeans(n_clusters=25).make_processor(train=train)
    if model_name == "iforest":
        return IsolationForest(n_trees=100).make_processor(train=train)
    if model_name == "autoencoder":
        return AutoEncoder().make_processor(train=train)
    raise ValueError(model_name)


def run_model(model_name: str, n_points: int, n_messages: int,
              partitions: int = 4, repeats: int = 1):
    rows = []
    for rep in range(repeats):
        mgr = PilotManager()
        edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                                n_workers=partitions))
        cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                                 n_workers=partitions))
        gen = MiniAppGenerator(n_points=n_points, seed=rep)
        pipe = EdgeToCloudPipeline(
            pilot_cloud_processing=cloud, pilot_edge=edge,
            produce_function_handler=gen.make_producer(),
            process_cloud_function_handler=make_processor(model_name),
            n_edge_devices=partitions)
        res = pipe.run(n_messages=n_messages, timeout_s=1200)
        tp = res.throughput()
        lat = res.latency()
        rows.append({
            "model": model_name, "n_points": n_points, "rep": rep,
            "processed": res.n_processed,
            "msgs_per_s": tp["msgs_per_s"],
            "mb_per_s": tp["bytes_per_s"] / 1e6,
            "latency_mean_ms": lat.get("mean_s", 0) * 1e3,
            "proc_ms": np.mean(res.metrics.latencies(
                "consumed", "processed")) * 1e3,
        })
        mgr.release_all()
    return rows


def run_fused(model_name: str, n_points: int, n_messages: int,
              batch: int = 8):
    """Beyond-paper: one jitted call over `batch` stacked messages."""
    import jax.numpy as jnp
    gen = MiniAppGenerator(n_points=n_points, seed=0)
    msgs = [gen.sample() for _ in range(n_messages)]
    if model_name == "kmeans":
        km = KMeans(n_clusters=25)
        st = km.init(msgs[0])
        fn = lambda x: km.assign(st, x.reshape(-1, 32))
    elif model_name == "autoencoder":
        ae = AutoEncoder()
        st = ae.init()
        fn = lambda x: ae.outlier_scores(st, x.reshape(-1, 32))
    else:
        return None
    stacked = [np.stack(msgs[i:i + batch])
               for i in range(0, n_messages - batch + 1, batch)]
    fn(stacked[0])                                      # compile
    t0 = time.monotonic()
    for s in stacked:
        r = fn(s)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    dt = time.monotonic() - t0
    msgs_done = len(stacked) * batch
    return {"model": f"{model_name}+fused", "n_points": n_points,
            "msgs_per_s": msgs_done / dt,
            "mb_per_s": msgs_done * message_nbytes(n_points) / dt / 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--messages", type=int, default=48)
    ap.add_argument("--points", type=int, nargs="*",
                    default=[250, 2_500, 10_000])
    ap.add_argument("--models", nargs="*",
                    default=["kmeans", "iforest", "autoencoder"])
    ap.add_argument("--fused", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    all_rows = []
    print(f"{'model':>14} {'points':>7} {'msg/s':>9} {'MB/s':>8} "
          f"{'lat ms':>9} {'proc ms':>9}")
    for model in args.models:
        for n_points in args.points:
            n_msgs = args.messages if model != "iforest" else max(
                8, args.messages // 4)       # iforest is slow on CPU
            rows = run_model(model, n_points, n_msgs)
            m = np.mean([r["msgs_per_s"] for r in rows])
            mb = np.mean([r["mb_per_s"] for r in rows])
            lat = np.mean([r["latency_mean_ms"] for r in rows])
            pr = np.mean([r["proc_ms"] for r in rows])
            print(f"{model:>14} {n_points:7d} {m:9.2f} {mb:8.2f} "
                  f"{lat:9.1f} {pr:9.1f}")
            all_rows.extend(rows)
    if args.fused:
        for model in ("kmeans", "autoencoder"):
            for n_points in args.points:
                row = run_fused(model, n_points, args.messages)
                if row:
                    print(f"{row['model']:>14} {n_points:7d} "
                          f"{row['msgs_per_s']:9.2f} "
                          f"{row['mb_per_s']:8.2f}         -         -")
                    all_rows.append(row)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(all_rows, f, indent=1)
    return all_rows


if __name__ == "__main__":
    main()
