"""Geographic distribution (paper Fig 3 right): data source on XSEDE (US),
processing at LRZ (Germany), WAN between them — and the placement engine's
prediction of when that is (not) the bottleneck.

The WAN shaper carries the paper's measured band: 140–160 ms RTT,
60–100 Mbit/s. We run the same k-means workload local vs geo-distributed,
then ask the PlacementEngine to rank edge vs cloud placement for a light
(k-means) and a heavy (auto-encoder) task — reproducing the paper's
conclusion that "the network is not the bottleneck for the compute-
intensive models".

    PYTHONPATH=src python examples/geo_distributed.py
"""
import numpy as np

from repro.core import (ComputeResource, EdgeToCloudPipeline, PilotManager,
                        PlacementEngine, TaskProfile, WanShaper)
from repro.ml import KMeans, MiniAppGenerator, message_nbytes

N_POINTS = 2_500
N_MESSAGES = 64


def run(wan):
    manager = PilotManager()
    pilot_edge = manager.submit_pilot(
        ComputeResource(tier="edge", n_workers=4))
    pilot_cloud = manager.submit_pilot(
        ComputeResource(tier="cloud", n_workers=4))
    gen = MiniAppGenerator(n_points=N_POINTS, seed=11)
    km = KMeans(n_clusters=25)
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=pilot_cloud, pilot_edge=pilot_edge,
        produce_function_handler=gen.make_producer(),
        process_cloud_function_handler=km.make_processor(),
        wan_shaper=wan)
    res = pipe.run(n_messages=N_MESSAGES, timeout_s=300)
    manager.release_all()
    return res


print(f"message size: {message_nbytes(N_POINTS)/1e3:.0f} KB "
      f"({N_POINTS} points x 32 features)\n")

local = run(None)
print(f"local (LRZ only):      {local.throughput()['msgs_per_s']:8.1f} "
      f"msg/s   mean latency {local.latency()['mean_s']*1e3:8.1f} ms")

geo = run(WanShaper(bandwidth_bps=80e6, rtt_s=0.150, sleep=True))
print(f"geo (XSEDE -> LRZ):    {geo.throughput()['msgs_per_s']:8.1f} "
      f"msg/s   mean latency {geo.latency()['mean_s']*1e3:8.1f} ms")
# with sleep=True the WAN delay is spent inside produce(), so the shaped
# transfer shows up in the produced->broker_in hop
wan_hop = geo.per_hop().get("produced->broker_in", {})
print(f"WAN hop latency:       mean {wan_hop.get('mean_s', 0)*1e3:8.1f} ms "
      f"(paper: 140-160 ms RTT + transfer)\n")

# --- placement evaluation (the paper's Fig 3 trade-off as a cost model) ----
manager = PilotManager()
p_edge = manager.submit_pilot(ComputeResource(tier="edge", n_workers=1))
p_cloud = manager.submit_pilot(ComputeResource(tier="cloud", n_workers=8))
engine = PlacementEngine()
msg_bytes = message_nbytes(N_POINTS)

kmeans_task = TaskProfile(flops=2 * N_POINTS * 25 * 32,     # light
                          input_bytes=msg_bytes, input_tier="edge")
ae_task = TaskProfile(flops=6 * 11_552 * N_POINTS * 50,     # heavy (training)
                      input_bytes=msg_bytes, input_tier="edge")

for name, task in [("k-means", kmeans_task), ("auto-encoder", ae_task)]:
    table = engine.compare_tiers(task, [p_edge, p_cloud])
    choice = engine.place(task, [p_edge, p_cloud])
    print(f"{name:13s} est. completion: "
          + "  ".join(f"{t}={v*1e3:.1f}ms" for t, v in sorted(table.items()))
          + f"   -> place on {choice.pilot.tier} "
          f"(transfer {choice.breakdown['t_in']*1e3:.1f}ms, "
          f"compute {choice.breakdown['t_compute']*1e3:.1f}ms)")
print("\nk-means is transfer-bound (geo placement halves throughput); the "
      "heavy model is compute-bound — matching the paper's Fig 3 finding.")
manager.release_all()
