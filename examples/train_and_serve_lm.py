"""End-to-end LM driver: train a transformer with the full substrate stack
(data pipeline → train step → checkpointing), then serve it with batched
requests — the LM-substrate counterpart of the paper's edge-to-cloud flow,
with the ParameterService carrying weights from the trainer to the server
exactly like the paper's Redis parameter server carries model updates.

Defaults are CPU-sized; pass ``--params 100`` for the ~100M-param variant
(same code, longer wall time).

    PYTHONPATH=src python examples/train_and_serve_lm.py
    PYTHONPATH=src python examples/train_and_serve_lm.py --params 100 \
        --steps 300   # ~100M params, few hundred steps
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import ParameterService
from repro.launch.train import train_loop
from repro.models import transformer as T
from repro.serve import BatchServer, Request
from repro.train import step as TS


def sized_config(target_m: float):
    """internlm2-family config scaled to ~target_m million params."""
    base = get_arch("internlm2-1.8b")
    if target_m >= 100:
        # ~103M backbone: 12L x 768, vocab 8k
        return dataclasses.replace(
            base, name=f"internlm2-{target_m:.0f}m", n_layers=12,
            d_model=768, n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048,
            vocab_size=8192, remat=False)
    return dataclasses.replace(
        base, name="internlm2-mini", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, d_head=64, d_ff=512, vocab_size=2048, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", type=float, default=10,
                    help="target size in millions (100 => ~100M)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = sized_config(args.params)
    print(f"config {cfg.name}: {cfg.param_count/1e6:.1f}M params")

    tc = TS.TrainConfig(lr=1e-3, warmup=max(10, args.steps // 10),
                        total_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, state, history = train_loop(
            cfg, tc, steps=args.steps, batch=args.batch, seq_len=args.seq,
            ckpt_dir=ckpt_dir, ckpt_every=max(20, args.steps // 3))
        print(f"train: loss {history[0]['loss']:.3f} -> "
              f"{history[-1]['loss']:.3f}")
        assert history[-1]["loss"] < history[0]["loss"], "loss must fall"

        # --- hand the weights to the server via the parameter service ---
        ps = ParameterService()
        ps.publish("lm", params)
        version, served_params = ps.fetch("lm")
        served_params = jax.tree.map(jnp.asarray, served_params)
        print(f"published weights v{version} to the parameter service")

        server = BatchServer(served_params, cfg, n_slots=4, max_len=256)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            server.submit(Request(
                request_id=f"r{i}",
                prompt=rng.integers(1, cfg.vocab_size, 32).astype(np.int32),
                max_new_tokens=16))
        done = server.run(max_requests=args.requests, idle_timeout_s=1.0)
        n_tok = sum(len(r.result_tokens) for r in done)
        print(f"served {len(done)} requests, {n_tok} tokens "
              f"({server.metrics['decoded_tokens']} batched decode tokens)")


if __name__ == "__main__":
    main()
