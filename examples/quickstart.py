"""Quickstart — the paper's Listings 1 & 2 in ~40 lines.

Acquire an edge pilot and a cloud pilot (step 1), define the three FaaS
functions, instantiate the EdgeToCloudPipeline (step 2), run 128 messages,
and read the linked metrics (step 3).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ComputeResource, EdgeToCloudPipeline, PilotManager
from repro.ml import KMeans, MiniAppGenerator

# --- step 1: acquire pilots (resource management, no workload code) --------
manager = PilotManager()
pilot_edge = manager.submit_pilot(
    ComputeResource(tier="edge", n_workers=4, memory_gb=4))     # RasPi-class
pilot_cloud = manager.submit_pilot(
    ComputeResource(tier="cloud", n_workers=4, memory_gb=44))   # LRZ large VM

# --- FaaS functions (Listing 1) ---------------------------------------------
generator = MiniAppGenerator(n_points=2_500, n_clusters=25, seed=7)
produce_edge = generator.make_producer()            # sensing / data generation


def process_edge(context, data=None):
    """Edge pre-processing: drop non-finite rows before the WAN hop."""
    return data[np.isfinite(data).all(axis=1)]


kmeans = KMeans(n_clusters=25, n_features=32)
process_cloud = kmeans.make_processor(train=True)   # score + update model

# --- step 2: instantiate + run (Listing 2) -----------------------------------
pipeline = EdgeToCloudPipeline(
    pilot_cloud_processing=pilot_cloud,
    pilot_edge=pilot_edge,
    produce_function_handler=produce_edge,
    process_edge_function_handler=process_edge,
    process_cloud_function_handler=process_cloud,
    function_context={"model": "kmeans", "n_clusters": 25},
)
result = pipeline.run(n_messages=128)

# --- step 3: monitoring -------------------------------------------------------
print(f"processed {result.n_processed}/{result.n_produced} messages "
      f"in {result.wall_s:.2f}s")
tp = result.throughput()
print(f"throughput: {tp['msgs_per_s']:.0f} msg/s, "
      f"{tp['bytes_per_s']/1e6:.1f} MB/s")
print(f"end-to-end latency: {result.latency()}")
print("per-hop latency:")
for hop, stats in result.per_hop().items():
    print(f"  {hop:25s} mean {stats['mean_s']*1e3:7.2f} ms")
outliers = sum(r["n_outliers"] for r in result.results)
print(f"outliers flagged across stream: {outliers}")
manager.release_all()
