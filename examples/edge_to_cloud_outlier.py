"""Full Pilot-Edge scenario: three outlier detectors, model hot-swap,
autoscaling, and failure recovery — the paper's §II-D dynamism story.

1. stream k-means over the pipeline (low-fidelity model),
2. hot-swap the cloud function to the auto-encoder at runtime —
   ``replace_function`` re-binds the payload without re-allocating pilots,
3. watch the AutoScaler grow the cloud pilot when the heavier model
   falls behind (broker lag),
4. kill a consumer task mid-stream and observe retry-based recovery.

    PYTHONPATH=src python examples/edge_to_cloud_outlier.py
"""
import threading

import numpy as np

from repro.core import (AutoScaler, ComputeResource, EdgeToCloudPipeline,
                        ParameterService, PilotManager, ScalePolicy)
from repro.ml import AutoEncoder, KMeans, MiniAppGenerator

manager = PilotManager()
pilot_edge = manager.submit_pilot(ComputeResource(tier="edge", n_workers=4))
pilot_cloud = manager.submit_pilot(ComputeResource(tier="cloud",
                                                   n_workers=2))

generator = MiniAppGenerator(n_points=1_000, n_clusters=25, seed=3)
params_service = ParameterService()

kmeans = KMeans(n_clusters=25)
ae = AutoEncoder()
km_processor = kmeans.make_processor(params_service, "kmeans")
ae_processor = ae.make_processor(params_service, "autoencoder")

# inject one transient fault: the 5th message's processing attempt dies once
fault = {"armed": True}
fault_lock = threading.Lock()


def flaky_process(context, data=None):
    with fault_lock:
        if fault["armed"] and context.attempt == 0:
            fault["armed"] = False
            raise RuntimeError("injected consumer fault")
    return km_processor(context, data=data)


pipeline = EdgeToCloudPipeline(
    pilot_cloud_processing=pilot_cloud,
    pilot_edge=pilot_edge,
    produce_function_handler=generator.make_producer(),
    process_cloud_function_handler=flaky_process,
    parameter_service=params_service,
    max_retries=2,
)

# autoscaler: watch broker lag on the pipeline's topic
scaler = AutoScaler(
    manager, pilot_cloud,
    lag_fn=lambda: (pipeline._topic.end_offsets()
                    and sum(pipeline._topic.end_offsets()) or 0)
    - int(pipeline.metrics.counter("runtime.completed")),
    policy=ScalePolicy(max_workers=8, lag_high=16, cooldown_s=0.2),
)

# hot-swap to the auto-encoder after ~1/3 of the stream
def swap_later():
    import time
    time.sleep(0.5)
    pipeline.replace_function("process_cloud", ae_processor)
    print(">> hot-swapped process_cloud: kmeans -> autoencoder "
          "(no pilot re-allocation)")


threading.Thread(target=swap_later, daemon=True).start()
scaler.start()
result = pipeline.run(n_messages=96, timeout_s=120)
scaler.stop()

print(f"\nprocessed {result.n_processed} messages in {result.wall_s:.2f}s "
      f"({result.throughput()['msgs_per_s']:.0f} msg/s)")
print(f"task errors: {result.metrics.counter('runtime.task_errors'):.0f}, "
      f"retries: {result.metrics.counter('runtime.retries'):.0f} "
      f"(the injected fault was retried transparently)")
for ev in result.metrics.events("autoscale"):
    print(f"autoscale event: {ev['from_workers']} -> {ev['to_workers']} "
          f"workers at lag={ev['lag']}")
for ev in result.metrics.events("function_replaced"):
    print(f"function replaced: stage={ev['stage']} fn={ev['fn']}")
print(f"parameter-service versions: "
      f"{ {n: params_service.version(n) for n in params_service.names()} }")
manager.release_all()
