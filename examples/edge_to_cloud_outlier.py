"""Full Pilot-Edge scenario: three outlier detectors, model hot-swap,
autoscaling, and failure recovery — the paper's §II-D dynamism story.

0. ask the DES-backed PlacementAdvisor where this workload should run
   (``pipeline.run(placement='advise')`` emulates the real pipeline
   across placements × WAN bands in a few hundred ms),
1. stream k-means over the pipeline (low-fidelity model) as a *paced*
   live demo: ``ThreadedExecutor(service_model=...)`` charges every stage
   its calibrated continuum service time (scaled by ``PACE`` so the demo
   stays snappy) on real threads,
2. hot-swap the cloud function to the auto-encoder at runtime —
   ``replace_function`` re-binds the payload without re-allocating pilots
   (the pacing follows: the calibrated AE is ~7,500× costlier per point),
3. watch the AutoScaler grow the cloud pilot when the heavier model
   falls behind (broker lag),
4. kill a consumer task mid-stream and observe retry-based recovery.

    PYTHONPATH=src python examples/edge_to_cloud_outlier.py
"""
import threading

import numpy as np

from repro.core import (AutoScaler, ComputeResource, EdgeToCloudPipeline,
                        ParameterService, PilotManager, ScalePolicy,
                        ThreadedExecutor)
from repro.cost import default_cost_model
from repro.ml import AutoEncoder, KMeans, MiniAppGenerator

N_POINTS = 1_000
PACE = 0.02          # play the paper-testbed timeline 50x faster

manager = PilotManager()
pilot_edge = manager.submit_pilot(ComputeResource(tier="edge", n_workers=4))
pilot_cloud = manager.submit_pilot(ComputeResource(tier="cloud",
                                                   n_workers=2))

generator = MiniAppGenerator(n_points=N_POINTS, n_clusters=25, seed=3)
params_service = ParameterService()

kmeans = KMeans(n_clusters=25)
ae = AutoEncoder()
km_processor = kmeans.make_processor(params_service, "kmeans")
ae_processor = ae.make_processor(params_service, "autoencoder")

# inject one transient fault: the 5th message's processing attempt dies once
fault = {"armed": True}
fault_lock = threading.Lock()


def flaky_process(context, data=None):
    with fault_lock:
        if fault["armed"] and context.attempt == 0:
            fault["armed"] = False
            raise RuntimeError("injected consumer fault")
    return km_processor(context, data=data)


pipeline = EdgeToCloudPipeline(
    pilot_cloud_processing=pilot_cloud,
    pilot_edge=pilot_edge,
    produce_function_handler=generator.make_producer(),
    process_cloud_function_handler=flaky_process,
    parameter_service=params_service,
    function_context={"model": "kmeans", "n_points": N_POINTS},
    max_retries=2,
)

# --- step 0: placement advisory (DES on the real pipeline, virtual time) --
report = pipeline.run(placement="advise")
print(report.table())
best = report.best("10mbit")
print(f">> advisor: run {report.model} on the *{best.placement}* tier at "
      f"10 Mbit/s ({best.throughput_msgs_s:.1f} msg/s predicted)\n")

# --- paced live run: calibrated service times on real threads -------------
cost = default_cost_model()
current = {"model": "kmeans"}


def paced_service(stage, ctx, payload):
    """Charge each stage its calibrated continuum cost × PACE (the same
    per-point generation cost the advisor's prediction is priced with)."""
    from repro.cost.calibrate import DEFAULT_GEN_S_PER_POINT
    if stage == "produce":
        return PACE * DEFAULT_GEN_S_PER_POINT * N_POINTS
    if stage == "process_cloud":
        return PACE * cost.model_compute_s(current["model"], N_POINTS,
                                           "cloud")
    return 0.0


# autoscaler: watch broker lag on the pipeline's topic
scaler = AutoScaler(
    manager, pilot_cloud,
    lag_fn=lambda: (pipeline._topic.end_offsets()
                    and sum(pipeline._topic.end_offsets()) or 0)
    - int(pipeline.metrics.counter("runtime.completed")),
    policy=ScalePolicy(max_workers=8, lag_high=16, cooldown_s=0.2),
)

# hot-swap to the auto-encoder after ~1/3 of the stream
def swap_later():
    import time
    time.sleep(0.5)
    current["model"] = "autoencoder"     # re-pace *before* the swap lands
    pipeline.replace_function("process_cloud", ae_processor)
    print(">> hot-swapped process_cloud: kmeans -> autoencoder "
          "(no pilot re-allocation)")


threading.Thread(target=swap_later, daemon=True).start()
scaler.start()
result = pipeline.run(n_messages=96, timeout_s=120,
                      scheduler=ThreadedExecutor(
                          service_model=paced_service))
scaler.stop()

print(f"\nprocessed {result.n_processed} messages in {result.wall_s:.2f}s "
      f"({result.throughput()['msgs_per_s']:.0f} msg/s)")
print(f"task errors: {result.metrics.counter('runtime.task_errors'):.0f}, "
      f"retries: {result.metrics.counter('runtime.retries'):.0f} "
      f"(the injected fault was retried transparently)")
for ev in result.metrics.events("autoscale"):
    print(f"autoscale event: {ev['from_workers']} -> {ev['to_workers']} "
          f"workers at lag={ev['lag']}")
for ev in result.metrics.events("function_replaced"):
    print(f"function replaced: stage={ev['stage']} fn={ev['fn']}")
print(f"parameter-service versions: "
      f"{ {n: params_service.version(n) for n in params_service.names()} }")
manager.release_all()
