#!/usr/bin/env python
"""Validate a BENCH_*.json file against its committed schema — stdlib only
(the CI image has no jsonschema package), supporting the subset the
benchmarks' schemas use: type (including union lists like
["integer", "null"]) / required / properties / additionalProperties /
enum / minimum / exclusiveMinimum / items / minItems-maxItems (the
per-stage tier-vector column).

Usage::

    python tools/check_bench_schema.py BENCH_sim.json \\
        benchmarks/BENCH_sim.schema.json
"""
from __future__ import annotations

import json
import sys

_TYPES = {"object": dict, "array": list, "string": str,
          "integer": int, "number": (int, float), "boolean": bool,
          "null": type(None)}


def _matches_type(value, t):
    if not isinstance(value, _TYPES[t]):
        return False
    if t in ("integer", "number") and isinstance(value, bool):
        return False
    return True


def _check(value, schema, path, errors):
    t = schema.get("type")
    if t is not None:
        # JSON Schema allows a union of types, e.g. ["integer", "null"]
        types = t if isinstance(t, list) else [t]
        if not any(_matches_type(value, x) for x in types):
            errors.append(f"{path}: expected {t}, got "
                          f"{type(value).__name__} ({value!r})")
            return
    if "enum" in schema and value not in schema["enum"]:
        errors.append(f"{path}: {value!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(value, (int, float)) \
            and value < schema["minimum"]:
        errors.append(f"{path}: {value!r} < minimum {schema['minimum']}")
    if "exclusiveMinimum" in schema and isinstance(value, (int, float)) \
            and value <= schema["exclusiveMinimum"]:
        errors.append(f"{path}: {value!r} <= exclusiveMinimum "
                      f"{schema['exclusiveMinimum']}")
    if isinstance(value, dict):
        props = schema.get("properties", {})
        for req in schema.get("required", ()):
            if req not in value:
                errors.append(f"{path}: missing required key {req!r}")
        if schema.get("additionalProperties") is False:
            for k in value:
                if k not in props:
                    errors.append(f"{path}: unexpected key {k!r}")
        for k, sub in props.items():
            if k in value:
                _check(value[k], sub, f"{path}.{k}", errors)
    if isinstance(value, list):
        if "minItems" in schema and len(value) < schema["minItems"]:
            errors.append(f"{path}: {len(value)} item(s) < minItems "
                          f"{schema['minItems']}")
        if "maxItems" in schema and len(value) > schema["maxItems"]:
            errors.append(f"{path}: {len(value)} item(s) > maxItems "
                          f"{schema['maxItems']}")
        if "items" in schema:
            for i, item in enumerate(value):
                _check(item, schema["items"], f"{path}[{i}]", errors)


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        data = json.load(f)
    with open(argv[2]) as f:
        schema = json.load(f)
    errors: list = []
    _check(data, schema, "$", errors)
    for e in errors[:50]:
        print(f"schema violation: {e}")
    if errors:
        print(f"\nFAIL: {argv[1]} does not match {argv[2]} "
              f"({len(errors)} violation(s))")
        return 1
    n = len(data) if isinstance(data, list) else 1
    print(f"OK: {argv[1]} matches {argv[2]} ({n} row(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
