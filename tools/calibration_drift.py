#!/usr/bin/env python
"""Calibration-drift report: re-measure the kernels on *this* host —
roofline HLO flops plus a live efficiency/sigma service refit — and
compare against the committed ``calibration.json``.

The committed calibration pins the paper-testbed service fit so every
consumer stays deterministic; this tool answers "how far has this
container drifted from it": the achieved-fraction-of-peak (efficiency)
and lognormal service-noise sigma refit live, next to the committed
values, as a JSON artifact CI uploads on every slow-lane run (the
ROADMAP's calibration-drift follow-up).

Usage::

    PYTHONPATH=src python tools/calibration_drift.py \\
        --messages 5 --out CALIBRATION_drift.json

Exit code is 0 unless ``--max-kernel-drift R`` is given and a kernel's
re-measured HLO flops/point drifts beyond a factor of R from the
committed value (jax/XLA version drift changes fusion decisions, not
orders of magnitude — the service fit is expected to drift and is never
gated).
"""
from __future__ import annotations

import argparse
import json
import sys


def _enable_compilation_cache() -> None:
    """Mirror tests/conftest.py: persist XLA compiles under .jax_cache so
    CI's restored cache actually shortens the kernel measurements."""
    import os

    import jax
    try:
        cache_dir = os.environ.get(
            "JAX_COMPILATION_CACHE_DIR",
            os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    except Exception:
        pass                     # older jax without the cache: run without


def drift_report(models=None, n_messages: int = 5, tier: str = "cloud"):
    """Refit each model live and pair the numbers with the committed
    calibration.  Returns ``{"meta": ..., "models": [row, ...]}``."""
    _enable_compilation_cache()
    from repro.cost.calibrate import Calibrator, load_calibration
    committed = load_calibration()
    cal = Calibrator()
    rows = []
    for name in models or sorted(committed):
        c = committed[name]
        kf, kb = cal.measure_kernel(name)
        eff, sigma = cal.measure_service(
            name, n_messages=n_messages, tier=tier,
            kernel_flops_per_point=kf)
        rows.append({
            "model": name,
            "kernel_flops_per_point": round(kf, 3),
            "committed_kernel_flops_per_point": c.kernel_flops_per_point,
            "kernel_flops_ratio": kf / c.kernel_flops_per_point,
            "kernel_bytes_per_point": round(kb, 3),
            "achieved_fraction_of_peak": eff,
            "committed_efficiency": c.efficiency,
            "efficiency_ratio": eff / c.efficiency,
            "sigma": sigma,
            "committed_sigma": c.sigma,
        })
    import jax
    return {
        "meta": {"n_messages": n_messages, "tier": tier,
                 "jax_version": jax.__version__,
                 "generated_by": "tools/calibration_drift.py"},
        "models": rows,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write the drift report as JSON")
    ap.add_argument("--messages", type=int, default=5,
                    help="live service samples per model")
    ap.add_argument("--models", nargs="+", default=None,
                    help="restrict to these calibrated models")
    ap.add_argument("--tier", default="cloud",
                    help="tier whose peak rate the efficiency is "
                         "measured against")
    ap.add_argument("--max-kernel-drift", type=float, default=None,
                    help="fail (exit 1) if any kernel's re-measured HLO "
                         "flops drift beyond this factor of the "
                         "committed value")
    args = ap.parse_args(argv)

    report = drift_report(models=args.models, n_messages=args.messages,
                          tier=args.tier)
    hdr = (f"{'model':>12} {'flops/pt':>12} {'committed':>12} "
           f"{'ratio':>6} {'eff':>8} {'committed':>9} {'sigma':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in report["models"]:
        print(f"{r['model']:>12} {r['kernel_flops_per_point']:>12.1f} "
              f"{r['committed_kernel_flops_per_point']:>12.1f} "
              f"{r['kernel_flops_ratio']:>6.2f} "
              f"{r['achieved_fraction_of_peak']:>8.5f} "
              f"{r['committed_efficiency']:>9.3f} {r['sigma']:>7.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, default=float)
            f.write("\n")
        print(f"wrote {args.out}")

    if args.max_kernel_drift is not None:
        bad = [r for r in report["models"]
               if not (1.0 / args.max_kernel_drift
                       <= r["kernel_flops_ratio"]
                       <= args.max_kernel_drift)]
        if bad:
            for r in bad:
                print(f"KERNEL DRIFT: {r['model']} flops ratio "
                      f"{r['kernel_flops_ratio']:.2f} exceeds factor "
                      f"{args.max_kernel_drift}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
