#!/usr/bin/env python
"""Fail CI when tests skip because a dependency is missing.

Reads a ``pytest -rs`` log (file argument or stdin) and scans the short
test summary's SKIPPED lines. Skips caused by a *missing dependency*
(``importorskip`` — e.g. hypothesis absent from the image, the failure
mode ROADMAP flags) fail the job; intentional skips (platform guards,
explicit markers) pass through.

Local vs CI behaviour: the dev container image is known to lack
``hypothesis`` (it is in ``requirements-test.txt`` and installed in CI),
so *known image gaps* are downgraded to loud-but-passing warnings when
run outside CI. In CI (the ``CI`` env var is set, as on GitHub Actions)
or with ``--strict`` every missing-dependency skip fails, keeping the
gap visible where it must be fixed. ``--warn-only`` downgrades
everything (exit 0) for exploratory local runs.

Usage::

    PYTHONPATH=src python -m pytest -rs -q | tee pytest.log
    python tools/check_skips.py pytest.log
    python tools/check_skips.py --strict pytest.log      # force CI mode
    python tools/check_skips.py --warn-only pytest.log   # never fail
"""
from __future__ import annotations

import os
import re
import sys

# importorskip / missing-module phrasings across pytest versions
MISSING_DEP = re.compile(
    r"could not import|No module named|not installed|"
    r"unable to import|requires the .* package", re.IGNORECASE)

# dependencies knowingly absent from the dev container image but present
# in CI (requirements-test.txt): visible locally as warnings, enforced in
# CI as failures. Matched against the *import-error clause* (the exact
# module name next to it), never the whole line, so neither a path that
# contains the word nor a package that merely starts with it
# (hypothesis_jsonschema) can mask a genuinely new missing dependency.
KNOWN_IMAGE_GAPS = ("hypothesis",)

_GAP = (r"['\"]?(?:" + "|".join(re.escape(d) for d in KNOWN_IMAGE_GAPS)
        + r")(?![\w.])['\"]?")
_KNOWN_GAP_RE = re.compile(
    r"(?:could not import|No module named|unable to import)\s*:?\s*"
    + _GAP
    + r"|" + _GAP + r"\s+(?:is\s+)?not installed"
    + r"|requires the\s+" + _GAP + r"\s+package", re.IGNORECASE)

SKIP_LINE = re.compile(r"^SKIPPED\s*(\[\d+\])?\s*(?P<rest>.*)$")


def check(lines, *, strict: bool = True, warn_only: bool = False) -> int:
    bad, known, intentional = [], [], []
    for line in lines:
        m = SKIP_LINE.match(line.strip())
        if not m:
            continue
        rest = m.group("rest")
        if not MISSING_DEP.search(rest):
            intentional.append(rest)
        elif not strict and _KNOWN_GAP_RE.search(rest):
            known.append(rest)
        else:
            bad.append(rest)
    for s in intentional:
        print(f"skip (intentional): {s}")
    for s in known:
        print(f"skip (known image gap — CI installs it and enforces): {s}")
    for s in bad:
        print(f"skip (MISSING DEPENDENCY): {s}")
    if bad:
        print(f"\n{'WARN' if warn_only else 'FAIL'}: {len(bad)} test(s) "
              f"skipped because a dependency is missing — install it in "
              f"the CI image (see requirements-test.txt).")
        return 0 if warn_only else 1
    print(f"OK: {len(intentional)} intentional skip(s), "
          f"{len(known)} known image-gap skip(s), "
          f"no enforced missing-dependency skips.")
    return 0


def main(argv) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("log", nargs="?", default=None,
                    help="pytest -rs log file (default: stdin)")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--strict", action="store_true",
                      help="fail on every missing-dependency skip, "
                           "including known image gaps (the CI default)")
    mode.add_argument("--warn-only", action="store_true",
                      help="report but never fail (exploratory runs)")
    args = ap.parse_args(argv[1:])
    # truthy CI only: CI=false / CI=0 (common opt-outs) stay local mode
    in_ci = os.environ.get("CI", "").lower() in ("1", "true", "yes")
    strict = args.strict or (not args.warn_only and in_ci)
    if args.log:
        with open(args.log) as f:
            return check(f, strict=strict, warn_only=args.warn_only)
    return check(sys.stdin, strict=strict, warn_only=args.warn_only)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
