#!/usr/bin/env python
"""Fail CI when tests skip because a dependency is missing.

Reads a ``pytest -rs`` log (file argument or stdin) and scans the short
test summary's SKIPPED lines. Skips caused by a *missing dependency*
(``importorskip`` — e.g. hypothesis absent from the image, the failure
mode ROADMAP flags) fail the job; intentional skips (platform guards,
explicit markers) pass through.

Usage::

    PYTHONPATH=src python -m pytest -rs -q | tee pytest.log
    python tools/check_skips.py pytest.log
"""
from __future__ import annotations

import re
import sys

# importorskip / missing-module phrasings across pytest versions
MISSING_DEP = re.compile(
    r"could not import|No module named|not installed|"
    r"unable to import|requires the .* package", re.IGNORECASE)

SKIP_LINE = re.compile(r"^SKIPPED\s*(\[\d+\])?\s*(?P<rest>.*)$")


def check(lines) -> int:
    bad, intentional = [], []
    for line in lines:
        m = SKIP_LINE.match(line.strip())
        if not m:
            continue
        rest = m.group("rest")
        (bad if MISSING_DEP.search(rest) else intentional).append(rest)
    for s in intentional:
        print(f"skip (intentional): {s}")
    for s in bad:
        print(f"skip (MISSING DEPENDENCY): {s}")
    if bad:
        print(f"\nFAIL: {len(bad)} test(s) skipped because a dependency "
              f"is missing — install it in the CI image "
              f"(see requirements-test.txt).")
        return 1
    print(f"OK: {len(intentional)} intentional skip(s), "
          f"no missing-dependency skips.")
    return 0


def main(argv) -> int:
    if len(argv) > 1:
        with open(argv[1]) as f:
            return check(f)
    return check(sys.stdin)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
