"""Chaos suite: mid-run drift events, the watching ReAdvisor, and live
placement hot-swap.

Every drift kind (band / churn / outage) is scheduled as an ordinary DES
event, so drifted runs stay bit-identical — the per-kind goldens here
pin that three sweeps deep.  The band-drop golden is the headline
(benchmarks/bench_drift.py runs the same cell): a cloud placement's WAN
degrades 100→10 Mbit/s at t=8 s, the ReAdvisor notices the observed hop
delay blow past its prediction and hot-swaps the processing stage
cloud→fog (``rebind_stage`` + epoch consumer migration), and the
end-to-end p95 beats the static run — with identical swap timestamps
under shard counts 1 and 2.  The chaos matrix crosses each drift kind
with crash/silent consumer failures under straggler speculation and
holds the exactly-once and speculation-accounting invariants.
"""
import time
from dataclasses import replace
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (ComputeResource, ContinuumPipeline, PilotManager,
                        StageSpec, ThreadedExecutor)
from repro.cost.advisor import PlacementAdvisor
from repro.cost.model import default_cost_model
from repro.cost.readvisor import ReAdvisor, ReAdviseSpec
from repro.sim.scenarios import (DriftSpec, FailureSpec, Scenario,
                                 run_scenario)
from repro.sim.shard import DRIFT_PARITY_COLS, run_drift_sharded

# ---------------------------------------------------------------------------
# the band-drop golden (same cell bench_drift.py reports)
# ---------------------------------------------------------------------------

GOLDEN = Scenario(
    placement="cloud", wan_band="100mbit", n_messages=60, n_points=25_000,
    gen_s_per_point=1.28e-4, seed=3, speculative_factor=2.0,
    drift=(DriftSpec(at_s=8.0, kind="band", band="10mbit"),),
    readvise=ReAdviseSpec(interval_s=2.0, min_samples=2, hysteresis=3.0),
)


def test_band_drop_golden_hot_swap_beats_static():
    static = run_scenario(replace(GOLDEN, readvise=None))
    res = run_scenario(GOLDEN)

    # the drift landed in both runs, as a scheduled event
    assert static.metrics.events("drift_band")
    assert res.metrics.events("drift_band")
    assert static.drift_events == res.drift_events == 1

    # the static run rides out the degraded band; the re-advised run
    # hot-swaps cloud→fog and recovers the tail
    assert static.swaps == []
    assert len(res.swaps) == 1
    swap = res.swaps[0]
    assert swap["stage"] == "process_cloud"
    assert (swap["from"], swap["to"]) == ("cloud", "fog")
    assert swap["t_decided"] > 8.0            # after the drift, not before
    assert swap["t_applied"] == pytest.approx(
        swap["t_decided"] + GOLDEN.readvise.apply_delay_s)
    assert res.tiers[-1] == "fog"
    assert static.tiers[-1] == "cloud"
    assert res.latency_p95_s < static.latency_p95_s
    assert res.makespan_s < static.makespan_s

    # the full decision→rebind→migrate chain is observable
    assert res.metrics.events("readvise_decision")
    assert res.metrics.events("stage_rebound")
    assert res.metrics.events("consumer_drained")

    # exactly-once across the migration: the epoch hand-off re-delivers
    # through the at-least-once path and dedup keeps the output unique
    assert res.n_processed == GOLDEN.n_messages
    assert res.n_duplicates == 0


def test_band_drop_golden_bit_identical():
    rows = [run_scenario(GOLDEN).row() for _ in range(3)]
    # swap timestamps and speculation counters included
    assert rows[0] == rows[1] == rows[2]
    assert rows[0]["swaps"][0]["t_decided"] == rows[0]["swaps"][0]["t_decided"]


def test_band_drop_golden_shard_parity():
    base = run_drift_sharded(GOLDEN, shards=1)
    cut = run_drift_sharded(GOLDEN, shards=2, mode="inline")
    for col in DRIFT_PARITY_COLS:
        assert cut[col] == base[col], (
            f"{col} drifts across the tier cut: {cut[col]!r} "
            f"!= {base[col]!r}")
    assert base["swaps"] and base["swaps"][0]["to"] == "fog"
    assert cut["windows"] > 1           # conservative sync actually ran


def test_band_drop_golden_shard_mp_matches_inline():
    a = run_drift_sharded(GOLDEN, shards=2, mode="inline")
    b = run_drift_sharded(GOLDEN, shards=2, mode="mp")
    for col in DRIFT_PARITY_COLS:
        assert a[col] == b[col]


def test_drift_sharding_refuses_unshardable_cells():
    with pytest.raises(ValueError):
        run_drift_sharded(GOLDEN, shards=4)
    with pytest.raises(ValueError):
        run_drift_sharded(replace(GOLDEN, placement="fog"))
    with pytest.raises(ValueError):    # churn mutates the consumer fleet
        run_drift_sharded(replace(
            GOLDEN, drift=(DriftSpec(at_s=1.0, kind="churn", delta=-1),)))
    with pytest.raises(ValueError):    # failures act across the cut
        run_drift_sharded(replace(
            GOLDEN, failures=(FailureSpec(at_s=1.0, consumer_idx=0),)))


# ---------------------------------------------------------------------------
# hysteresis: within tolerance the advisor stays put
# ---------------------------------------------------------------------------

def test_quiet_run_never_swaps():
    # same watched run, no drift: the healthy band keeps the observed
    # hop within hysteresis of the prediction, so no decision ever fires
    res = run_scenario(replace(GOLDEN, drift=()))
    assert res.swaps == []
    assert not res.metrics.events("readvise_decision")
    assert res.tiers[-1] == "cloud"
    assert res.n_processed == GOLDEN.n_messages


class _FakeMetrics:
    """counter()-compatible stand-in for a broker topic's produce
    counters, advanced by hand between ticks."""

    def __init__(self):
        self.c = {"topic.t.msgs_in": 0.0, "topic.t.wan_delay_s": 0.0,
                  "topic.t.bytes_in": 0.0}

    def counter(self, name):
        return self.c[name]

    def push(self, msgs, mean_delay, mean_bytes):
        self.c["topic.t.msgs_in"] += msgs
        self.c["topic.t.wan_delay_s"] += msgs * mean_delay
        self.c["topic.t.bytes_in"] += msgs * mean_bytes


def _readvisor(**kw):
    pilot = lambda n: SimpleNamespace(resource=SimpleNamespace(n_workers=n))
    kw.setdefault("targets", {"cloud": pilot(4), "fog": pilot(4)})
    kw.setdefault("flops", 1e9)
    rv = ReAdvisor(default_cost_model().with_wan("100mbit"),
                   stage="process_cloud", **kw)
    rv.begin(0.0)
    return rv


def test_readvisor_hysteresis_and_min_samples():
    rv = _readvisor(hysteresis=3.0, min_samples=8, interval_s=1.0)
    m = _FakeMetrics()
    step = lambda t: rv.step(now=t, metrics=m, topic="t",
                             current_tier="cloud", src_tier="edge")

    # too few samples in the window: abstain, whatever the delay says
    m.push(4, 100.0, 6.4e6)
    assert step(1.0) is None
    # healthy window (observed ≈ predicted): within hysteresis, stay put
    m.push(10, 0.6, 6.4e6)
    assert step(2.0) is None
    # degraded window: observed hop dwarfs the fog score → swap decision
    m.push(10, 30.0, 6.4e6)
    dec = step(3.0)
    assert dec is not None
    assert (dec.from_tier, dec.to_tier) == ("cloud", "fog")
    assert dec.scores["cloud"] > 3.0 * dec.scores["fog"]
    # the budget is spent at decision time: the next degraded window
    # cannot emit a duplicate while the first swap is still in flight
    m.push(10, 30.0, 6.4e6)
    assert step(4.0) is None


def test_readvisor_validates_knobs():
    with pytest.raises(ValueError):
        _readvisor(hysteresis=0.5)
    with pytest.raises(ValueError):
        _readvisor(targets={})


def test_threaded_executor_readvises_live():
    """The wall-clock path: a daemon monitor thread ticks the ReAdvisor,
    re-binds the watched stage mid-run and spawns a replacement fleet —
    the run still delivers every result exactly once."""
    mgr = PilotManager(devices=())
    dev = mgr.submit_pilot(ComputeResource(tier="device", n_workers=2))
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    fog = mgr.submit_pilot(ComputeResource(tier="fog", n_workers=2))

    def process(ctx, data=None):
        time.sleep(0.02)               # keep the run alive past a tick
        return float(np.sum(data))

    pipe = ContinuumPipeline(stages=[
        StageSpec("sense", lambda ctx: np.arange(64, dtype=np.float64),
                  pilot=dev),
        StageSpec("process", process, pilot=edge),
    ])
    # 1e12 flops price edge at ~100 s vs fog at ~25 s per message — the
    # ranking favours fog by 4x, far past hysteresis, so the first tick
    # that observes any traffic decides the swap
    rv = ReAdvisor(default_cost_model(), stage="process", flops=1e12,
                   targets={"edge": edge, "fog": fog},
                   interval_s=0.05, hysteresis=2.0, min_samples=1,
                   cooldown_s=0.0, max_swaps=1, apply_delay_s=0.0)
    res = pipe.run(n_messages=24, timeout_s=60.0,
                   scheduler=ThreadedExecutor(), readvise=rv)
    assert res.n_processed == 24
    assert res.results == [float(np.sum(np.arange(64.0)))] * 24
    assert rv.swap_log
    assert rv.swap_log[0]["from"] == "edge"
    assert rv.swap_log[0]["to"] == "fog"
    assert pipe.stages[1].pilot.tier == "fog"
    assert res.metrics.events("stage_rebound")
    mgr.release_all()


# ---------------------------------------------------------------------------
# per-kind drift goldens: every kind is an ordinary, reproducible event
# ---------------------------------------------------------------------------

_BASE = dict(placement="cloud", wan_band="100mbit", n_messages=48, seed=1)

_KIND_DRIFTS = {
    "band": DriftSpec(at_s=0.05, kind="band", band="10mbit",
                      restore_after_s=0.1),
    "churn": DriftSpec(at_s=0.05, kind="churn", delta=-2,
                       restore_after_s=0.1),
    "outage": DriftSpec(at_s=0.05, kind="outage", tier="cloud",
                        restore_after_s=0.1),
}


@pytest.mark.parametrize("kind", sorted(_KIND_DRIFTS))
def test_drift_kind_golden_bit_identical(kind):
    sc = Scenario(drift=(_KIND_DRIFTS[kind],), **_BASE)
    runs = [run_scenario(sc) for _ in range(3)]
    rows = [r.row() for r in runs]
    assert rows[0] == rows[1] == rows[2]
    res = runs[0]
    assert res.metrics.events(f"drift_{kind}")
    assert res.metrics.events(f"drift_{kind}_restored")
    # the drift perturbs but never loses work
    assert res.n_processed == _BASE["n_messages"]


def test_drift_band_restore_reprices_back():
    # a band dip with a restore: slower than the clean run while degraded,
    # but it completes, and both shaper events are on record
    clean = run_scenario(Scenario(**_BASE))
    dipped = run_scenario(Scenario(drift=(_KIND_DRIFTS["band"],), **_BASE))
    assert dipped.metrics.events("drift_band")
    assert dipped.metrics.events("drift_band_restored")
    assert dipped.n_processed == clean.n_processed
    assert dipped.makespan_s >= clean.makespan_s


def test_drift_outage_loses_then_respawns_consumers():
    res = run_scenario(Scenario(drift=(_KIND_DRIFTS["outage"],), **_BASE))
    ev = res.metrics.events("drift_outage")
    assert ev and ev[0]["tier"] == "cloud"
    assert res.metrics.events("drift_outage_restored")
    assert res.n_processed == _BASE["n_messages"]


def test_drift_validation():
    with pytest.raises(ValueError):   # unknown band name
        run_scenario(Scenario(
            drift=(DriftSpec(at_s=0.1, kind="band", band="3mbit"),),
            **_BASE))
    with pytest.raises(ValueError):   # unknown band table
        run_scenario(Scenario(
            drift=(DriftSpec(at_s=0.1, kind="band", band="10mbit",
                             table="lan"),),
            **_BASE))


# ---------------------------------------------------------------------------
# chaos matrix: drift × consumer failure × speculation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fkind", ["crash", "silent"])
@pytest.mark.parametrize("dkind", sorted(_KIND_DRIFTS))
def test_chaos_matrix_exactly_once_and_spec_accounting(dkind, fkind):
    sc = Scenario(
        drift=(_KIND_DRIFTS[dkind],),
        failures=(FailureSpec(at_s=0.2, consumer_idx=0,
                              restart_after_s=0.3, kind=fkind),),
        speculative_factor=2.0, **_BASE)
    res = run_scenario(sc)
    # exactly-once output survives drift + failure + speculation at once
    assert res.n_processed == _BASE["n_messages"]
    # every speculative launch is accounted for — no leaked races
    assert (res.spec_wins + res.spec_losses + res.spec_cancelled
            == res.spec_launches)
    # and the whole chaos cell is still deterministic
    assert run_scenario(sc).row() == res.row()


# ---------------------------------------------------------------------------
# advisor metro-band sweep (the static advisory's fog-hop knob)
# ---------------------------------------------------------------------------

def test_advisor_metro_band_sweep_varies_fog_cells():
    adv = PlacementAdvisor(n_messages=8, service_sigma=0.0)
    rep = adv.advise("kmeans", placements=("fog", "cloud"),
                     bands=("10mbit",),
                     metro_bands=("10mbit", "100mbit"))
    fog = [c for c in rep.cells if c.placement == "fog"]
    cloud = [c for c in rep.cells if c.placement == "cloud"]
    assert sorted(c.metro_band for c in fog) == ["100mbit", "10mbit"]
    assert len(set(c.latency_p95_s for c in fog)) == 2   # the hop matters
    assert all(c.metro_band is None for c in cloud)      # no metro hop
    assert all(c.row()["metro"] == c.metro_band for c in rep.cells)


def test_advisor_metro_band_sweep_validates_names():
    adv = PlacementAdvisor(n_messages=8)
    with pytest.raises(ValueError):
        adv.advise("kmeans", placements=("fog",), bands=("10mbit",),
                   metro_bands=("900mbit",))
