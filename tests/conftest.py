"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests must see the real single CPU device (the 512-device override belongs
exclusively to launch/dryrun.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
