"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests must see the real single CPU device (the 512-device override belongs
exclusively to launch/dryrun.py).

The jit-heavy tests dominate tier-1 wall time, so a persistent XLA
compilation cache is enabled (keyed by HLO hash; disable with
REPRO_NO_JAX_CACHE=1).  First runs pay full compile cost; reruns and CI
with a restored cache directory get the compile time back.
"""
import os

import numpy as np
import pytest


def _enable_jax_compilation_cache():
    if os.environ.get("REPRO_NO_JAX_CACHE"):
        return
    try:
        import jax
        cache_dir = os.environ.get(
            "JAX_COMPILATION_CACHE_DIR",
            os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    except Exception:
        pass                     # older jax without the cache: run without


_enable_jax_compilation_cache()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
