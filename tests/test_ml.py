"""Paper ML workload tests: detector quality on labeled synthetic data,
the paper's exact AE topology, streaming-update convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ml import AutoEncoder, IsolationForest, KMeans, MiniAppGenerator
from repro.ml.autoencoder import ae_param_count
from repro.ml.datagen import PAPER_POINTS, message_nbytes


def test_message_sizes_match_paper():
    """25–10,000 points x 32 feat = 7 KB–2.6 MB (paper §III.1)."""
    assert abs(message_nbytes(25) - 6_400) < 1_000
    assert abs(message_nbytes(10_000) - 2_560_000) < 10_000
    assert PAPER_POINTS == (25, 250, 2_500, 10_000)


def test_generator_determinism_and_outlier_frac():
    g1 = MiniAppGenerator(n_points=1000, seed=5)
    g2 = MiniAppGenerator(n_points=1000, seed=5)
    np.testing.assert_array_equal(g1.sample(), g2.sample())
    pts, is_out = MiniAppGenerator(n_points=5000, outlier_frac=0.02,
                                   seed=1).sample_with_labels()
    assert 0.01 <= is_out.mean() <= 0.03


def test_ae_param_count_is_papers_11552():
    ae = AutoEncoder()
    assert ae_param_count(ae.init()["params"]) == 11_552


def test_ae_learns_and_detects():
    gen = MiniAppGenerator(n_points=2000, outlier_frac=0.02, seed=2)
    pts, is_out = gen.sample_with_labels()
    ae = AutoEncoder()
    st = ae.init()
    losses = []
    for _ in range(40):
        st, loss = ae.update(st, pts)
        losses.append(loss)
    assert losses[-1] < losses[0] * 0.9
    s = np.asarray(ae.outlier_scores(st, pts))
    pred = s > s.mean() + 2 * s.std()
    tp = (pred & is_out).sum()
    assert tp / max(pred.sum(), 1) > 0.8          # precision
    assert tp / max(is_out.sum(), 1) > 0.5        # recall


def test_kmeans_converges_and_detects():
    gen = MiniAppGenerator(n_points=2500, outlier_frac=0.02, seed=1)
    pts, is_out = gen.sample_with_labels()
    km = KMeans(n_clusters=25)
    st = km.init(pts)
    inert = [km.inertia(st, pts)]
    for _ in range(10):
        st = km.update(st, pts)
        inert.append(km.inertia(st, pts))
    assert inert[-1] < inert[0]
    s = np.asarray(km.outlier_scores(st, pts))
    pred = s > s.mean() + 3 * s.std()
    assert (pred & is_out).sum() / max(pred.sum(), 1) > 0.9


def test_kmeans_pallas_impl_matches():
    gen = MiniAppGenerator(n_points=500, seed=3)
    pts = gen.sample()
    km_j = KMeans(n_clusters=25, impl="jnp")
    km_p = KMeans(n_clusters=25, impl="pallas")
    st = km_j.init(pts)
    ids_j, d_j = km_j.assign(st, pts)
    ids_p, d_p = km_p.assign(st, pts)
    np.testing.assert_array_equal(np.asarray(ids_j), np.asarray(ids_p))
    # the ||x||^2-2xc+||c||^2 expansion cancels catastrophically at d~0
    # (init seeds centroids FROM sample points): absolute error floor is
    # sqrt(eps*||x||^2) ~ 0.05 for ||x||^2 ~ 2e4, regardless of impl.
    np.testing.assert_allclose(np.asarray(d_j), np.asarray(d_p),
                               atol=0.05, rtol=1e-3)


@pytest.mark.slow
def test_isoforest_separates_outliers():
    gen = MiniAppGenerator(n_points=1500, outlier_frac=0.03, seed=4)
    pts, is_out = gen.sample_with_labels()
    f = IsolationForest(n_trees=50)
    st = f.fit(pts)
    s = np.asarray(f.outlier_scores(st, pts))
    # outliers must score strictly higher on average
    assert s[is_out].mean() > s[~is_out].mean() + 0.05
    # AUC-ish check via rank statistics
    order = np.argsort(s)
    ranks = np.empty_like(order, float)
    ranks[order] = np.arange(len(s))
    auc = (ranks[is_out].mean() - ranks.mean()) / len(s) + 0.5
    assert auc > 0.85


def test_processors_share_via_param_service():
    from repro.core import ParameterService
    ps = ParameterService()
    km = KMeans(n_clusters=5, n_features=4)
    gen = MiniAppGenerator(n_points=200, n_features=4, n_clusters=5,
                           seed=0)

    class Ctx:
        attempt = 0

    proc_a = km.make_processor(ps, "m")
    proc_a(Ctx(), data=gen.sample())
    assert ps.version("m") == 1
    # a second (fresh) processor picks up the published model
    proc_b = km.make_processor(ps, "m", train=False)
    out = proc_b(Ctx(), data=gen.sample())
    assert "n_outliers" in out
    assert ps.version("m") == 1     # train=False published nothing


def test_kmeans_update_threads_impl_to_fused_kernel(monkeypatch):
    """Satellite bugfix regression: KMeans(impl='pallas').update() must
    reach the fused Pallas kernel — historically _update re-ran _assign
    with the *default* impl, silently bypassing it."""
    import repro.kernels.ops as kops
    from repro.ml import kmeans as mlk
    calls = []
    real = kops.kmeans_assign_update

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(kops, "kmeans_assign_update", counting)
    jax.clear_caches()                 # force a retrace through the spy
    gen = MiniAppGenerator(n_points=300, seed=5)
    pts = gen.sample()
    km = KMeans(n_clusters=10, impl="pallas")
    st = km.init(pts)
    st = km.update(st, pts)
    assert calls, "update() never reached the fused Pallas kernel"
    assert st["counts"].sum() == 300


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_kmeans_precision_variant_still_converges(precision):
    """Reduced-precision streaming k-means still drives inertia down to
    fp32-comparable clustering quality (individual centroids may settle
    in different basins after a boundary flip — quality, not bitwise
    trajectory, is the contract)."""
    gen = MiniAppGenerator(n_points=1000, seed=6)
    pts = gen.sample()
    km = KMeans(n_clusters=25, precision=precision)
    ref = KMeans(n_clusters=25)
    st, st_ref = km.init(pts), ref.init(pts)
    inert0 = km.inertia(st, pts)
    for _ in range(5):
        st = km.update(st, pts)
        st_ref = ref.update(st_ref, pts)
    assert km.inertia(st, pts) < inert0
    assert km.inertia(st, pts) < 1.25 * ref.inertia(st_ref, pts)
