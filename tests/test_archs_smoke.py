"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward + one train step on CPU, asserting output
shapes and no NaNs. The FULL configs are exercised only via the dry-run.

Fast/slow matrix: tier-1 wall time is dominated by XLA compiles of the 10
arch configs (~280 s cold), so the fast lane (``-m "not slow"``) runs a
representative trio — one SSM (mamba2-130m), one multimodal/embeddings
arch (qwen2-vl-2b), one MoE (qwen3-moe-235b-a22b) — and the remaining
seven ride behind ``-m slow`` (a parallel CI job; ``pytest -x -q`` with no
marker filter still runs everything)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.models import transformer as T
from repro.train import step as TS

ARCHS = list_archs()

# one representative per major family: ssm / multimodal-embeddings / moe
FAST_ARCHS = ("mamba2-130m", "qwen2-vl-2b", "qwen3-moe-235b-a22b")
ARCH_MATRIX = [a if a in FAST_ARCHS
               else pytest.param(a, marks=pytest.mark.slow) for a in ARCHS]


def _inputs(cfg, b=2, s=32, key=None):
    key = key or jax.random.key(1)
    if cfg.input_mode == "embeddings":
        return {"embeds": jax.random.normal(key, (b, s, cfg.d_model)),
                "positions": jnp.tile(jnp.arange(s)[None, None], (3, b, 1)),
                "labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.n_codebooks > 1:
        return {"tokens": jax.random.randint(key, (b, s, cfg.n_codebooks),
                                             0, cfg.vocab_size),
                "labels": jnp.zeros((b, s, cfg.n_codebooks), jnp.int32)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jnp.zeros((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_MATRIX)
def test_forward_shapes_no_nan(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.key(0), cfg)
    inputs = _inputs(cfg)
    logits, aux = T.forward(params, cfg, inputs)
    b, s = 2, 32
    if cfg.n_codebooks > 1:
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_MATRIX)
def test_train_step(arch):
    cfg = get_arch(arch).reduced()
    tc = TS.TrainConfig()
    params, state = TS.init_train_state(jax.random.key(0), cfg, tc)
    step = jax.jit(TS.make_train_step(cfg, tc))
    inputs = _inputs(cfg, b=2, s=32)
    p2, s2, metrics = step(params, state, inputs)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(s2["step"]) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_MATRIX)
def test_decode_step(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.key(0), cfg)
    b = 2
    cache = T.init_cache(cfg, b, 16, jnp.float32)
    if cfg.input_mode == "embeddings":
        inp = {"embeds": jax.random.normal(jax.random.key(2),
                                           (b, 1, cfg.d_model)),
               "positions": jnp.zeros((3, b, 1), jnp.int32),
               "length": jnp.asarray(0, jnp.int32)}
    elif cfg.n_codebooks > 1:
        inp = {"tokens": jnp.ones((b, 1, cfg.n_codebooks), jnp.int32),
               "length": jnp.asarray(0, jnp.int32)}
    else:
        inp = {"tokens": jnp.ones((b, 1), jnp.int32),
               "length": jnp.asarray(0, jnp.int32)}
    logits, new_cache = T.decode_step(params, cfg, cache, inp)
    assert not bool(jnp.isnan(logits).any())
    assert logits.shape[1] == 1
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCH_MATRIX)
def test_loss_grads_finite(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.key(0), cfg)
    inputs = _inputs(cfg)
    grads, metrics = jax.grad(
        lambda p: T.loss_fn(p, cfg, inputs), has_aux=True)(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


def test_param_counts_match_published():
    """Analytic param counts are within tolerance of the published sizes."""
    expect = {
        "nemotron-4-340b": (340e9, 0.05),
        "internlm2-1.8b": (1.89e9, 0.05),
        "minicpm3-4b": (4.0e9, 0.1),
        "mistral-nemo-12b": (12.2e9, 0.05),
        "mamba2-130m": (130e6, 0.05),
        "hymba-1.5b": (1.5e9, 0.15),
        "arctic-480b": (480e9, 0.05),
        "qwen3-moe-235b-a22b": (235e9, 0.05),
        "musicgen-medium": (1.5e9, 0.35),   # backbone-only of "medium"
        "qwen2-vl-2b": (1.5e9, 0.25),       # sans vision tower
    }
    for name, (target, tol) in expect.items():
        n = get_arch(name).param_count
        assert abs(n - target) / target < tol, (name, n, target)


def test_moe_active_params():
    cfg = get_arch("qwen3-moe-235b-a22b")
    active = cfg.active_param_count
    assert abs(active - 22e9) / 22e9 < 0.15, active


def test_skip_shapes_policy():
    """long_500k only runs for sub-quadratic archs."""
    for name in ARCHS:
        cfg = get_arch(name)
        subquad = cfg.family in ("ssm", "hybrid")
        assert ("long_500k" in cfg.skip_shapes) == (not subquad), name
