"""MoE dispatch invariants: grouped vs ungrouped equivalence, sort-free
position correctness, capacity gating, drop accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# no custom reason=: pytest's default "could not import 'hypothesis'"
# message is what tools/check_skips.py keys its missing-dependency and
# known-image-gap detection on
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_arch
from repro.models import layers as L


def _cfg(capacity_factor=8.0, top_k=2, n_experts=4):
    base = get_arch("qwen3-moe-235b-a22b").reduced()
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=capacity_factor,
                                      top_k=top_k, n_experts=n_experts))


def test_grouped_equals_ungrouped_with_headroom():
    cfg = _cfg(capacity_factor=8.0)
    p = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    y1, a1 = L.moe_forward(p, x, cfg, groups=1)
    y4, a4 = L.moe_forward(p, x, cfg, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-6)
    assert abs(float(a1["lb_loss"]) - float(a4["lb_loss"])) < 1e-6


def test_capacity_gate_falls_back_ungrouped():
    """Tiny token counts must not take the grouped path (capacity floor
    would oversize the buffer `groups`x)."""
    cfg = _cfg(capacity_factor=1.25, n_experts=4)
    p = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 2, cfg.d_model))  # 4 toks
    # groups=4 -> 1 token/group -> gate must fall back; result == groups=1
    y1, _ = L.moe_forward(p, x, cfg, groups=1)
    y4, _ = L.moe_forward(p, x, cfg, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-6)


@given(seed=st.integers(0, 100), n=st.integers(1, 300),
       e=st.integers(1, 16))
@settings(max_examples=20, deadline=None)
def test_dispatch_positions_dense_per_expert(seed, n, e):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, e, size=(n,)), jnp.int32)
    pos = np.asarray(L._dispatch_positions(ids, e))
    for ex in range(e):
        ps = np.sort(pos[np.asarray(ids) == ex])
        assert (ps == np.arange(len(ps))).all()


def test_dropped_tokens_contribute_zero():
    """With capacity 8 and all tokens routed to one expert, overflow
    tokens must contribute exactly zero output."""
    cfg = _cfg(capacity_factor=0.01, top_k=1, n_experts=4)
    p = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    # force router to prefer expert 0 strongly
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model))
    y, aux = L.moe_forward(p, x, cfg)
    assert float(aux["dropped_frac"]) > 0.5
    # every dropped token's output row is exactly zero (gate * nothing);
    # the zero count must equal the drop count exactly
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    assert (norms == 0.0).sum() == round(64 * float(aux["dropped_frac"]))


def test_moe_grad_flows_through_grouped_path():
    cfg = _cfg(capacity_factor=2.0)
    p = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))

    def loss(p):
        y, aux = L.moe_forward(p, x, cfg, groups=4)
        return jnp.sum(y ** 2) + aux["lb_loss"]

    g = jax.grad(loss)(p)
    for k in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[k]).sum()) > 0, k
        assert bool(jnp.isfinite(g[k]).all()), k
