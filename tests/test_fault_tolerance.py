"""End-to-end fault-tolerance: train → checkpoint → lose the pilot →
re-admit a smaller pilot → reshard-restore → training continues with the
same loss trajectory. This is the pod-loss recovery path of the multi-pod
story, exercised on the CPU host."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore
from repro.configs import get_arch
from repro.core import ComputeResource, PilotManager, SimClock, remesh_restart
from repro.data import make_batch_iterator
from repro.models import transformer as T
from repro.train import step as TS


def test_pilot_liveness_detection_virtual_time():
    """Silent pilot loss is detected on the injected clock — the paper's
    failure detector, exercised without any real heartbeat waiting."""
    clock = SimClock()
    mgr = PilotManager(devices=(), clock=clock, heartbeat_timeout_s=5.0)
    healthy = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1))
    silent = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1))
    assert mgr.check_liveness() == []
    clock.advance(4.0)
    mgr.heartbeat(healthy)               # only one pilot keeps beating
    clock.advance(3.0)                   # silent pilot is now 7 s stale
    lost = mgr.check_liveness()
    assert lost == [silent]
    assert silent.state == "failed" and healthy.state == "active"
    # stale-but-already-failed pilots are not re-reported
    clock.advance(100.0)
    assert mgr.check_liveness() == [healthy]
    assert mgr.check_liveness() == []


def test_liveness_loss_triggers_remesh_restart_virtual_time():
    """End-to-end recovery loop under virtual time: heartbeat loss →
    check_liveness marks the pilot failed → remesh_restart re-admits a
    replacement and restores state, all in zero wall time."""
    clock = SimClock()
    mgr = PilotManager(clock=clock, heartbeat_timeout_s=5.0)
    n = mgr.free_devices
    pilot = mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=n))
    clock.advance(10.0)                  # the pilot went silent
    lost = mgr.check_liveness()
    assert lost == [pilot] and pilot.state == "failed"
    restored = {}

    def restore_fn(new_pilot):
        restored["tier"] = new_pilot.tier
        return {"step": 3}

    # devices of the failed pilot are gone; recover on what's left (0 here)
    new_pilot, state = remesh_restart(mgr, pilot, 0, restore_fn=restore_fn)
    assert state == {"step": 3}
    assert new_pilot.state == "active" and restored["tier"] == "cloud"


@pytest.mark.slow
def test_pod_loss_checkpoint_restart(tmp_path):
    cfg = get_arch("mamba2-130m").reduced()
    tc = TS.TrainConfig(lr=1e-3, warmup=2, total_steps=20)

    # --- phase 1: train 6 steps on the "big" pilot, checkpointing ---
    mgr = PilotManager()
    n = mgr.free_devices
    pilot = mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=n))
    params, state = TS.init_train_state(jax.random.key(0), cfg, tc)
    step_fn = jax.jit(TS.make_train_step(cfg, tc))
    it = make_batch_iterator(cfg, 2, 32, seed=1)
    batches = [next(it) for _ in range(12)]
    for i in range(6):
        params, state, metrics = step_fn(params, state, batches[i])
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    ck.save(6, {"params": params, "state": state})
    loss_at_6 = float(metrics["loss"])

    # --- phase 2: the pilot fails; recover on fewer devices ---
    def restore_fn(new_pilot):
        like = {"params": params, "state": state}
        pspecs = None
        mesh = new_pilot.mesh
        return restore(str(tmp_path), 6, like=like, mesh=mesh,
                       pspecs=pspecs)

    new_pilot, restored = remesh_restart(mgr, pilot, 0,
                                         restore_fn=restore_fn)
    assert new_pilot.state == "active"
    r_params, r_state = restored["params"], restored["state"]
    assert int(r_state["step"]) == 6

    # --- phase 3: continue training; must match an uninterrupted run ---
    for i in range(6, 9):
        r_params, r_state, m2 = step_fn(r_params, r_state, batches[i])
    # uninterrupted reference
    p_ref, s_ref = TS.init_train_state(jax.random.key(0), cfg, tc)
    for i in range(9):
        p_ref, s_ref, m_ref = step_fn(p_ref, s_ref, batches[i])
    np.testing.assert_allclose(float(m2["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(r_params), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-5)


def test_restore_onto_explicit_mesh_pspecs(tmp_path):
    """Reshard-on-restore with real NamedShardings (1-device mesh here;
    the 512-device version is exercised by the dry-run path)."""
    from jax.sharding import PartitionSpec as P
    cfg = get_arch("internlm2-1.8b").reduced()
    params = T.init_params(jax.random.key(0), cfg)
    from repro.ckpt import save
    save(str(tmp_path), 1, params)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = T.ShardRules(batch=("data",), model="model", fsdp=None)
    pspecs = T.param_pspecs(cfg, rules)
    got = restore(str(tmp_path), 1, like=params, mesh=mesh, pspecs=pspecs)
    for leaf in jax.tree.leaves(got):
        assert isinstance(leaf.sharding, jax.sharding.NamedSharding)
    # values identical
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
