"""Virtual-time emulator tests: SimClock/EventScheduler semantics, broker
behaviour under virtual time, scenario determinism, and the Fig-3 golden
placement results (k-means is transfer-bound, autoencoders are
compute-bound).  Everything here runs in milliseconds of wall time."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (Broker, ComputeResource, ConsumerGroup,
                        MetricsRegistry, PilotManager, SimClock, WanShaper,
                        as_clock)
from repro.core.elastic import ScalePolicy
from repro.core.placement import LinkModel, PlacementEngine
from repro.sim import PARK, ActorKilled, EventScheduler
from repro.sim.scenarios import (AUTOENCODER, ISOFOREST, KMEANS,
                                 DiurnalArrivals, FailureSpec,
                                 FlashCrowdArrivals, PoissonArrivals,
                                 Scenario, TraceArrivals, format_table,
                                 placement_estimates, run_scenario, sweep)

TRACE_FILE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "traces",
    "azure_functions_like.txt")


# ---------------------------------------------------------------------------
# SimClock
# ---------------------------------------------------------------------------

def test_simclock_advance_and_auto_sleep():
    c = SimClock()
    assert c.now() == 0.0
    c.advance(2.5)
    assert c.now() == 2.5
    c.sleep(1.5)                       # auto mode: jumps, no wall blocking
    assert c.now() == 4.0
    c.advance_to(3.0)                  # never backwards
    assert c.now() == 4.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_simclock_manual_sleep_blocks_until_driven():
    c = SimClock(auto_advance=False)
    woke = threading.Event()

    def sleeper():
        c.sleep(10.0)
        woke.set()

    th = threading.Thread(target=sleeper, daemon=True)
    th.start()
    deadline = time.monotonic() + 5.0
    while c.sleepers == 0 and time.monotonic() < deadline:
        time.sleep(0.001)
    assert c.sleepers == 1
    assert not woke.is_set()
    c.advance(9.0)                     # not enough
    time.sleep(0.02)
    assert not woke.is_set()
    c.advance(1.5)                     # past the deadline
    assert woke.wait(5.0)
    th.join(5.0)


def test_simclock_close_releases_sleepers():
    c = SimClock(auto_advance=False)
    done = threading.Event()
    th = threading.Thread(target=lambda: (c.sleep(1e9), done.set()),
                          daemon=True)
    th.start()
    time.sleep(0.01)
    c.close()
    assert done.wait(5.0)


def test_as_clock_coerces_callables():
    t = {"v": 7.0}
    c = as_clock(lambda: t["v"])
    assert c.now() == 7.0 and not c.virtual
    sim = SimClock()
    assert as_clock(sim) is sim
    assert not as_clock(None).virtual


# ---------------------------------------------------------------------------
# EventScheduler
# ---------------------------------------------------------------------------

def test_scheduler_orders_by_time_then_insertion():
    sched = EventScheduler()
    out = []
    sched.at(2.0, lambda: out.append("b"))
    sched.at(1.0, lambda: out.append("a"))
    sched.at(2.0, lambda: out.append("c"))   # same time, later insertion
    ev = sched.at(3.0, lambda: out.append("dropped"))
    ev.cancel()
    n = sched.run()
    assert out == ["a", "b", "c"]
    assert n == 3
    assert sched.clock.now() == 2.0


def test_scheduler_handlers_schedule_followups():
    sched = EventScheduler()
    ticks = []

    def tick():
        ticks.append(sched.clock.now())
        if len(ticks) < 5:
            sched.after(0.5, tick)

    sched.at(0.0, tick)
    sched.run()
    assert ticks == [0.0, 0.5, 1.0, 1.5, 2.0]


def test_scheduler_run_until_bound():
    sched = EventScheduler()
    out = []
    for i in range(10):
        sched.at(float(i), lambda i=i: out.append(i))
    sched.run(until=4.0)
    assert out == [0, 1, 2, 3, 4]
    sched.run()
    assert out == list(range(10))


# ---------------------------------------------------------------------------
# actors (cooperative DES processes)
# ---------------------------------------------------------------------------

def test_actor_sleep_park_resume_and_return():
    sched = EventScheduler()
    trace = []

    def body():
        trace.append(("start", sched.clock.now()))
        got = yield 1.5                      # sleep 1.5 virtual seconds
        assert got is None
        trace.append(("awake", sched.clock.now()))
        got = yield PARK                     # park until external resume
        trace.append(("resumed", sched.clock.now(), got))
        return "done"

    exits = []
    actor = sched.spawn(body(),
                        on_exit=lambda a, exc, res: exits.append((exc, res)))
    sched.run(until=2.0)
    assert trace == [("start", 0.0), ("awake", 1.5)]
    assert sched.clock.now() == 2.0          # run(until=) covers the window
    assert actor.parked and actor.alive
    sched.clock.advance(1.0)
    actor.resume("payload")
    sched.run()
    assert trace[-1] == ("resumed", 3.0, "payload")
    assert exits == [(None, "done")]
    assert not actor.alive


def test_actor_kill_delivers_exception_at_yield_point():
    sched = EventScheduler()
    cleaned, exits = [], []

    def body():
        try:
            yield PARK
        except ActorKilled:
            cleaned.append(True)
            raise

    actor = sched.spawn(body(),
                        on_exit=lambda a, exc, res: exits.append(exc))
    sched.run()
    actor.kill()
    sched.run()
    assert cleaned == [True]
    assert isinstance(exits[0], ActorKilled)


def test_actor_drop_goes_dark_without_on_exit():
    sched = EventScheduler()
    exits = []

    def body():
        yield 10.0
        exits.append("ran")

    actor = sched.spawn(body(), on_exit=lambda a, e, r: exits.append("exit"))
    sched.run(until=1.0)
    actor.drop()
    sched.run()
    assert exits == []                       # silent: no steps, no on_exit
    assert sched.clock.now() < 10.0


def test_actor_custom_effect_interpreter():
    """Non-numeric yields route to the spawner's interpreter (numbers are
    always sleeps — that's the fixed part of the actor protocol)."""
    sched = EventScheduler()
    out = []

    def interpret(actor, eff):
        actor.resume(eff["x"] * 2, delay=1.0)    # echo doubled, 1 s later

    def body():
        out.append((yield {"x": 21}))
        out.append((yield {"x": 5}))

    sched.spawn(body(), interpret=interpret)
    sched.run()
    assert out == [42, 10]
    assert sched.clock.now() == 2.0


# ---------------------------------------------------------------------------
# broker under virtual time
# ---------------------------------------------------------------------------

def test_topic_append_subscriptions():
    """Event-driven consumers: subscribers are notified on every produce
    with the partition and WAN-shaped visibility time."""
    clock = SimClock()
    b = Broker(clock=clock)
    sh = WanShaper(bandwidth_bps=8e6, rtt_s=0.1, sleep=False)
    t = b.create_topic("t", n_partitions=2, shaper=sh)
    got = []
    cb = lambda p, ready: got.append((p, ready))     # noqa: E731
    t.subscribe(cb)
    t.subscribe(cb)                  # double-subscribe is a no-op…
    t.produce(np.zeros(1000, np.float64), partition=1)
    assert len(got) == 1             # …so the append fires cb exactly once
    assert got[0][0] == 1 and got[0][1] > clock.now()
    t.unsubscribe(cb)
    t.unsubscribe(cb)                # unknown/already-removed: tolerated
    t.produce(np.zeros(10, np.float64), partition=0)
    assert len(got) == 1

def test_wan_visibility_honored_under_virtual_clock():
    """With a virtual clock, a message is invisible until its WAN-shaped
    ready time; polling jumps time there instead of sleeping."""
    clock = SimClock()
    b = Broker(clock=clock)
    sh = WanShaper(bandwidth_bps=8e6, rtt_s=0.1, sleep=False)   # 1 MB/s
    t = b.create_topic("t", shaper=sh)
    t.produce(np.zeros(125_000, np.float64))        # ~1 MB -> ~1.05+ s
    msg, ready = t.poll_nowait(0, 0)
    assert msg is None and ready is not None and ready > 1.0
    msg = t.poll(0, 0, timeout_s=10.0)              # advances virtual time
    assert msg is not None
    assert clock.now() >= ready
    assert clock.now() < 2.0                         # ...but only to ready


def test_poll_timeout_expires_in_virtual_time():
    clock = SimClock()
    b = Broker(clock=clock)
    t = b.create_topic("t")
    t0 = time.perf_counter()
    assert t.poll(0, 0, timeout_s=30.0) is None      # nothing produced
    assert time.perf_counter() - t0 < 5.0            # no real 30 s wait
    assert clock.now() >= 30.0


def test_consumer_group_poll_nowait_ready_hint():
    clock = SimClock()
    b = Broker(clock=clock)
    sh = WanShaper(bandwidth_bps=8e6, rtt_s=0.0, sleep=False)
    t = b.create_topic("t", n_partitions=2, shaper=sh)
    g = ConsumerGroup(t)
    g.join("c0")
    t.produce(np.zeros(125_000 // 8, np.float64), partition=0)
    msg, ready = g.poll_nowait("c0")
    assert msg is None and ready is not None
    clock.advance_to(ready)
    msg, _ = g.poll_nowait("c0")
    assert msg is not None
    g.commit(msg)
    assert g.lag() == 0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_consumer_group_rebalance_no_gaps_deterministic(seed):
    """Seed-parametrized (no-hypothesis) cousin of the property test:
    random crash-before-commit / rejoin churn never loses an offset."""
    clock = SimClock()
    b = Broker(clock=clock)
    t = b.create_topic("t", n_partitions=3)
    g = ConsumerGroup(t)
    rng = np.random.default_rng(seed)
    consumers = ["c0", "c1", "c2"]
    for c in consumers:
        g.join(c)
    n_msgs = 30
    for i in range(n_msgs):
        t.produce(np.array([i]))
    seen, deliveries, alive = set(), 0, list(consumers)
    for _ in range(2000):
        if g.lag() == 0:
            break
        if len(alive) < len(consumers) and rng.random() < 0.2:
            back = [c for c in consumers if c not in alive][0]
            alive.append(back)
            g.join(back)
        cid = alive[rng.integers(0, len(alive))]
        msg, _ = g.poll_nowait(cid)
        if msg is None:
            clock.advance(0.01)
            continue
        deliveries += 1
        seen.add(int(msg.value()[0]))
        if len(alive) > 1 and rng.random() < 0.25:
            alive.remove(cid)
            g.leave(cid)                # crash before commit -> redeliver
        else:
            g.commit(msg)
    assert g.lag() == 0
    assert deliveries >= n_msgs
    assert seen == set(range(n_msgs))


@pytest.mark.parametrize("bw_mbit", [1.0, 10.0, 80.0])
def test_wan_shaper_monotone_and_serialized(bw_mbit):
    bw = bw_mbit * 1e6
    sizes = [1_000, 10_000, 100_000, 1_000_000]
    delays = [WanShaper(bandwidth_bps=bw, rtt_s=0.1,
                        sleep=False).delay_for(n, now=0.0) for n in sizes]
    assert delays == sorted(delays)
    sh = WanShaper(bandwidth_bps=bw, rtt_s=0.0, sleep=False)
    clears = [sh.delay_for(n, now=0.0) for n in sizes]
    np.testing.assert_allclose(clears[-1],
                               sum(n * 8.0 / bw for n in sizes), rtol=1e-9)


# ---------------------------------------------------------------------------
# scenarios: determinism + the paper's Fig-3 golden results
# ---------------------------------------------------------------------------

def test_scenario_bit_reproducible():
    sc = Scenario(model=KMEANS, placement="cloud", wan_band="10mbit",
                  n_messages=32, seed=7,
                  failures=(FailureSpec(at_s=1.0, consumer_idx=1),))
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.row() == b.row()            # bit-identical metrics
    assert a.latency_mean_s == b.latency_mean_s


def test_scenario_failure_injection_at_least_once():
    sc = Scenario(model=KMEANS, placement="cloud", wan_band="100mbit",
                  n_messages=48, seed=1,
                  failures=(FailureSpec(at_s=0.5, consumer_idx=0,
                                        restart_after_s=0.5),
                            FailureSpec(at_s=1.0, consumer_idx=1,
                                        restart_after_s=None)))
    r = run_scenario(sc)
    assert r.n_processed == 48           # nothing lost across rebalances
    assert r.metrics.events("consumer_crashed")
    assert r.metrics.events("consumer_restarted")


def test_scenario_wall_time_budget():
    """A Fig-3 cell covering ~minutes of virtual pipeline time must
    emulate in well under a second."""
    r = run_scenario(Scenario(model=AUTOENCODER, placement="cloud",
                              wan_band="10mbit", n_messages=32))
    assert r.makespan_s > 10.0           # real pipeline time emulated
    assert r.wall_ms < 5_000.0


def test_fig3_kmeans_prefers_edge_on_slow_wan():
    """Paper Fig 3 (left): k-means is transfer-bound — on a 10 Mbit/s WAN
    edge placement beats cloud placement by a wide margin, and cloud
    throughput scales with the WAN band."""
    edge = run_scenario(Scenario(model=KMEANS, placement="edge",
                                 wan_band="10mbit", n_messages=48))
    cloud10 = run_scenario(Scenario(model=KMEANS, placement="cloud",
                                    wan_band="10mbit", n_messages=48))
    cloud100 = run_scenario(Scenario(model=KMEANS, placement="cloud",
                                     wan_band="100mbit", n_messages=48))
    assert edge.throughput_msgs_s > 5 * cloud10.throughput_msgs_s
    assert cloud100.throughput_msgs_s > 3 * cloud10.throughput_msgs_s
    # transfer-bound: raw points cross the WAN only under cloud placement
    assert cloud10.wan_mbytes > 10 * edge.wan_mbytes


def test_fig3_autoencoder_wan_insensitive():
    """Paper Fig 3 (right): the autoencoder is compute-bound — placement
    ranking is unchanged across WAN bands and cloud throughput barely
    moves between 10 and 100 Mbit/s."""
    results = {}
    for band in ("10mbit", "100mbit"):
        for placement in ("edge", "cloud"):
            r = run_scenario(Scenario(model=AUTOENCODER,
                                      placement=placement, wan_band=band,
                                      n_messages=32))
            results[(band, placement)] = r.throughput_msgs_s
    for band in ("10mbit", "100mbit"):
        assert results[(band, "cloud")] > 3 * results[(band, "edge")]
    ratio = results[("100mbit", "cloud")] / results[("10mbit", "cloud")]
    assert ratio < 1.2                   # the network is not the bottleneck


def test_placement_engine_agrees_with_emulation():
    """The cost model the PlacementEngine prices placements with must give
    the same qualitative answer as the emulator (both read the shared
    repro.cost calibration — one oracle, not two)."""
    est_k = placement_estimates(Scenario(model=KMEANS, wan_band="10mbit"))
    assert est_k["edge"] < est_k["cloud"]       # k-means: stay on the edge
    est_i = placement_estimates(Scenario(model=ISOFOREST,
                                         wan_band="10mbit"))
    assert est_i["edge"] < est_i["cloud"]       # iforest: transfer-bound too
    for band in ("10mbit", "100mbit"):
        est_a = placement_estimates(Scenario(model=AUTOENCODER,
                                             wan_band=band))
        assert est_a["cloud"] < est_a["edge"]   # AE: always ship to cloud


def test_placement_engine_fig3_golden_links():
    """Golden pin of the Fig-3 qualitative result straight on the engine:
    k-means prefers the edge under a 10 Mbit/s WAN, the autoencoder ships
    to the cloud on every band, and a WAN upgrade helps the transfer-bound
    profile far more than the compute-bound one."""
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=4))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=4))
    k_prof = KMEANS.task_profile(2_500)
    a_prof = AUTOENCODER.task_profile(2_500)

    def engine(bw_mbit):
        links = {("edge", "cloud"): LinkModel(bandwidth=bw_mbit * 1e6 / 8,
                                              latency_s=0.15)}
        return PlacementEngine(links=links)

    e10, e100 = engine(10.0), engine(100.0)
    assert e10.place(k_prof, [edge, cloud]).pilot.tier == "edge"
    for eng in (e10, e100):
        assert eng.place(a_prof, [edge, cloud]).pilot.tier == "cloud"
    # WAN upgrade shrinks the k-means cloud estimate much more than AE's
    k_ratio = (e100.estimate(k_prof, cloud).est_time_s
               / e10.estimate(k_prof, cloud).est_time_s)
    a_ratio = (e100.estimate(a_prof, cloud).est_time_s
               / e10.estimate(a_prof, cloud).est_time_s)
    assert k_ratio < a_ratio < 1.0


def test_sweep_and_table():
    rows = sweep(models=(KMEANS,), placements=("edge", "cloud"),
                 bands=("10mbit",), n_messages=16)
    assert len(rows) == 2
    table = format_table(rows)
    assert "kmeans" in table and "msg/s" in table
    assert all(r.n_processed == 16 for r in rows)


# ---------------------------------------------------------------------------
# metrics under an injected clock
# ---------------------------------------------------------------------------

def test_metrics_stamps_use_injected_clock():
    clock = SimClock()
    reg = MetricsRegistry(clock=clock)
    reg.stamp("m", "produced")
    clock.advance(3.0)
    reg.stamp("m", "processed")
    assert reg.latencies("produced", "processed") == [3.0]
    assert reg.first_stamp("produced") == 0.0
    assert reg.last_stamp("processed") == 3.0


# ---------------------------------------------------------------------------
# event-loop bugfix pins (PR 6): resume-vs-sleep, run(until=), open loop
# ---------------------------------------------------------------------------

def test_actor_resume_must_not_rewrite_timed_sleep():
    """Regression: ``resume()`` during a timed sleep used to *reschedule*
    the pending wakeup at ``now + delay`` — a stray resume silently moved
    an actor's alarm clock.  Here the actor sleeps until t=5.0 and a
    resume lands at t=1.0: pre-fix the actor woke at 1.0 (with the
    resume's payload delivered into the ``yield 5.0``), post-fix the
    resume is a no-op and the wakeup stays at 5.0."""
    sched = EventScheduler()
    trace = []

    def body():
        got = yield 5.0
        trace.append(("awake", sched.clock.now(), got))
        got = yield PARK                 # parked: resume must work here
        trace.append(("resumed", sched.clock.now(), got))

    actor = sched.spawn(body())
    sched.run(until=1.0)
    assert trace == []                   # still mid-sleep
    actor.resume("stray")                # would have woken it at 1.0
    sched.run(until=4.0)
    assert trace == []                   # old behaviour: ("awake", 1.0, "stray")
    sched.run(until=6.0)
    assert trace == [("awake", 5.0, None)]
    actor.resume("legit")                # parked now: resume is the protocol
    sched.run()                          # clock sits at 6.0 (until= bound)
    assert trace[-1] == ("resumed", 6.0, "legit")


def test_actor_resume_works_when_idle_on_interpreted_effect():
    """An actor suspended on an interpreted effect has no pending wakeup:
    the interpreter's (possibly delayed) ``resume`` must still land."""
    sched = EventScheduler()
    out = []

    def interpret(actor, eff):
        actor.resume(eff["v"] * 10, delay=2.0)

    def body():
        out.append((yield {"v": 3}))     # non-numeric: routed to interpret

    sched.spawn(body(), interpret=interpret)
    sched.run()
    assert out == [30] and sched.clock.now() == 2.0


def test_run_until_advances_clock_to_bound_on_drain():
    """Regression: ``run(until=T)`` that drained the heap early used to
    leave the clock at the last event's time, so back-to-back bounded
    runs silently lost the idle tail of each window."""
    sched = EventScheduler()
    out = []
    sched.at(1.0, lambda: out.append(1))
    sched.run(until=4.0)
    assert out == [1]
    assert sched.clock.now() == 4.0      # pre-fix: stuck at 1.0
    # next event beyond the bound: clock still advances exactly to until
    sched.at(9.0, lambda: out.append(9))
    sched.run(until=6.0)
    assert out == [1] and sched.clock.now() == 6.0
    sched.run()                          # unbounded: runs the rest
    assert out == [1, 9] and sched.clock.now() == 9.0
    # unbounded drain of an empty heap must NOT advance to infinity
    before = sched.clock.now()
    sched.run()
    assert sched.clock.now() == before


# ---------------------------------------------------------------------------
# open-loop arrival processes + per-stage autoscaling (PR 6)
# ---------------------------------------------------------------------------

def test_arrival_processes_deterministic_sorted_and_sized():
    for proc in (PoissonArrivals(rate_hz=200.0),
                 DiurnalArrivals(base_rate_hz=20.0, peak_rate_hz=200.0,
                                 period_s=10.0),
                 FlashCrowdArrivals(base_rate_hz=20.0, burst_rate_hz=400.0,
                                    burst_at_s=1.0, burst_duration_s=0.5)):
        a = proc.times(500, seed=3)
        b = proc.times(500, seed=3)
        assert len(a) == 500
        assert np.array_equal(a, b)                  # same seed: identical
        assert np.all(np.diff(a) >= 0.0)             # sorted
        assert float(a[0]) >= 0.0
        assert not np.array_equal(a, proc.times(500, seed=4))


def test_arrival_process_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(rate_hz=0.0)
    with pytest.raises(ValueError):
        DiurnalArrivals(base_rate_hz=10.0, peak_rate_hz=5.0, period_s=10.0)
    with pytest.raises(ValueError):
        FlashCrowdArrivals(base_rate_hz=10.0, burst_rate_hz=5.0,
                           burst_at_s=1.0, burst_duration_s=1.0)


def test_flash_crowd_concentrates_arrivals_in_burst():
    proc = FlashCrowdArrivals(base_rate_hz=10.0, burst_rate_hz=1000.0,
                              burst_at_s=2.0, burst_duration_s=1.0)
    t = proc.times(400, seed=0)
    in_burst = int(np.sum((t >= 2.0) & (t < 3.0)))
    assert in_burst > 200                # the burst dominates the draw


def test_trace_arrivals_replays_committed_trace_deterministically():
    proc = TraceArrivals(path=TRACE_FILE)
    a = proc.times(500, seed=3)
    assert len(a) == 500
    assert float(a[0]) == 0.0                    # re-based to start at 0
    assert np.all(np.diff(a) >= 0.0)             # sorted
    # replay, not a random draw: the seed is ignored by design
    assert np.array_equal(a, proc.times(500, seed=4))


def test_trace_arrivals_parses_comments_sorts_and_rebases(tmp_path):
    p = tmp_path / "trace.txt"
    p.write_text("# header\n\n7.5\n3.0\n# comment\n5.0\n")
    t = TraceArrivals(path=str(p)).times(3, seed=0)
    np.testing.assert_allclose(t, [0.0, 2.0, 4.5])


def test_trace_arrivals_periodic_extension_and_time_scale(tmp_path):
    p = tmp_path / "trace.txt"
    p.write_text("0.0\n1.0\n4.0\n")
    proc = TraceArrivals(path=str(p))
    # period = last + mean gap = 4.0 + 2.0: repetitions tile at 6.0
    t = proc.times(7, seed=0)
    np.testing.assert_allclose(t, [0.0, 1.0, 4.0,
                                   6.0, 7.0, 10.0,
                                   12.0])
    np.testing.assert_allclose(
        TraceArrivals(path=str(p), time_scale=0.5).times(3, seed=0),
        [0.0, 0.5, 2.0])


def test_trace_arrivals_validation(tmp_path):
    with pytest.raises(ValueError):
        TraceArrivals(path=TRACE_FILE, time_scale=0.0)
    empty = tmp_path / "empty.txt"
    empty.write_text("# nothing but headers\n\n")
    with pytest.raises(ValueError):
        TraceArrivals(path=str(empty)).times(1, seed=0)
    bad = tmp_path / "bad.txt"
    bad.write_text("1.0\nnan\n")
    with pytest.raises(ValueError):
        TraceArrivals(path=str(bad)).times(2, seed=0)


def test_trace_driven_scenario_is_bit_identical():
    """The full DES driven by the committed recorded trace: open-loop
    replay paces the run to the trace's span and stays bit-identical."""
    sc = Scenario(model=KMEANS, placement="cloud", wan_band="100mbit",
                  n_messages=40, n_devices=4, n_points=10, seed=11,
                  arrival=TraceArrivals(path=TRACE_FILE))
    span = float(sc.arrival.times(sc.n_messages, sc.seed)[-1])
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.row() == b.row()
    assert a.n_processed == 40
    assert a.makespan_s >= 0.8 * span    # paced by the recorded trace


def test_open_loop_scenario_paces_traffic_and_is_bit_identical():
    """Open loop: traffic intensity is the arrival process's, not the
    pipeline's — the makespan tracks the arrival span instead of
    collapsing to back-to-back production.  And the whole run stays
    bit-identical across three executions."""
    sc = Scenario(model=KMEANS, placement="cloud", wan_band="100mbit",
                  n_messages=120, n_devices=4, n_points=10, seed=11,
                  arrival=PoissonArrivals(rate_hz=40.0))
    span = float(sc.arrival.times(sc.n_messages, sc.seed)[-1])
    a, b, c = (run_scenario(sc) for _ in range(3))
    assert a.row() == b.row() == c.row()
    assert a.n_processed == 120
    assert a.makespan_s >= 0.8 * span    # paced by arrivals, not drain rate
    closed = run_scenario(Scenario(model=KMEANS, placement="cloud",
                                   wan_band="100mbit", n_messages=120,
                                   n_devices=4, n_points=10, seed=11))
    assert closed.makespan_s < a.makespan_s


def test_per_stage_autoscaling_scales_hot_stage():
    """A flash crowd through the 3-stage fog pipeline with a per-stage
    policy on the fog stage: the scaler must react (scale up on the
    burst), and the run stays deterministic."""
    sc = Scenario(model=KMEANS, placement="fog", wan_band="100mbit",
                  n_messages=200, n_devices=4, n_points=1000, seed=5,
                  arrival=FlashCrowdArrivals(base_rate_hz=20.0,
                                             burst_rate_hz=1000.0,
                                             burst_at_s=1.0,
                                             burst_duration_s=1.0),
                  autoscale_stages=(
                      ("process_fog", ScalePolicy(min_workers=2,
                                                  max_workers=16,
                                                  lag_high=8,
                                                  lag_low=1,
                                                  cooldown_s=0.2)),))
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.row() == b.row()
    assert a.n_processed == 200
    assert a.row()["autoscale_actions"] > 0
    ups = [e for e in a.autoscale_events
           if e["to_workers"] > e["from_workers"]]
    assert ups                           # the burst forced a scale-up


def test_per_stage_autoscaler_rejects_source_stage():
    from repro.core.executor import SimExecutor
    from repro.sim.scenarios import build_pipeline
    sc = Scenario(model=KMEANS, placement="fog", wan_band="100mbit",
                  n_messages=8, n_devices=2, n_points=10, seed=0)
    pipe, ex, mgr = build_pipeline(sc)
    ex.autoscalers = {0: object()}       # stage 0 has no consumer group
    with pytest.raises(ValueError):
        pipe.run(n_messages=8, timeout_s=30.0, collect_results=False,
                 scheduler=ex)


def test_arrival_plan_validates_against_run_args():
    from repro.sim.scenarios import arrival_plan, build_pipeline
    sc = Scenario(model=KMEANS, placement="cloud", wan_band="100mbit",
                  n_messages=16, n_devices=4, n_points=10, seed=0,
                  arrival=PoissonArrivals(rate_hz=100.0))
    pipe, ex, mgr = build_pipeline(sc)
    plan = arrival_plan(sc)
    assert plan is not None and sum(len(p) for p in plan) == 16
    with pytest.raises(ValueError):      # n_messages disagrees with plan
        pipe.run(n_messages=15, timeout_s=30.0, collect_results=False,
                 scheduler=ex, arrival_plan=plan)
    with pytest.raises(ValueError):      # wrong number of device streams
        pipe.run(timeout_s=30.0, collect_results=False, scheduler=ex,
                 arrival_plan=plan[:-1])
