"""Behaviour tests for the Pilot-Edge core: broker semantics, pilot
lifecycle, runtime fault tolerance, placement, parameter service,
elasticity."""
import threading
import time

import numpy as np
import pytest

from repro.core import (AutoScaler, Broker, ComputeResource, ConsumerGroup,
                        EdgeToCloudPipeline, MetricsRegistry,
                        ParameterService, Pilot, PilotError, PilotManager,
                        PlacementEngine, ScalePolicy, SimClock, TaskFailed,
                        TaskProfile, TaskRuntime, WanShaper, remesh_restart)
from repro.core.monitoring import LatencySketch


def _drive(clock, fut, step_s=0.5, timeout_s=10.0):
    """Advance virtual time in steps until the future resolves — the test
    plays the role of the (virtual) passage of time."""
    deadline = time.monotonic() + timeout_s
    while not fut.done() and time.monotonic() < deadline:
        clock.advance(step_s)
        time.sleep(0.002)
    return fut


# ---------------------------------------------------------------------------
# broker
# ---------------------------------------------------------------------------

def test_topic_ordering_within_partition():
    b = Broker()
    t = b.create_topic("t", n_partitions=1)
    for i in range(10):
        t.produce(np.array([i]), partition=0)
    got = [t.poll(0, i).value()[0] for i in range(10)]
    assert got == list(range(10))


def test_topic_round_robin_and_keyed():
    b = Broker()
    t = b.create_topic("t", n_partitions=4)
    msgs = [t.produce(np.array([i])) for i in range(8)]
    assert sorted(m.partition for m in msgs) == [0, 0, 1, 1, 2, 2, 3, 3]
    m1 = t.produce(np.array([1]), key="device-7")
    m2 = t.produce(np.array([2]), key="device-7")
    assert m1.partition == m2.partition


def test_serialization_roundtrip_and_sizes():
    b = Broker()
    t = b.create_topic("t")
    data = np.random.default_rng(0).standard_normal((100, 32))
    m = t.produce(data)
    got = t.poll(0, 0).value()
    np.testing.assert_array_equal(got, data)
    # paper accounting: ~8 B/value + npy header
    assert abs(m.nbytes - 100 * 32 * 8) < 200


def test_consumer_group_commit_resume():
    b = Broker()
    t = b.create_topic("t", n_partitions=2)
    g = ConsumerGroup(t)
    g.join("c0")
    for i in range(6):
        t.produce(np.array([i]))
    seen = []
    for _ in range(3):
        m = g.poll("c0", timeout_s=1.0)
        seen.append(int(m.value()[0]))
        g.commit(m)
    assert g.lag() == 3
    # c0 dies; c1 takes over from committed offsets
    g.leave("c0")
    g.join("c1")
    rest = []
    for _ in range(3):
        m = g.poll("c1", timeout_s=1.0)
        rest.append(int(m.value()[0]))
        g.commit(m)
    assert sorted(seen + rest) == list(range(6))
    assert g.lag() == 0


def test_wan_shaper_bandwidth_serialization():
    sh = WanShaper(bandwidth_bps=8e6, rtt_s=0.1, sleep=False)  # 1 MB/s
    d1 = sh.delay_for(500_000, now=0.0)      # 0.5 MB -> 0.5s tx + 0.05 lat
    assert abs(d1 - 0.55) < 1e-6
    d2 = sh.delay_for(500_000, now=0.0)      # queued behind the first
    assert abs(d2 - 1.05) < 1e-6


# ---------------------------------------------------------------------------
# pilots
# ---------------------------------------------------------------------------

def test_pilot_admission_and_release():
    mgr = PilotManager()
    n = mgr.free_devices
    p = mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=n))
    assert mgr.free_devices == 0
    assert p.mesh is not None and p.mesh.size == n
    with pytest.raises(PilotError):
        mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=1))
    mgr.release(p)
    assert mgr.free_devices == n


def test_pilot_edge_no_devices():
    mgr = PilotManager()
    p = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=3))
    assert p.mesh is None and p.capacity == 3
    mgr.release(p)


def test_pilot_resize_workers():
    mgr = PilotManager()
    p = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    mgr.resize(p, n_workers=8)
    assert p.resource.n_workers == 8


def test_failed_pilot_devices_not_reused():
    mgr = PilotManager()
    n = mgr.free_devices
    p = mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=n))
    mgr.mark_failed(p)
    assert p.state == "failed"
    assert mgr.free_devices == 0          # devices are gone, not recycled


# ---------------------------------------------------------------------------
# runtime: retries, heartbeats, stragglers
# ---------------------------------------------------------------------------

def _edge_pilot(workers=4):
    return PilotManager().submit_pilot(
        ComputeResource(tier="edge", n_workers=workers))


def test_runtime_basic_and_map():
    rt = TaskRuntime(_edge_pilot())
    futs = rt.map(lambda ctx, x: x * 2, range(8))
    assert [f.result(5) for f in futs] == [0, 2, 4, 6, 8, 10, 12, 14]
    rt.shutdown()


def test_runtime_retry_then_success():
    rt = TaskRuntime(_edge_pilot(), max_retries=2)
    calls = []

    def flaky(ctx):
        calls.append(ctx.attempt)
        if ctx.attempt < 2:
            raise RuntimeError("boom")
        return "ok"

    assert rt.submit(flaky).result(10) == "ok"
    assert calls == [0, 1, 2]
    assert rt.metrics.counter("runtime.retries") == 2
    rt.shutdown()


def test_runtime_retries_exhausted():
    rt = TaskRuntime(_edge_pilot(), max_retries=1)
    fut = rt.submit(lambda ctx: 1 / 0)
    with pytest.raises(TaskFailed):
        fut.result(10)
    rt.shutdown()


def test_runtime_heartbeat_timeout_recovers():
    # virtual time: the hung attempt blocks on the SimClock; advancing past
    # the heartbeat timeout triggers loss detection with zero real waiting
    clock = SimClock(auto_advance=False)
    rt = TaskRuntime(_edge_pilot(), max_retries=1,
                     heartbeat_timeout_s=0.3, monitor_interval_s=0.01,
                     clock=clock)
    state = {"hung": False}
    hung = threading.Event()

    def task(ctx):
        if ctx.attempt == 0:
            state["hung"] = True
            hung.set()
            ctx.clock.sleep(60.0)    # no heartbeat -> declared lost
            return "zombie"
        return "recovered"

    fut = rt.submit(task)
    assert hung.wait(5.0)
    assert _drive(clock, fut).result(1) == "recovered"
    assert state["hung"]
    clock.close()
    rt.shutdown(wait=False)


def test_runtime_straggler_speculation():
    clock = SimClock(auto_advance=False)
    rt = TaskRuntime(_edge_pilot(8), speculative_factor=3.0,
                     monitor_interval_s=0.01, clock=clock)
    # establish a (virtually instantaneous) median
    for f in rt.map(lambda ctx, x: x, range(6)):
        f.result(5)
    hung = threading.Event()

    def straggler(ctx):
        if ctx.attempt == 0:
            hung.set()
            ctx.clock.sleep(600.0)   # way past 3x median
            return "slow"
        return "backup"

    fut = rt.submit(straggler)
    assert hung.wait(5.0)
    assert _drive(clock, fut).result(1) == "backup"
    assert fut.speculated
    m = rt.metrics
    assert m.counter("runtime.speculative_launches") >= 1
    # first-completion-wins accounting: the backup won, and every launch
    # is accounted (wins + losses + cancelled == launches)
    assert m.counter("runtime.speculative_wins") == 1
    assert (m.counter("runtime.speculative_wins")
            + m.counter("runtime.speculative_losses")
            + m.counter("runtime.speculative_cancelled")
            == m.counter("runtime.speculative_launches"))
    clock.close()
    rt.shutdown(wait=False)


def test_runtime_speculation_cancelled_on_terminal_failure():
    """A speculated task that never completes (backup attempts exhaust the
    retries) resolves its launches as *cancelled*, keeping the accounting
    identity for the whole-body path too."""
    clock = SimClock(auto_advance=False)
    rt = TaskRuntime(_edge_pilot(8), speculative_factor=3.0,
                     max_retries=1, monitor_interval_s=0.01, clock=clock)
    for f in rt.map(lambda ctx, x: x, range(6)):
        f.result(5)
    hung = threading.Event()

    def doomed(ctx):
        if ctx.attempt == 0:
            hung.set()
            ctx.clock.sleep(600.0)   # straggles → speculation fires
            return "slow"
        raise RuntimeError("backup blows up")   # → retries exhaust

    fut = rt.submit(doomed)
    assert hung.wait(5.0)
    with pytest.raises(TaskFailed):
        _drive(clock, fut).result(1)
    m = rt.metrics
    launches = m.counter("runtime.speculative_launches")
    assert launches >= 1
    assert m.counter("runtime.speculative_wins") == 0
    assert (m.counter("runtime.speculative_losses")
            + m.counter("runtime.speculative_cancelled") == launches)
    assert m.counter("runtime.speculative_cancelled") >= 1
    clock.close()
    rt.shutdown(wait=False)


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_placement_light_task_stays_on_edge():
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=8))
    eng = PlacementEngine()
    light = TaskProfile(flops=1e6, input_bytes=1e6, input_tier="edge")
    heavy = TaskProfile(flops=1e12, input_bytes=1e6, input_tier="edge")
    assert eng.place(light, [edge, cloud]).pilot.tier == "edge"
    assert eng.place(heavy, [edge, cloud]).pilot.tier == "cloud"


def test_placement_preference_and_memory_veto():
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1,
                                            memory_gb=4))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=1,
                                             memory_gb=44))
    eng = PlacementEngine()
    pref = TaskProfile(flops=1e6, preferred_tiers=("cloud",))
    assert eng.place(pref, [edge, cloud]).pilot.tier == "cloud"
    big = TaskProfile(flops=1e6, memory_gb=16.0)
    assert eng.place(big, [edge, cloud]).pilot.tier == "cloud"


# ---------------------------------------------------------------------------
# parameter service
# ---------------------------------------------------------------------------

def test_param_service_versioning():
    ps = ParameterService()
    v1 = ps.publish("m", {"w": np.ones(3)})
    v2 = ps.publish("m", {"w": np.ones(3) * 2})
    assert (v1, v2) == (1, 2)
    ver, tree = ps.fetch("m")
    assert ver == 2 and tree["w"][0] == 2
    assert ps.fetch_if_newer("m", 2) is None
    got = ps.fetch_if_newer("m", 1)
    assert got is not None and got[0] == 2


def test_param_service_publish_is_snapshot():
    ps = ParameterService()
    w = np.ones(3)
    ps.publish("m", {"w": w})
    w[:] = 99                      # mutate after publish
    assert ps.fetch("m")[1]["w"][0] == 1


def test_param_service_subscribe():
    ps = ParameterService()
    got = []
    ps.subscribe("m", lambda v, t: got.append(v))
    ps.publish("m", {"w": np.zeros(1)})
    ps.publish("m", {"w": np.zeros(1)})
    assert got == [1, 2]


# ---------------------------------------------------------------------------
# pipeline end-to-end + dynamism
# ---------------------------------------------------------------------------

def _mini_pipeline(n_workers=2, **kw):
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=n_workers))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=n_workers))
    rng = np.random.default_rng(0)
    return EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: rng.standard_normal((50, 4)),
        process_cloud_function_handler=lambda ctx, data=None:
            float(np.mean(data)),
        n_edge_devices=n_workers, **kw)


def test_pipeline_processes_all_messages():
    res = _mini_pipeline().run(n_messages=40, timeout_s=30)
    assert res.n_processed == 40
    assert len(res.results) == 40
    assert res.metrics.summary()["count"] == 40


def test_pipeline_hot_swap():
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    rng = np.random.default_rng(0)
    n_seen = []

    def slow_fn(ctx, data=None):
        n_seen.append(1)
        time.sleep(0.005)                 # keep the stream in flight
        return float(np.mean(data))

    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: rng.standard_normal((50, 4)),
        process_cloud_function_handler=slow_fn, n_edge_devices=2)
    swapped = []

    def new_fn(ctx, data=None):
        swapped.append(1)
        return -1.0

    def swap_when_halfway():
        while len(n_seen) < 10:
            time.sleep(0.002)
        pipe.replace_function("process_cloud", new_fn)

    threading.Thread(target=swap_when_halfway, daemon=True).start()
    res = pipe.run(n_messages=60, timeout_s=30)
    assert res.n_processed == 60
    assert swapped, "hot-swapped function never ran"
    assert any(r == -1.0 for r in res.results)


def test_pipeline_consumer_fault_recovers():
    fault = {"armed": True}
    lock = threading.Lock()
    rng = np.random.default_rng(0)
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))

    def flaky(ctx, data=None):
        with lock:
            if fault["armed"]:
                fault["armed"] = False
                raise RuntimeError("injected")
        return 0.0

    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: rng.standard_normal((10, 4)),
        process_cloud_function_handler=flaky, max_retries=2)
    res = pipe.run(n_messages=30, timeout_s=30)
    assert res.n_processed == 30           # nothing lost
    assert res.metrics.counter("runtime.task_errors") == 1
    assert res.metrics.counter("runtime.retries") == 1


def test_pipeline_runs_under_manual_simclock():
    """The threaded pipeline accepts a manually driven SimClock: a driver
    thread plays time while run() executes, metrics land on virtual
    timestamps, and shutdown doesn't hang on parked virtual sleepers."""
    clock = SimClock(auto_advance=False)
    pipe = _mini_pipeline(clock=clock)
    stop = threading.Event()

    def drive():
        while not stop.is_set():
            clock.advance(0.05)
            time.sleep(0.001)

    driver = threading.Thread(target=drive, daemon=True)
    driver.start()
    t0 = time.monotonic()
    try:
        res = pipe.run(n_messages=20, timeout_s=300.0)
    finally:
        stop.set()
        driver.join(5.0)
        clock.close()
    assert res.n_processed == 20
    assert time.monotonic() - t0 < 30.0     # no real-timeout stalls
    assert res.wall_s < 300.0               # virtual wall, not real
    assert res.metrics.summary()["count"] == 20


def test_threaded_run_rejects_auto_advance_clock():
    """Auto-advance virtual time belongs to SimExecutor; the threaded
    strategy (the default) refuses it at run time."""
    pipe = _mini_pipeline(clock=SimClock())      # construction is fine now
    with pytest.raises(ValueError):
        pipe.run(n_messages=4)


def test_sim_executor_requires_pipeline_clock():
    from repro.core import SimExecutor
    pipe = _mini_pipeline(clock=SimClock())
    with pytest.raises(ValueError):
        pipe.run(n_messages=4, scheduler=SimExecutor(clock=SimClock()))
    # and a wall-clock pipeline can't adopt a DES strategy
    with pytest.raises(ValueError):
        _mini_pipeline().run(n_messages=4, scheduler=SimExecutor())


def test_pipeline_wan_accounting():
    sh = WanShaper(bandwidth_bps=80e6, rtt_s=0.15, sleep=False)
    res = _mini_pipeline(wan_shaper=sh).run(n_messages=10, timeout_s=30)
    assert res.n_processed == 10
    # every message recorded a wan delay stamp
    lat = res.metrics.latencies("produced", "broker_in")
    assert len(lat) == 10


# ---------------------------------------------------------------------------
# elasticity
# ---------------------------------------------------------------------------

def test_autoscaler_scales_up_and_down():
    mgr = PilotManager()
    pilot = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    lag = {"v": 100}
    sc = AutoScaler(mgr, pilot, lag_fn=lambda: lag["v"],
                    policy=ScalePolicy(max_workers=8, lag_high=50,
                                       lag_low=5, cooldown_s=0.0))
    assert sc.step_once() == 4
    assert sc.step_once() == 8
    assert sc.step_once() is None          # at max
    lag["v"] = 0
    assert sc.step_once() == 4
    assert pilot.resource.n_workers == 4


def test_remesh_restart():
    mgr = PilotManager()
    n = mgr.free_devices
    p = mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=n))
    restored = {}

    def restore_fn(new_pilot):
        restored["mesh_size"] = new_pilot.mesh.size if new_pilot.mesh \
            else 0
        return {"step": 7}

    # device lost: restart on n-? — single-device container: reuse 0 free
    mgr.release(p)                      # free them to simulate survivors
    p2 = mgr.submit_pilot(ComputeResource(tier="cloud", n_devices=n))
    new_pilot, state = remesh_restart(mgr, p2, 0, restore_fn=restore_fn)
    assert state == {"step": 7}
    assert new_pilot.state == "active"


# ---------------------------------------------------------------------------
# broker log truncation (bounded-memory retention)
# ---------------------------------------------------------------------------

def test_truncation_reclaims_committed_prefix_keeps_absolute_offsets():
    b = Broker()
    t = b.create_topic("t", n_partitions=1, truncate_batch=4)
    g = ConsumerGroup(t)
    g.join("c0")
    for i in range(10):
        t.produce(np.array([i]))
    for _ in range(10):
        g.commit(g.poll("c0", timeout_s=1.0))
    # 10 committed in batches of 4: two chunks reclaimed, 2 retained
    assert t.truncated_msgs == 8
    assert t.log_start_offsets() == [8]
    assert t.end_offsets() == [10]          # absolute offsets unaffected
    assert [m.offset for m in t.partitions[0].log] == [8, 9]
    assert int(t.poll(0, 8).value()[0]) == 8
    with pytest.raises(KeyError):
        t.poll(0, 7)                        # below the log start: reclaimed
    # producing after truncation continues the absolute numbering
    m = t.produce(np.array([10]))
    assert m.offset == 10


def test_truncation_blocked_until_every_group_commits():
    """The group-minimum committed offset bounds reclamation: a lagging
    second group pins the log even though the first has committed all."""
    b = Broker()
    t = b.create_topic("t", n_partitions=1, truncate_batch=2)
    g1 = ConsumerGroup(t, group_id="g1")
    g2 = ConsumerGroup(t, group_id="g2")
    g1.join("a")
    g2.join("b")
    for i in range(8):
        t.produce(np.array([i]))
    for _ in range(8):
        g1.commit(g1.poll("a", timeout_s=1.0))
    assert t.truncated_msgs == 0            # g2 still at offset 0
    for _ in range(8):
        g2.commit(g2.poll("b", timeout_s=1.0))
    assert t.truncated_msgs == 8
    assert t.log_sizes() == [0]


def test_truncation_late_group_starts_at_log_start():
    """Kafka 'earliest' semantics against a truncated log: a group that
    joins after reclamation starts at the log start (not absolute 0) and
    replays exactly the retained tail."""
    b = Broker()
    t = b.create_topic("t", n_partitions=1, truncate_batch=3)
    g = ConsumerGroup(t)
    g.join("c0")
    for i in range(9):
        t.produce(np.array([i]))
    for _ in range(7):
        g.commit(g.poll("c0", timeout_s=1.0))
    assert t.log_start_offsets() == [6]
    late = ConsumerGroup(t, group_id="late")
    assert late.committed == [6]
    late.join("z")
    got = []
    for _ in range(3):
        m = late.poll("z", timeout_s=1.0)
        got.append(int(m.value()[0]))
        late.commit(m)
    assert got == [6, 7, 8]
    assert late.lag() == 0


def test_truncation_callback_reports_reclaimed_msg_ids():
    b = Broker()
    t = b.create_topic("t", n_partitions=2, truncate_batch=2)
    reclaimed = []
    t.on_truncate(lambda part, ids: reclaimed.append((part, list(ids))))
    g = ConsumerGroup(t)
    g.join("c0")
    produced = [t.produce(np.array([i])) for i in range(8)]
    for _ in range(8):
        g.commit(g.poll("c0", timeout_s=1.0))
    got_ids = {mid for _, ids in reclaimed for mid in ids}
    assert got_ids == {m.msg_id for m in produced}
    assert {p for p, _ in reclaimed} == {0, 1}


def test_truncation_disabled_and_no_group_cases():
    b = Broker()
    # retention off: logs grow, base pinned at 0
    t0 = b.create_topic("t0", n_partitions=1)
    g = ConsumerGroup(t0)
    g.join("c0")
    for i in range(6):
        t0.produce(np.array([i]))
    for _ in range(6):
        g.commit(g.poll("c0", timeout_s=1.0))
    assert t0.truncated_msgs == 0
    assert t0.log_start_offsets() == [0]
    assert t0.maybe_truncate(0) == 0
    # retention on but no consumer group yet: nothing is safe to reclaim
    t1 = b.create_topic("t1", n_partitions=1, truncate_batch=1)
    t1.produce(np.array([0]))
    assert t1.maybe_truncate(0) == 0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_truncation_churn_preserves_at_least_once(seed):
    """Seed-driven cousin of the hypothesis property test in
    test_properties.py (which needs the CI image): random poll/commit/
    crash/rejoin churn against a truncating topic never reclaims an
    uncommitted offset and still delivers every message at least once."""
    rng = np.random.default_rng(seed)
    n_msgs = int(rng.integers(10, 40))
    n_parts = int(rng.integers(1, 4))
    batch = int(rng.integers(1, 6))
    clock = SimClock()
    b = Broker(clock=clock)
    t = b.create_topic("t", n_partitions=n_parts, truncate_batch=batch)
    g = ConsumerGroup(t)
    consumers = ["c0", "c1"]
    for c in consumers:
        g.join(c)
    for i in range(n_msgs):
        t.produce(np.array([i]))
    seen, deliveries = set(), 0
    alive = list(consumers)
    for _ in range(40 * n_msgs + 400):
        starts = t.log_start_offsets()
        ends = t.end_offsets()
        for p in range(n_parts):
            assert starts[p] <= g.committed[p], \
                "truncation reclaimed an uncommitted offset"
            assert [m.offset for m in t.partitions[p].log] \
                == list(range(starts[p], ends[p]))
        if g.lag() == 0:
            break
        if len(alive) < len(consumers) and rng.random() < 0.2:
            back = [c for c in consumers if c not in alive][0]
            alive.append(back)
            g.join(back)
        cid = alive[int(rng.integers(0, len(alive)))]
        msg, _ = g.poll_nowait(cid)
        if msg is None:
            clock.advance(0.01)
            continue
        deliveries += 1
        seen.add(int(msg.value()[0]))
        if len(alive) > 1 and rng.random() < 0.25:
            # crash before the commit: the offset must survive truncation
            # and be redelivered after the rebalance
            alive.remove(cid)
            g.leave(cid)
        else:
            g.commit(msg)
    assert g.lag() == 0
    assert deliveries >= n_msgs          # at-least-once
    assert seen == set(range(n_msgs))    # every message delivered, no gaps


# ---------------------------------------------------------------------------
# streaming metrics (bounded-memory sketches)
# ---------------------------------------------------------------------------

class _Tick:
    """Bare now() callable with settable time (the seed clock API)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_streaming_registry_matches_exact_aggregates():
    """The same stamp stream through exact and streaming registries:
    counts/first/last/throughput/max agree exactly, percentiles agree to
    within the sketch's bucket width."""
    rng = np.random.default_rng(7)
    lats = rng.lognormal(mean=-2.0, sigma=1.0, size=2000)
    clocks = (_Tick(), _Tick())
    exact = MetricsRegistry(clocks[0])
    stream = MetricsRegistry(clocks[1], streaming=True)
    for i, lat in enumerate(lats):
        for clk, m in zip(clocks, (exact, stream)):
            clk.t = i * 0.01
            m.stamp(f"m{i}", "produced", bytes=100.0)
            clk.t = i * 0.01 + float(lat)
            m.stamp(f"m{i}", "processed", bytes=100.0)
    assert stream.pending_traces == 0          # all retired at `processed`
    assert stream.retired_traces == len(lats)
    se, ss = exact.summary(), stream.summary()
    assert se["count"] == ss["count"] == len(lats)
    np.testing.assert_allclose(ss["mean_s"], se["mean_s"], rtol=1e-9)
    assert ss["max_s"] == se["max_s"]
    for q in (0.5, 0.9, 0.95, 0.99):
        np.testing.assert_allclose(stream.percentile(q),
                                   exact.percentile(q), rtol=0.04)
    for ev in ("produced", "processed"):
        assert stream.event_count(ev) == exact.event_count(ev)
        assert stream.first_stamp(ev) == exact.first_stamp(ev)
        assert stream.last_stamp(ev) == exact.last_stamp(ev)
        assert stream.throughput(ev) == exact.throughput(ev)


def test_streaming_registry_refuses_per_message_views():
    m = MetricsRegistry(streaming=True)
    m.stamp("a", "produced")
    m.stamp("a", "processed")
    with pytest.raises(RuntimeError):
        m.latencies()


def test_latency_sketch_percentile_bounds():
    rng = np.random.default_rng(3)
    xs = rng.exponential(scale=0.1, size=5000)
    sk = LatencySketch()
    for x in xs:
        sk.add(float(x))
    assert sk.count == len(xs)
    assert sk.percentile(0.0) == float(np.min(xs))     # exact extremes
    assert sk.percentile(1.0) == float(np.max(xs))
    srt = np.sort(xs)
    for q in (0.25, 0.5, 0.75, 0.95, 0.99):
        est = sk.percentile(q)
        ref = float(srt[min(len(xs) - 1, int(q * len(xs)))])
        assert ref <= est <= ref * (1.0 + 2 * 10 ** (1 / sk.PER_DECADE))
        np.testing.assert_allclose(est, ref, rtol=0.04)
    empty = LatencySketch()
    assert empty.percentile(0.5) == 0.0


def test_streaming_fifo_window_bounds_pending_traces():
    """Traces that never reach `processed` (intermediate hops) leave
    through the max_pending FIFO window instead of accumulating."""
    m = MetricsRegistry(streaming=True, max_pending=10)
    for i in range(100):
        m.stamp(f"m{i}", "produced")
    assert m.pending_traces == 10
    assert m.retired_traces == 90
    # produced-only traces have no spans: nothing lands in the sketches
    assert m.summary() == {"count": 0}
    # ...but their event stats were still counted at the stamp
    assert m.event_count("produced") == 100


def test_pipeline_streaming_metrics_and_truncation_end_to_end():
    """The real threaded pipeline with bounded-memory both ways on:
    sketch-backed metrics and broker-log retention. Everything still
    processes, the summary comes off the sketches, and the topic log was
    actually reclaimed while the run was in flight."""
    m = MetricsRegistry(streaming=True)
    pipe = _mini_pipeline(metrics=m, truncate_logs=8)
    res = pipe.run(n_messages=40, timeout_s=30)
    assert res.n_processed == 40
    assert res.metrics.summary()["count"] == 40
    assert res.metrics.percentile(0.95) > 0.0
    assert sum(t.truncated_msgs for t in pipe._topics) > 0
    with pytest.raises(RuntimeError):
        res.metrics.latencies()
