"""Training integration: loss decreases, microbatch-accumulation equivalence,
checkpoint resume determinism, optimizer behaviours, compression round trip
under shard_map."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import make_batch_iterator
from repro.launch.train import train_loop
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import step as TS


def test_loss_decreases_short_run(tmp_path):
    cfg = get_arch("internlm2-1.8b").reduced()
    tc = TS.TrainConfig(lr=1e-3, warmup=5, total_steps=40)
    _, _, hist = train_loop(cfg, tc, steps=40, batch=4, seq_len=64,
                            log_every=5, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_grad_accum_equals_full_batch():
    """microbatches=2 must match microbatches=1 on the same global batch."""
    cfg = get_arch("mamba2-130m").reduced()
    inputs = {"tokens": jax.random.randint(jax.random.key(0), (4, 32), 0,
                                           cfg.vocab_size),
              "labels": jax.random.randint(jax.random.key(1), (4, 32), 0,
                                           cfg.vocab_size)}
    outs = {}
    for m in (1, 2):
        tc = TS.TrainConfig(microbatches=m)
        params, state = TS.init_train_state(jax.random.key(2), cfg, tc)
        step = jax.jit(TS.make_train_step(cfg, tc))
        p2, _, metrics = step(params, state, inputs)
        outs[m] = (p2, float(metrics["loss"]))
    assert abs(outs[1][1] - outs[2][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][0]),
                    jax.tree.leaves(outs[2][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=3e-4)


def test_checkpoint_resume_bit_exact(tmp_path):
    """Stop at step 10, resume to 20 == straight run to 20."""
    cfg = get_arch("mamba2-130m").reduced()
    tc = TS.TrainConfig(lr=1e-3, warmup=2, total_steps=20)
    d1 = str(tmp_path / "a")
    train_loop(cfg, tc, steps=10, batch=2, seq_len=32, ckpt_dir=d1,
               ckpt_every=10, log=lambda *_: None)
    p_resumed, _, _ = train_loop(cfg, tc, steps=20, batch=2, seq_len=32,
                                 ckpt_dir=d1, ckpt_every=10,
                                 log=lambda *_: None)
    p_straight, _, _ = train_loop(cfg, tc, steps=20, batch=2, seq_len=32,
                                  ckpt_dir=None, log=lambda *_: None)
    for a, b in zip(jax.tree.leaves(p_resumed),
                    jax.tree.leaves(p_straight)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-6)


def test_adamw_and_adafactor_reduce_loss():
    def quad_loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name, lambda s: 0.1)
        params = {"w": jnp.zeros((4, 4))}
        state = opt.init(params)
        losses = []
        for step in range(50):
            g = jax.grad(quad_loss)(params)
            upd, state = opt.update(g, state, params, step)
            params = jax.tree.map(lambda p, u: p + u, params, upd)
            losses.append(float(quad_loss(params)))
        assert losses[-1] < losses[0] * 0.1, name


def test_adafactor_state_is_factored():
    opt = make_optimizer("adafactor", lambda s: 1e-3)
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}
    st = opt.init(params)
    assert st["v"]["w"]["vr"].shape == (16,)
    assert st["v"]["w"]["vc"].shape == (8,)
    assert st["v"]["b"]["v"].shape == (8,)


def test_compressed_psum_shard_map_single_device():
    """int8 psum under shard_map on a 1-element 'pod' axis: exact identity
    up to quantization error; error feedback captures the residual."""
    from repro.optim.compression import compressed_psum
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                    jnp.float32)

    def body(g):
        avg, err = compressed_psum(g, "pod", jnp.zeros_like(g))
        return avg, err

    from jax.sharding import PartitionSpec as P
    from repro import compat
    fn = compat.shard_map(body, mesh=mesh, in_specs=P(),
                          out_specs=(P(), P()), check_vma=False)
    avg, err = fn(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(avg - g))) <= scale / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(avg + err), np.asarray(g),
                               atol=1e-6)


def test_train_driver_cli(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "mamba2-130m", "--reduced", "--steps", "4",
               "--batch", "2", "--seq", "32",
               "--ckpt-dir", str(tmp_path / "c")])
    assert rc == 0
    assert os.path.isdir(tmp_path / "c" / "step_4")
