"""Standalone PP-correctness check, run in a subprocess with a forced
2-device host (tests/test_pipeline.py drives it).

Compares the GPipe pipeline loss/step against the standard (non-PP)
train step on identical params and batch: the pipeline is just a
re-scheduling, so the loss must match to fp tolerance and one optimizer
step must produce the same parameters.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_arch
from repro.models import transformer as T
from repro.train import step as TS
from repro.train.pipeline import (PipelineConfig, init_pp_state,
                                  make_pp_train_step)


def main():
    cfg = get_arch("internlm2-1.8b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    tc = TS.TrainConfig(lr=1e-3, warmup=1, total_steps=10)
    pc = PipelineConfig(n_stages=2, microbatches=2, stage_axis="pod")
    mesh = jax.make_mesh((2,), ("pod",))
    rules = T.ShardRules(batch=(), model=None, fsdp=None,
                         moe_groups=1)

    key = jax.random.key(0)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (4, 32), 0,
                                     cfg.vocab_size),
    }

    # --- reference: plain train step (no sharding, 1 device semantics) ---
    ref_params, ref_state = TS.init_train_state(key, cfg, tc)
    ref_step = jax.jit(TS.make_train_step(cfg, tc))
    ref_p2, _, ref_metrics = ref_step(ref_params, ref_state, batch)
    ref_loss = float(ref_metrics["loss"])

    # --- pipeline: same init, blocks reshaped to (S, L/S, ...) ---
    pp_params, pp_state = init_pp_state(key, cfg, tc, pc)
    with compat.set_mesh(mesh):
        pp_step = make_pp_train_step(cfg, tc, pc, rules, mesh)
        pp_p2, _, pp_metrics = pp_step(pp_params, pp_state, batch)
    pp_loss = float(pp_metrics["loss"])

    print(f"ref_loss={ref_loss:.6f} pp_loss={pp_loss:.6f}")
    assert abs(ref_loss - pp_loss) < 2e-4, (ref_loss, pp_loss)

    # parameters after one step must match (reshape blocks back)
    pp_blocks_flat = jax.tree.map(
        lambda x: np.asarray(x).reshape(-1, *x.shape[2:]),
        pp_p2["blocks"])
    ref_blocks = jax.tree.map(np.asarray, ref_p2["blocks"])
    flat_pp, _ = jax.tree.flatten(pp_blocks_flat)
    flat_ref, _ = jax.tree.flatten(ref_blocks)
    for a, b in zip(flat_pp, flat_ref):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(pp_p2["head"]),
                               np.asarray(ref_p2["head"]),
                               atol=5e-4, rtol=5e-3)
    print("PP == reference: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
