"""Roofline machinery: trip-count-aware HLO cost parsing validated against
analytically known workloads, collective accounting, report rendering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import Roofline, model_flops
from repro.roofline.hlo_cost import HloCostModel


def _cost(fn, *args):
    return HloCostModel(jax.jit(fn).lower(*args).compile().as_text())


def test_scan_flops_weighted_by_trip_count():
    N, T = 256, 12
    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    w = jax.ShapeDtypeStruct((T, N, N), jnp.float32)

    def scan_fn(h, ws):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, h, ws)
        return h

    m = _cost(scan_fn, x, w)
    expected = T * 2 * N ** 3
    assert abs(m.dot_flops_only() - expected) / expected < 0.01


def test_nested_scan_flops():
    N, T1, T2 = 128, 3, 5
    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    w = jax.ShapeDtypeStruct((T1, T2, N, N), jnp.float32)

    def nested(h, wss):
        def outer(h, ws):
            def inner(h2, wi):
                return h2 @ wi, None
            h, _ = jax.lax.scan(inner, h, ws)
            return h, None
        h, _ = jax.lax.scan(outer, h, wss)
        return h

    m = _cost(nested, x, w)
    expected = T1 * T2 * 2 * N ** 3
    assert abs(m.dot_flops_only() - expected) / expected < 0.01


def test_unrolled_matches_scan():
    N, T = 128, 4
    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    w = jax.ShapeDtypeStruct((T, N, N), jnp.float32)

    def unrolled(h, ws):
        for i in range(T):
            h = h @ ws[i]
        return h

    def scanned(h, ws):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, h, ws)
        return h

    mu = _cost(unrolled, x, w)
    ms = _cost(scanned, x, w)
    assert abs(mu.dot_flops_only() - ms.dot_flops_only()) \
        / mu.dot_flops_only() < 0.01


def test_bytes_scale_with_trip_count():
    """Scanned matmul chain must move ~T x the weights+activations."""
    N, T = 256, 16
    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    w = jax.ShapeDtypeStruct((T, N, N), jnp.float32)

    def scanned(h, ws):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, h, ws)
        return h

    m = _cost(scanned, x, w)
    ideal = T * (3 * N * N * 4)          # read h, read w_i, write h
    got = m.bytes_accessed()
    assert got >= 0.9 * ideal            # must not undercount the loop
    assert got <= 4.0 * ideal            # and stay a sane upper bound


def test_grad_flops_about_3x_forward():
    N = 256
    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    w = jax.ShapeDtypeStruct((N, N), jnp.float32)

    def fwd(x, w):
        return jnp.sum((x @ w) ** 2)

    def bwd(x, w):
        return jax.grad(fwd, argnums=1)(x, w)

    f = _cost(fwd, x, w).dot_flops_only()
    g = _cost(bwd, x, w).dot_flops_only()
    # grad-of-matmul = 1 fwd + 1 bwd matmul here (x is not differentiated)
    assert g >= 1.9 * f


def test_roofline_bottleneck_classification():
    r = Roofline(arch="a", shape="s", mesh="16x16", chips=256,
                 hlo_flops=1e18, hlo_bytes=1e12, collective_bytes=1e12,
                 model_flops=9e17)
    assert r.bottleneck == "compute"
    assert r.roofline_fraction == 1.0
    r2 = Roofline(arch="a", shape="s", mesh="16x16", chips=256,
                  hlo_flops=1e15, hlo_bytes=1e12, collective_bytes=1e15,
                  model_flops=9e14)
    assert r2.bottleneck == "collective"
    assert r2.roofline_fraction < 0.1


def test_model_flops_formula():
    from repro.configs import SHAPES, get_arch
    cfg = get_arch("internlm2-1.8b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    expected = 6 * cfg.active_param_count * 4096 * 256
    assert abs(mf - expected) / expected < 1e-6
    # decode counts one token per sequence
    mfd = model_flops(cfg, SHAPES["decode_32k"])
    assert abs(mfd - 2 * cfg.active_param_count * 128) / mfd < 1e-6


def test_collective_bytes_from_sharded_matmul():
    """A TP matmul with a contracted sharded dim must show an all-reduce
    (or reduce-scatter) with ~result-size bytes."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dryrun covers the 512-way case)")
