"""Pipeline-parallelism correctness (subprocess: needs its own forced
2-device host before jax init)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_gpipe_matches_reference():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "pp_check.py")],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    assert "PP == reference: OK" in r.stdout
