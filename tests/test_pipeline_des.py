"""DES-native pipeline execution: the *genuine* EdgeToCloudPipeline under
``run(scheduler=SimExecutor(...))`` — determinism across repeated runs,
event-driven (non-polling) consumers, WAN visibility, crash + silent node
loss with heartbeat detection, and the lag-driven autoscaler in the loop."""
import numpy as np

from repro.core import (ComputeResource, EdgeToCloudPipeline,
                        MetricsRegistry, PilotManager, ScalePolicy,
                        SimClock, SimExecutor, WanShaper)
from repro.core.elastic import AutoScaler
from repro.sim.scenarios import (AUTOENCODER, KMEANS, FailureSpec, Scenario,
                                 build_pipeline, run_scenario)


def _des_pipeline(n_devices=2, n_messages=20, *, service_model=None,
                  wan_shaper=None, cloud_consumers=None,
                  heartbeat_timeout_s=1e9, process=None, **exec_kw):
    """A tiny real pipeline on an auto-advance SimClock + its executor."""
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=n_devices))
    cloud = mgr.submit_pilot(ComputeResource(
        tier="cloud", n_workers=cloud_consumers or n_devices))
    payload = np.arange(64, dtype=np.float64)
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: payload,
        process_cloud_function_handler=(
            process or (lambda ctx, data=None: float(np.sum(data)))),
        n_edge_devices=n_devices, cloud_consumers=cloud_consumers,
        wan_shaper=wan_shaper, metrics=metrics, clock=clock,
        heartbeat_timeout_s=heartbeat_timeout_s)
    ex = SimExecutor(clock=clock, service_model=service_model, **exec_kw)
    return pipe, ex, mgr, clock


def _fingerprint(res):
    """Everything that must be bit-identical across repeated runs."""
    lat = res.metrics.latencies("produced", "processed")
    return (res.n_processed, res.n_produced, res.wall_s, tuple(sorted(lat)),
            tuple((e["kind"], e["t"]) for e in res.metrics.events()
                  if e["kind"].startswith(("consumer_", "autoscale"))))


# ---------------------------------------------------------------------------
# the acceptance gate: real pipeline, bit-identical across 3 runs
# ---------------------------------------------------------------------------

def test_real_pipeline_bit_identical_across_three_runs():
    def one():
        svc = lambda stage, ctx, data: 0.02 if stage == "produce" else 0.05
        pipe, ex, _, _ = _des_pipeline(
            n_devices=3, service_model=svc,
            wan_shaper=WanShaper(bandwidth_bps=8e6, rtt_s=0.1, sleep=False))
        return _fingerprint(
            pipe.run(n_messages=30, timeout_s=600.0, scheduler=ex))

    a, b, c = one(), one(), one()
    assert a == b == c
    assert a[0] == 30                        # all processed
    assert a[2] > 0.0                        # virtual time actually passed


def test_scenario_bit_identical_across_three_runs():
    sc = Scenario(model=AUTOENCODER, placement="hybrid", wan_band="50mbit",
                  n_messages=24, seed=3,
                  failures=(FailureSpec(at_s=1.0, consumer_idx=0),))
    rows = [run_scenario(sc).row() for _ in range(3)]
    assert rows[0] == rows[1] == rows[2]


# ---------------------------------------------------------------------------
# semantics under the DES
# ---------------------------------------------------------------------------

def test_des_results_and_metrics_match_threaded_semantics():
    """The DES run produces real results from the real process function,
    with linked per-hop metrics, exactly like the threaded strategy."""
    pipe, ex, _, _ = _des_pipeline(n_devices=2, n_messages=16)
    res = pipe.run(n_messages=16, timeout_s=60.0, scheduler=ex)
    assert res.n_processed == 16 and res.n_produced == 16
    assert len(res.results) == 16
    assert all(r == float(np.sum(np.arange(64.0))) for r in res.results)
    assert res.metrics.summary()["count"] == 16
    assert res.per_hop()                      # linked hop decomposition


def test_des_consumers_are_event_driven_not_polling():
    """No idle ticking: the event count stays within a small constant per
    message (the old harness idle-ticked on a 50 ms cadence; a slow
    producer would have generated thousands of poll events)."""
    svc = lambda stage, ctx, data: 5.0 if stage == "produce" else 0.0
    pipe, ex, _, _ = _des_pipeline(n_devices=1, service_model=svc)
    res = pipe.run(n_messages=8, timeout_s=600.0, scheduler=ex)
    assert res.n_processed == 8
    assert res.wall_s >= 40.0                 # 8 messages × 5 s service
    # generous bound: spawn/park/wake/service/monitor events, not 50 ms polls
    assert ex.sched.executed < 400


def test_des_honors_wan_visibility_per_message():
    """Every end-to-end latency includes at least the one-way WAN latency
    (messages are invisible until their token-bucket ready time)."""
    pipe, ex, _, _ = _des_pipeline(
        n_devices=2,
        wan_shaper=WanShaper(bandwidth_bps=8e6, rtt_s=0.2, sleep=False))
    res = pipe.run(n_messages=12, timeout_s=60.0, scheduler=ex)
    lat = res.metrics.latencies("produced", "processed")
    assert len(lat) == 12
    assert min(lat) >= 0.1                    # rtt/2 one-way floor


def test_des_process_error_retries_and_recovers():
    boom = {"armed": True}

    def flaky(ctx, data=None):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected")
        return 0.0

    pipe, ex, _, _ = _des_pipeline(n_devices=2, process=flaky)
    res = pipe.run(n_messages=20, timeout_s=60.0, scheduler=ex)
    assert res.n_processed == 20              # nothing lost
    assert res.metrics.counter("runtime.task_errors") == 1
    assert res.metrics.counter("runtime.retries") == 1


def test_des_silent_node_loss_detected_by_heartbeat_monitor():
    """A consumer that goes dark (no exception, no group.leave) is detected
    by the DES heartbeat monitor, rebalanced out, and its partition's
    messages are redelivered to the relaunched member."""
    pipe, ex, _, _ = _des_pipeline(
        n_devices=2, heartbeat_timeout_s=2.0, monitor_interval_s=0.25,
        crash_plan=(FailureSpec(at_s=0.1, consumer_idx=0,
                                restart_after_s=None, kind="silent"),),
        service_model=lambda stage, ctx, data:
            0.05 if stage == "produce" else 0.0)
    res = pipe.run(n_messages=20, timeout_s=120.0, scheduler=ex)
    assert res.n_processed == 20
    assert res.metrics.events("consumer_lost")
    assert res.metrics.counter("runtime.retries") >= 1
    # loss is only detectable after the heartbeat timeout elapses
    assert res.wall_s > 2.0


def test_des_long_wan_wait_is_not_a_heartbeat_loss():
    """Waiting out a slow WAN is framework-idle, not a hung task: with
    13 s/message serialization and a 3 s heartbeat timeout, no consumer
    may be falsely declared lost (regression: the ready_at wait used to
    bypass the parked-wait bookkeeping the monitor skips)."""
    pipe, ex, _, _ = _des_pipeline(
        n_devices=1, heartbeat_timeout_s=3.0, monitor_interval_s=0.25,
        wan_shaper=WanShaper(bandwidth_bps=1e6, rtt_s=0.15, sleep=False))
    pipe.replace_function("produce",
                          lambda ctx: np.zeros(200_000, np.float64))
    res = pipe.run(n_messages=6, timeout_s=200.0, scheduler=ex)
    assert res.n_processed == 6
    assert not res.metrics.events("consumer_lost")
    assert res.metrics.counter("runtime.task_errors") == 0


def test_des_silent_loss_mid_service_releases_dedup_reservation():
    """A consumer that goes dark *while processing* holds a dedup
    reservation its generator can never release; the executor must free
    it so the redelivery is processed, not dropped as a duplicate."""
    pipe, ex, _, _ = _des_pipeline(
        n_devices=1, heartbeat_timeout_s=2.0, monitor_interval_s=0.25,
        crash_plan=(FailureSpec(at_s=0.3, consumer_idx=0,
                                restart_after_s=None, kind="silent"),),
        service_model=lambda stage, ctx, data:
            0.5 if stage == "process_cloud" else 0.01)
    res = pipe.run(n_messages=10, timeout_s=120.0, scheduler=ex)
    assert res.n_processed == 10              # nothing lost to the leak
    assert res.metrics.events("consumer_lost")
    assert res.wall_s < 60.0                  # no full-timeout stall


def test_des_crash_injection_rebalances_and_restarts():
    pipe, ex, _, _ = _des_pipeline(
        n_devices=3,
        crash_plan=(FailureSpec(at_s=0.2, consumer_idx=1,
                                restart_after_s=0.5),),
        # slow cloud stage so the run is still in flight at restart time
        service_model=lambda stage, ctx, data:
            0.05 if stage == "produce" else 0.3)
    res = pipe.run(n_messages=30, timeout_s=120.0, scheduler=ex)
    assert res.n_processed == 30
    assert res.metrics.events("consumer_crashed")
    assert res.metrics.events("consumer_restarted")


# ---------------------------------------------------------------------------
# autoscaler in the loop (satellite): lag spike → up → cooldown → down
# ---------------------------------------------------------------------------

def _autoscale_run():
    pipe, ex, mgr, clock = _des_pipeline(
        n_devices=4, cloud_consumers=1,
        # two-phase load: three devices burst at t=0 (lag spikes → scale
        # up), the fourth boots late so the pool drains and idles first
        # (lag → 0 → cooldown → scale down) before the second burst
        producer_offsets=(0.0, 0.0, 0.0, 25.0),
        service_model=lambda stage, ctx, data:
            0.01 if stage == "produce" else 1.0)
    scaler = AutoScaler(
        mgr, pipe.pilot_cloud, lag_fn=pipe.current_lag,
        policy=ScalePolicy(max_workers=4, min_workers=1,
                           lag_high=6, lag_low=2, cooldown_s=3.0),
        metrics=pipe.metrics, clock=clock)
    ex.autoscaler = scaler
    ex.autoscale_interval_s = 0.5
    res = pipe.run(n_messages=48, timeout_s=600.0, scheduler=ex)
    return res, scaler


def test_autoscaler_in_the_loop_scales_up_then_down():
    res, scaler = _autoscale_run()
    assert res.n_processed == 48
    ups = [h for h in scaler.history if h["to_workers"] > h["from_workers"]]
    downs = [h for h in scaler.history
             if h["to_workers"] < h["from_workers"]]
    assert ups and downs                      # spike → up, drain → down
    # cooldown honored: consecutive resizes ≥ cooldown_s apart
    ts = [h["t"] for h in scaler.history]
    assert all(b - a >= 3.0 for a, b in zip(ts, ts[1:]))
    # the pool actually followed the resizes (new members joined the group)
    assert res.metrics.events("consumer_spawned")
    assert res.metrics.events("consumer_retired")


def test_autoscaler_resize_timestamps_bit_identical_across_runs():
    histories = [_autoscale_run()[1].history for _ in range(3)]
    assert histories[0] == histories[1] == histories[2]
    assert len(histories[0]) >= 2


def test_scenario_autoscale_wiring():
    """Scenario-level autoscale: the policy rides through build_pipeline
    and the result reports the (deterministic) resize trace."""
    sc = Scenario(model=AUTOENCODER, placement="cloud", wan_band="100mbit",
                  n_messages=32, n_consumers=1,
                  autoscale=ScalePolicy(max_workers=4, min_workers=1,
                                        lag_high=6, lag_low=2,
                                        cooldown_s=2.0),
                  autoscale_interval_s=0.5)
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.n_processed == 32
    assert a.autoscale_events and a.autoscale_events == b.autoscale_events
    assert a.row() == b.row()


def test_build_pipeline_exposes_real_objects():
    pipe, ex, mgr = build_pipeline(Scenario(model=KMEANS, n_messages=8))
    assert isinstance(pipe, EdgeToCloudPipeline)
    res = pipe.run(n_messages=8, timeout_s=3600.0, scheduler=ex)
    assert res.n_processed == 8
