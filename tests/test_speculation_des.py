"""DES straggler speculation: SimExecutor mirrors
``TaskRuntime.speculative_factor`` under virtual time — a Service charge
running past ``factor × trailing median`` spawns a backup draw racing the
primary as scheduled events, first completion wins, with explicit
win/loss/cancel accounting that is bit-identical across runs.

Speculation is capacity-aware (Dask-style work stealing): the backup
occupies a *different, idle* consumer slot of the same stage — the stolen
stage-mate stops taking new messages until the race resolves, and when no
stage-mate is idle the backup is skipped
(``runtime.speculative_no_capacity``)."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ComputeResource, EdgeToCloudPipeline,
                        MetricsRegistry, PilotManager, SimClock,
                        SimExecutor)
from repro.core.executor import SpeculationStats
from repro.cost import CostModel
from repro.sim.scenarios import (KMEANS, Scenario, run_scenario)

HEAVY = dataclasses.replace(KMEANS, sigma=0.8)   # heavy tail: backups win


def _spec_scenario(factor, *, sigma=KMEANS.sigma, model=KMEANS,
                   n_messages=48, seed=0):
    return Scenario(model=model, placement="cloud", wan_band="100mbit",
                    n_messages=n_messages, seed=seed, service_sigma=sigma,
                    speculative_factor=factor)


# ---------------------------------------------------------------------------
# determinism goldens: seeded noise → bit-identical accounting ×3
# ---------------------------------------------------------------------------

def test_speculation_accounting_bit_identical_across_three_runs():
    rows = [run_scenario(_spec_scenario(1.2)).row() for _ in range(3)]
    assert rows[0] == rows[1] == rows[2]
    r = rows[0]
    assert r["spec_launches"] > 0             # stragglers actually raced
    assert (r["spec_wins"] + r["spec_losses"] + r["spec_cancelled"]
            == r["spec_launches"])            # every race resolves
    assert r["processed"] == 48               # speculation loses no data


def test_speculation_win_loss_golden_counts():
    """Numeric pins (pure virtual-time arithmetic — machine-independent):
    the calibrated k-means sigma at factor 1.2, and the heavy-tailed
    variant where backups genuinely win races.  Capacity-aware work
    stealing launches fewer backups than the historical same-slot race
    (producers never idle, so ``produce`` charges no longer speculate,
    and a busy stage skips the launch): the skips are accounted in
    ``runtime.speculative_no_capacity``."""
    r = run_scenario(_spec_scenario(1.2))
    assert (r.spec_launches, r.spec_wins, r.spec_losses) == (11, 0, 11)
    assert r.metrics.counter("runtime.speculative_no_capacity") > 0
    h = run_scenario(_spec_scenario(1.2, sigma=None, model=HEAVY,
                                    n_messages=64))
    assert h.spec_launches > 0 and h.spec_wins > 0 and h.spec_losses > 0
    assert (h.spec_launches, h.spec_wins, h.spec_losses) == (24, 10, 14)


def test_no_noise_means_no_speculation():
    """Regression pin: with sigma=0 every charge equals the median, so no
    charge ever outlives ``factor × median`` (factor ≥ 1) — zero backup
    launches, and the run is identical to speculation-off."""
    quiet = run_scenario(_spec_scenario(1.5, sigma=0.0))
    assert quiet.spec_launches == 0
    assert quiet.spec_wins == quiet.spec_losses == 0
    off = run_scenario(_spec_scenario(0.0, sigma=0.0))
    assert quiet.row() == off.row()


def test_lower_factor_speculates_at_least_as_much():
    """Monotonicity: a lower speculative_factor fires the straggler check
    earlier, so it can only launch ≥ as many backups."""
    launches = [run_scenario(_spec_scenario(f)).spec_launches
                for f in (1.05, 1.2, 1.5, 2.0, 1e9)]
    assert launches == sorted(launches, reverse=True)
    assert launches[0] > 0                    # the aggressive end fires
    assert launches[-1] == 0                  # the inert end never does


def test_factor_zero_and_missing_service_model_disable_speculation():
    r = run_scenario(_spec_scenario(0.0))
    assert r.spec_launches == 0
    # and the executor never builds a tracker without a service model
    clock = SimClock()
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=2, metrics=MetricsRegistry(clock=clock),
        clock=clock, speculative_factor=1.2)
    ex = SimExecutor(clock=clock)             # no service model
    res = pipe.run(n_messages=8, timeout_s=60.0, scheduler=ex)
    assert res.n_processed == 8
    assert ex.speculation is None
    assert res.metrics.counter("runtime.speculative_launches") == 0


def test_executor_factor_overrides_pipeline_factor():
    """SimExecutor(speculative_factor=...) wins over the pipeline's knob
    (same precedence as every other executor-level override)."""
    clock = SimClock()
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=2, metrics=MetricsRegistry(clock=clock),
        clock=clock, speculative_factor=0.0)
    service = CostModel().service_model(
        {"produce": 0.01, "process_cloud": 0.2}, sigma=0.6, seed=7)
    ex = SimExecutor(clock=clock, service_model=service,
                     speculative_factor=1.1)
    res = pipe.run(n_messages=24, timeout_s=600.0, scheduler=ex)
    assert res.n_processed == 24
    assert res.metrics.counter("runtime.speculative_launches") > 0


def test_speculation_shortens_heavy_tail_makespan():
    """The point of backup tasks: on the *compute-bound* autoencoder
    under heavy-tailed service noise, first-completion-wins cuts the
    straggler tail — virtual makespan with speculation < without, at
    every seed (k-means cloud cells are WAN-bound: sub-millisecond
    compute charges give speculation nothing to win).  The surplus
    consumers (4 consumers over 2 partitions) are the idle capacity the
    work-stealing backups run on."""
    from repro.sim.scenarios import AUTOENCODER
    heavy_ae = dataclasses.replace(AUTOENCODER, sigma=0.8)
    for seed in range(3):
        kw = dict(model=heavy_ae, placement="cloud", wan_band="100mbit",
                  n_messages=32, n_devices=2, n_consumers=4,
                  service_sigma=None, seed=seed)
        slow = run_scenario(Scenario(**kw))
        fast = run_scenario(Scenario(**kw, speculative_factor=1.3))
        assert fast.spec_wins > 0
        assert fast.makespan_s < slow.makespan_s


def test_speculation_deterministic_under_silent_loss_injection():
    """Crash injection and speculation compose: the run stays
    bit-deterministic, loses nothing, and the accounting identity
    holds."""
    from repro.sim.scenarios import FailureSpec
    sc = Scenario(model=HEAVY, placement="cloud", wan_band="100mbit",
                  n_messages=32, n_devices=2, n_consumers=2,
                  service_sigma=None, speculative_factor=1.05,
                  failures=(FailureSpec(at_s=1.0, consumer_idx=0,
                                        restart_after_s=1.0,
                                        kind="silent"),))
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.row() == b.row()                 # deterministic under injection
    assert a.n_processed == 32                # nothing lost
    assert a.spec_launches > 0
    assert (a.spec_wins + a.spec_losses + a.spec_cancelled
            == a.spec_launches)


def test_speculation_race_unresolved_at_run_end_counts_cancelled():
    """A backup race still in flight when the run ends resolves as
    *cancelled* — never a phantom win/loss, so the accounting identity
    survives truncated runs.  The second consumer (no partition of its
    own) is the idle slot the backup steals."""
    clock = SimClock()
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=1, cloud_consumers=2,
        metrics=MetricsRegistry(clock=clock), clock=clock,
        heartbeat_timeout_s=1e9)
    # three 1 s charges warm the median, then a 100 s straggler whose
    # backup also draws 100 s: the race cannot resolve before the 10 s
    # run deadline
    charges = iter([1.0, 1.0, 1.0] + [100.0] * 10)

    def service(stage, ctx, payload):
        return next(charges) if stage == "process_cloud" else 0.0

    ex = SimExecutor(clock=clock, service_model=service,
                     speculative_factor=1.5)
    res = pipe.run(n_messages=4, timeout_s=10.0, scheduler=ex)
    assert res.n_processed == 3               # the straggler never lands
    m = res.metrics
    assert m.counter("runtime.speculative_launches") == 1
    assert m.counter("runtime.speculative_cancelled") == 1
    assert m.counter("runtime.speculative_wins") == 0
    assert m.counter("runtime.speculative_losses") == 0


def test_threaded_explicit_zero_disables_all_speculation():
    """ThreadedExecutor(speculative_factor=0.0) must fully disable
    speculation even when the pipeline's own factor is nonzero — both
    the charge-level race and TaskRuntime's whole-body backups (same
    override precedence as SimExecutor)."""
    from repro.core import ThreadedExecutor
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=2, speculative_factor=1.2)
    service = CostModel().service_model(
        {"produce": 0.001, "process_cloud": 0.004}, sigma=0.6, seed=3)
    ex = ThreadedExecutor(service_model=service, speculative_factor=0.0)
    res = pipe.run(n_messages=16, timeout_s=60.0, scheduler=ex)
    assert res.n_processed == 16
    assert ex.speculation is None
    assert res.metrics.counter("runtime.speculative_launches") == 0


# ---------------------------------------------------------------------------
# capacity-aware work stealing (ROADMAP follow-up)
# ---------------------------------------------------------------------------

def _steal_pipeline(cloud_workers):
    """1 partition, ``cloud_workers`` consumers: every consumer beyond
    the first owns no partition and parks — pure idle steal capacity."""
    clock = SimClock()
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=cloud_workers))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=1, cloud_consumers=cloud_workers,
        metrics=MetricsRegistry(clock=clock), clock=clock,
        heartbeat_timeout_s=1e9)
    return pipe, clock


def test_backup_steals_idle_slot_and_wins():
    """With an idle stage-mate, the straggler's backup runs on the stolen
    slot and (drawing a short service time) wins the race — the
    effective charge is threshold + backup, far under the straggler."""
    pipe, clock = _steal_pipeline(cloud_workers=2)
    charges = iter([1.0, 1.0, 1.0, 100.0, 1.0])   # straggler, then backup

    def service(stage, ctx, payload):
        return next(charges) if stage == "process_cloud" else 0.0

    ex = SimExecutor(clock=clock, service_model=service,
                     speculative_factor=1.5)
    res = pipe.run(n_messages=4, timeout_s=600.0, scheduler=ex)
    assert res.n_processed == 4
    m = res.metrics
    assert m.counter("runtime.speculative_launches") == 1
    assert m.counter("runtime.speculative_wins") == 1
    assert m.counter("runtime.speculative_no_capacity") == 0
    # threshold (1.5 × 1 s) + backup (1 s) ≈ 2.5 s, not the 100 s draw
    assert res.wall_s < 10.0


def test_no_idle_slot_means_no_backup():
    """Same straggler with a single consumer: there is no other slot to
    steal, so the backup is skipped (counted in
    ``runtime.speculative_no_capacity``) and the straggler runs out."""
    pipe, clock = _steal_pipeline(cloud_workers=1)
    charges = iter([1.0, 1.0, 1.0, 30.0, 1.0])

    def service(stage, ctx, payload):
        return next(charges) if stage == "process_cloud" else 0.0

    ex = SimExecutor(clock=clock, service_model=service,
                     speculative_factor=1.5)
    res = pipe.run(n_messages=4, timeout_s=600.0, scheduler=ex)
    assert res.n_processed == 4
    m = res.metrics
    assert m.counter("runtime.speculative_launches") == 0
    assert m.counter("runtime.speculative_no_capacity") == 1
    assert res.wall_s > 30.0                  # the straggler ran its course


def test_stolen_helper_stops_polling_until_race_resolves():
    """Work stealing means the backup *occupies* the helper slot: while
    the race runs, the lent consumer must not take new messages — with 2
    partitions and 2 consumers, stealing consumer-1 leaves its partition
    untouched until release, and everything still completes."""
    clock = SimClock()
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=2, cloud_consumers=2,
        metrics=MetricsRegistry(clock=clock), clock=clock,
        heartbeat_timeout_s=1e9)
    # producers are staggered so consumer-1 idles when the straggler hits
    svc = {"n": 0}

    def service(stage, ctx, payload):
        if stage != "process_cloud":
            return 0.0
        svc["n"] += 1
        return 20.0 if svc["n"] == 4 else 0.5

    ex = SimExecutor(clock=clock, service_model=service,
                     speculative_factor=1.5,
                     producer_offsets=(0.0, 30.0))
    res = pipe.run(n_messages=12, timeout_s=600.0, scheduler=ex)
    assert res.n_processed == 12              # nothing lost to the lend
    m = res.metrics
    launches = m.counter("runtime.speculative_launches")
    assert launches >= 1
    assert (m.counter("runtime.speculative_wins")
            + m.counter("runtime.speculative_losses")
            + m.counter("runtime.speculative_cancelled") == launches)


# ---------------------------------------------------------------------------
# SpeculationStats unit behaviour (shared by both executors)
# ---------------------------------------------------------------------------

def test_speculation_stats_warmup_and_threshold():
    stats = SpeculationStats(1.5, MetricsRegistry())
    assert stats.threshold("s") is None       # no samples yet
    for d in (1.0, 2.0):
        stats.record("s", d)
    assert stats.threshold("s") is None       # < MIN_SAMPLES warmup bar
    stats.record("s", 3.0)
    assert stats.threshold("s") == pytest.approx(1.5 * 2.0)
    stats.record("other", 10.0)               # stages don't cross-pollute
    assert stats.threshold("other") is None


def test_speculation_stats_inline_charge_accounting():
    """The ThreadedExecutor's inline form: a charge past the threshold
    races a redraw; the effective charge is the earlier finisher and the
    win/loss counters land in the metrics."""
    m = MetricsRegistry()
    stats = SpeculationStats(1.5, m)
    for d in (1.0, 1.0, 1.0):
        stats.record("s", d)                  # median 1.0, threshold 1.5
    # under threshold: charged as-is, no race
    assert stats.charge("s", 1.2, lambda: 0.1) == 1.2
    assert m.counter("runtime.speculative_launches") == 0
    # straggler, backup wins: threshold + redraw < primary
    assert stats.charge("s", 5.0, lambda: 0.5) == pytest.approx(2.0)
    assert m.counter("runtime.speculative_wins") == 1
    # straggler, backup loses: primary finishes first
    assert stats.charge("s", 1.6, lambda: 5.0) == pytest.approx(1.6)
    assert m.counter("runtime.speculative_losses") == 1
    assert m.counter("runtime.speculative_launches") == 2
