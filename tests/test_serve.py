"""Serving tests: prefill↔decode consistency for every arch family, ring
buffers, the batched server, and the train→publish→serve handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import transformer as T
from repro.serve import BatchServer, Request
from repro.serve.engine import prefill_with_cache

TOKEN_ARCHS = [a for a in list_archs()
               if get_arch(a).input_mode == "tokens"]


@pytest.mark.parametrize("arch", TOKEN_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.key(0), cfg, jnp.float32)
    B, S = 2, 24
    key = jax.random.key(1)
    shape = (B, S + 1, cfg.n_codebooks) if cfg.n_codebooks > 1 \
        else (B, S + 1)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks}, remat=False)
    lg, cache = prefill_with_cache(params, cfg, {"tokens": toks[:, :S]},
                                   max_len=32, cache_dtype=jnp.float32)
    dl, _ = T.decode_step(params, cfg, cache,
                          {"tokens": toks[:, S:S + 1],
                           "length": jnp.asarray(S, jnp.int32)})
    # MoE archs: capacity-drop sets differ between the (B*(S+1))-token
    # forward and the B-token decode — inherent GShard semantics.
    tol = 2e-2 if cfg.moe is not None else 2e-3
    np.testing.assert_allclose(np.asarray(dl[:, 0]),
                               np.asarray(full[:, S]), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, S - 1]), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-130m",
                                  "hymba-1.5b", "minicpm3-4b"])
def test_multi_token_incremental_decode(arch):
    """Decode 6 tokens sequentially; each must match the full forward."""
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.key(0), cfg, jnp.float32)
    B, S, N = 1, 12, 6
    toks = jax.random.randint(jax.random.key(3), (B, S + N), 0,
                              cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks}, remat=False)
    _, cache = prefill_with_cache(params, cfg, {"tokens": toks[:, :S]},
                                  max_len=S + N, cache_dtype=jnp.float32)
    for i in range(N):
        lg, cache = T.decode_step(
            params, cfg, cache,
            {"tokens": toks[:, S + i:S + i + 1],
             "length": jnp.asarray(S + i, jnp.int32)})
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_buffer_decode():
    """hymba's ring cache: decode far past the window stays consistent
    with the windowed full forward."""
    cfg = get_arch("hymba-1.5b").reduced()      # window = 16
    params = T.init_params(jax.random.key(0), cfg, jnp.float32)
    W = cfg.sliding_window
    B, S, N = 1, 3 * W // 2, 4                  # prefill beyond the window
    toks = jax.random.randint(jax.random.key(4), (B, S + N), 0,
                              cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks}, remat=False)
    _, cache = prefill_with_cache(params, cfg, {"tokens": toks[:, :S]},
                                  max_len=S + N, cache_dtype=jnp.float32)
    assert cache["k"].shape[2] == W             # ring buffer size
    for i in range(N):
        lg, cache = T.decode_step(
            params, cfg, cache,
            {"tokens": toks[:, S + i:S + i + 1],
             "length": jnp.asarray(S + i, jnp.int32)})
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   atol=2e-3, rtol=2e-3)


def test_batch_server_end_to_end():
    cfg = get_arch("internlm2-1.8b").reduced()
    params = T.init_params(jax.random.key(0), cfg, jnp.float32)
    server = BatchServer(params, cfg, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(request_id=f"r{i}",
                    prompt=rng.integers(1, cfg.vocab_size, 8).astype(
                        np.int32),
                    max_new_tokens=5) for i in range(4)]
    for r in reqs:
        server.submit(r)
    done = server.run(max_requests=4, idle_timeout_s=0.5)
    assert len(done) == 4
    for r in done:
        assert len(r.result_tokens) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.result_tokens)
        assert r.t_first_token is not None and r.t_done is not None


def test_batch_server_greedy_matches_manual_decode():
    """Server output == manual prefill+argmax loop (same params)."""
    cfg = get_arch("mamba2-130m").reduced()
    params = T.init_params(jax.random.key(0), cfg, jnp.float32)
    prompt = np.asarray([5, 9, 2, 7, 11, 3], np.int32)

    server = BatchServer(params, cfg, n_slots=1, max_len=64)
    server.submit(Request(request_id="x", prompt=prompt, max_new_tokens=4))
    done = server.run(max_requests=1, idle_timeout_s=0.5)
    got = done[0].result_tokens

    lg, cache = prefill_with_cache(
        params, cfg, {"tokens": jnp.asarray(prompt[None])},
        max_len=64, cache_dtype=jnp.bfloat16)
    want = [int(jnp.argmax(lg[0, -1]))]
    length = len(prompt)
    for _ in range(3):
        lg2, cache = T.decode_step(
            params, cfg, cache,
            {"tokens": jnp.asarray([[want[-1]]], jnp.int32),
             "length": jnp.asarray(length, jnp.int32)})
        want.append(int(jnp.argmax(lg2[0, 0])))
        length += 1
    assert got == want
