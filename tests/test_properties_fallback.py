"""Stub-hypothesis fallbacks for the DES-critical property tests.

``tests/test_properties.py`` skips wholesale when ``hypothesis`` is not
installed (the ``pytest.importorskip`` at its top — a known image gap
``tools/check_skips.py`` tracks).  The two invariants that guard the DES
hot path — event-heap bookkeeping under arbitrary at/after/cancel/step
interleavings, and log truncation never reclaiming an uncommitted
offset — are too load-bearing to go dark with the dependency, so this
module re-drives them as seed-parametrized ``np.random.default_rng``
loops (the churn-loop idiom of ``test_sim.py``'s rebalance test):
deterministic, shrink-free, always-on.  When hypothesis *is* present
both run; these cost milliseconds.
"""
import numpy as np
import pytest

from repro.core.broker import Broker, ConsumerGroup
from repro.sim import EventScheduler
from repro.sim.clock import SimClock


@pytest.mark.parametrize("seed", range(10))
def test_event_heap_interleaving_fallback(seed):
    """Under a random interleaving of at/after/cancel/step, ``len(sched)``
    equals the number of scheduled-but-unfired-and-uncancelled events,
    events fire in (time, insertion) order, and cancelled entries never
    execute nor perturb the tie-break of survivors."""
    rng = np.random.default_rng(seed)
    sched = EventScheduler()
    fired = []
    model = {}                           # ev_id -> (t, insertion_seq)
    handles = {}
    next_id = 0
    at_times = [0.0, 0.5, 1.0, 1.5, 2.0, 5.0]
    delays = [0.0, 0.5, 2.0]
    for _ in range(int(rng.integers(60, 140))):
        op = ("at", "after", "cancel", "step")[rng.integers(0, 4)]
        if op in ("at", "after"):
            i = next_id
            next_id += 1
            fn = lambda i=i: fired.append(i)      # noqa: E731
            if op == "at":
                t = at_times[rng.integers(0, len(at_times))]
                t = max(t, sched.clock.now())     # at() clamps to now
                handles[i] = sched.at(t, fn)
            else:
                d = delays[rng.integers(0, len(delays))]
                t = sched.clock.now() + d
                handles[i] = sched.after(d, fn)
            model[i] = (t, i)
        elif op == "cancel" and model:
            keys = sorted(model)
            i = keys[rng.integers(0, len(keys))]
            handles[i].cancel()
            del model[i]
        elif op == "step":
            ran = sched.step()
            if model:
                expect = min(model, key=model.get)
                assert ran and fired[-1] == expect
                del model[expect]
            else:
                assert not ran
        assert len(sched) == len(model)
    # drain: survivors fire in model order, nothing extra, len hits 0
    rest = sorted(model, key=model.get)
    n_before = len(fired)
    sched.run()
    assert fired[n_before:] == rest
    assert len(sched) == 0


@pytest.mark.parametrize("seed", range(10))
def test_log_truncation_at_least_once_fallback(seed):
    """With log truncation on, across random commit/crash/rejoin/
    late-second-group interleavings: nothing at or above any group's
    committed offset is ever reclaimed, absolute offsets survive
    truncation, and every message is delivered at least once."""
    rng = np.random.default_rng(seed)
    n_msgs = int(rng.integers(1, 51))
    n_parts = int(rng.integers(1, 5))
    n_consumers = int(rng.integers(1, 5))
    batch = int(rng.integers(1, 9))
    clock = SimClock()
    b = Broker(clock=clock)
    t = b.create_topic("t", n_partitions=n_parts, truncate_batch=batch)
    g = ConsumerGroup(t, group_id="g1")
    groups = [g]
    consumers = [f"c{i}" for i in range(n_consumers)]
    for c in consumers:
        g.join(c)
    for i in range(n_msgs):
        t.produce(np.array([i]))
    seen = set()
    deliveries = 0
    alive = list(consumers)
    second = None

    def check_invariants():
        starts = t.log_start_offsets()
        ends = t.end_offsets()
        for p in range(n_parts):
            for grp in groups:
                assert starts[p] <= grp.committed[p], \
                    "truncation reclaimed an uncommitted offset"
            # retained messages keep their absolute offsets, densely
            part = t.partitions[p]
            offs = [m.offset for m in part.log]
            assert offs == list(range(starts[p], ends[p]))

    for _ in range(40 * n_msgs + 400):
        check_invariants()
        if g.lag() == 0:
            break
        # a late second group joins mid-stream: it starts at the log
        # start (replaying the retained tail) and from then on bounds
        # further truncation
        if second is None and rng.random() < 0.05:
            second = ConsumerGroup(t, group_id="g2")
            groups.append(second)
            second.join("z0")
            assert second.committed == t.log_start_offsets()
        if second is not None and rng.random() < 0.3:
            msg, _ = second.poll_nowait("z0")
            if msg is not None:
                second.commit(msg)
        if len(alive) < n_consumers and rng.random() < 0.15:
            back = [c for c in consumers if c not in alive][0]
            alive.append(back)
            g.join(back)
        cid = alive[rng.integers(0, len(alive))]
        msg, _ = g.poll_nowait(cid)
        if msg is None:
            clock.advance(0.01)
            continue
        deliveries += 1
        seen.add(int(msg.value()[0]))
        if len(alive) > 1 and rng.random() < 0.2:
            # crash *before* the commit: the offset must be redelivered
            # to a surviving member after the rebalance — truncation
            # must not have reclaimed it meanwhile
            alive.remove(cid)
            g.leave(cid)
        else:
            g.commit(msg)
    check_invariants()
    assert g.lag() == 0
    assert deliveries >= n_msgs          # at-least-once
    assert seen == set(range(n_msgs))    # every offset delivered, no gaps
