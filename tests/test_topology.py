"""Continuum topology: multi-hop routing over the tier graph — triangle
optimality (a detour never beats a direct link), per-hop latency
accumulation, routed transfer pricing in the CostModel, and the
``DEFAULT_LINKS``/``WAN_BANDS`` equality pins over the default 4-tier
device/edge/fog/cloud instance."""
import dataclasses

import pytest

from repro.core.placement import DEFAULT_LINKS
from repro.cost import CostModel
from repro.cost.profiles import (DEFAULT_PROFILE, DEVICE_EDGE_LINK,
                                 EDGE_FOG_LINK, WAN_BANDS, LinkModel,
                                 Route, Topology)


# ---------------------------------------------------------------------------
# routing over the default 4-tier instance
# ---------------------------------------------------------------------------

def test_default_profile_is_four_tier_continuum():
    tiers = set(DEFAULT_PROFILE.tiers)
    assert {"device", "edge", "fog", "cloud"} <= tiers
    # per-tier device rates are strictly ordered along the continuum
    rates = [DEFAULT_PROFILE.tier(t).device.peak_flops
             for t in ("device", "edge", "fog", "cloud")]
    assert rates == sorted(rates)
    assert len(set(rates)) == 4


def test_triangle_direct_link_is_never_beaten_by_fog_detour():
    """Satellite pin: route(edge→cloud) must take the direct WAN link —
    the edge→fog→cloud detour pays the metro hop *plus* the same WAN
    crossing, so it cannot be faster at any message size."""
    topo = DEFAULT_PROFILE.topology
    for nbytes in (0.0, 1e3, 1.25e6, 1e9):
        r = topo.route("edge", "cloud", nbytes)
        assert r.tiers == ("edge", "cloud")
        detour_s = (EDGE_FOG_LINK.latency_s + nbytes / EDGE_FOG_LINK.bandwidth
                    + r.transfer_s(nbytes))
        assert r.transfer_s(nbytes) <= detour_s


def test_multi_hop_route_accumulates_per_hop_latency():
    """device→cloud has no direct link: the route rides device→edge→cloud
    and its latency/transfer cost is the *sum* over hops, not the max."""
    r = DEFAULT_PROFILE.route("device", "cloud")
    assert r.tiers == ("device", "edge", "cloud")
    wan = DEFAULT_PROFILE.link("edge", "cloud")
    assert r.latency_s == pytest.approx(
        DEVICE_EDGE_LINK.latency_s + wan.latency_s)
    nbytes = 1e6
    assert r.transfer_s(nbytes) == pytest.approx(
        nbytes / DEVICE_EDGE_LINK.bandwidth + DEVICE_EDGE_LINK.latency_s
        + nbytes / wan.bandwidth + wan.latency_s)


def test_route_as_link_is_store_and_forward_equivalent():
    """The serialized-equivalent single link (harmonic bandwidth +
    accumulated latency) prices identically to the per-hop sum for any
    message size."""
    r = DEFAULT_PROFILE.route("device", "cloud")
    eff = r.as_link()
    for nbytes in (1.0, 1e4, 1e7):
        assert (nbytes / eff.bandwidth + eff.latency_s
                == pytest.approx(r.transfer_s(nbytes)))
    # harmonic: the effective bandwidth is below every hop's
    assert eff.bandwidth < min(h.link.bandwidth for h in r.hops)


def test_cost_model_transfer_prices_routed_paths():
    cm = CostModel()
    # the historical direct-link pin still holds (10 Mbit/s + 150 ms)
    assert cm.transfer_s(1.25e6, "edge", "cloud") == pytest.approx(1.150)
    # device→cloud pays both hops
    direct = cm.transfer_s(1.25e6, "edge", "cloud")
    local = cm.transfer_s(1.25e6, "device", "edge")
    assert cm.transfer_s(1.25e6, "device", "cloud") == pytest.approx(
        direct + local)
    assert cm.route("device", "cloud").tiers == ("device", "edge", "cloud")


def test_routing_is_deterministic_and_same_tier_is_intra():
    topo = DEFAULT_PROFILE.topology
    routes = [topo.route("device", "hpc", 1e6).tiers for _ in range(5)]
    assert len(set(routes)) == 1
    r = DEFAULT_PROFILE.route("cloud", "cloud")
    assert r.transfer_s(1e6) == pytest.approx(1e6 / 10e9)


def test_disconnected_tiers_fall_back_to_default_wan():
    """A profile whose topology cannot connect two tiers prices the pair
    at the legacy fallback (default WAN band, doubled latency) instead of
    dead-ending."""
    island = Topology({("a", "b"): LinkModel(1e6, 0.01)}, tiers=("a", "b",
                                                                 "c"))
    assert island.route("a", "c") is None
    r = DEFAULT_PROFILE.route("edge", "nowhere")
    assert len(r.hops) == 1
    wan = DEFAULT_PROFILE.wan()
    assert r.hops[0].link.bandwidth == wan.bandwidth
    assert r.hops[0].link.latency_s == pytest.approx(2 * wan.latency_s)


def test_route_object_shape():
    r = DEFAULT_PROFILE.route("device", "cloud")
    assert isinstance(r, Route)
    assert [h.src for h in r.hops] == ["device", "edge"]
    assert [h.dst for h in r.hops] == ["edge", "cloud"]
    empty = DEFAULT_PROFILE.topology.route("edge", "edge")
    assert empty.hops == () and empty.transfer_s(1e9) == 0.0


# ---------------------------------------------------------------------------
# the shared-table pins survive the topology refactor
# ---------------------------------------------------------------------------

def test_default_links_and_wan_bands_pins_still_hold():
    """``DEFAULT_LINKS`` / ``WAN_BANDS`` are views of the default 4-tier
    instance: the historical equality pins survive the refactor, and the
    new fog→cloud edge carries the same constrained WAN band."""
    assert DEFAULT_LINKS[("edge", "cloud")] == WAN_BANDS["10mbit"]
    assert DEFAULT_LINKS[("edge", "hpc")] == WAN_BANDS["10mbit"]
    assert DEFAULT_LINKS[("fog", "cloud")] == WAN_BANDS["10mbit"]
    assert DEFAULT_LINKS[("device", "edge")] == DEVICE_EDGE_LINK
    assert DEFAULT_LINKS[("edge", "fog")] == EDGE_FOG_LINK
    assert DEFAULT_LINKS == dict(DEFAULT_PROFILE.links)
    # the non-WAN continuum links never collide with a WAN band price
    # (``with_wan`` re-pricing matches on link equality)
    bands = set(WAN_BANDS.values())
    assert DEVICE_EDGE_LINK not in bands
    assert EDGE_FOG_LINK not in bands


def test_with_wan_reprices_wan_edges_only():
    fast = DEFAULT_PROFILE.with_wan("100mbit")
    assert fast.link("fog", "cloud") == WAN_BANDS["100mbit"]
    assert fast.link("edge", "cloud") == WAN_BANDS["100mbit"]
    assert fast.link("edge", "fog") == EDGE_FOG_LINK       # metro untouched
    assert fast.link("device", "edge") == DEVICE_EDGE_LINK


def test_custom_topology_is_a_profile_change():
    """The refactor's promise: a new topology (second edge site with a
    private fat path to fog) is a one-line profile change — routing picks
    the new path up without any pipeline code."""
    site2 = LinkModel(bandwidth=1e9, latency_s=0.001)
    custom = dataclasses.replace(
        DEFAULT_PROFILE,
        links={**DEFAULT_PROFILE.links, ("edge2", "fog"): site2})
    r = custom.route("edge2", "cloud", 1e6)
    assert r.tiers == ("edge2", "fog", "cloud")
    assert r.latency_s == pytest.approx(
        site2.latency_s + custom.link("fog", "cloud").latency_s)
