"""Dry-run smoke in a subprocess (the 512-device XLA flag must not leak
into this pytest process). Kept cheap: one small cell per mesh.

Skipped unless RUN_DRYRUN_TESTS=1 (each cell compiles for ~1–2 min)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.skipif(
    os.environ.get("RUN_DRYRUN_TESTS") != "1",
    reason="set RUN_DRYRUN_TESTS=1 to compile dry-run cells (slow)")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT)


@pytest.mark.parametrize("mesh_flag", [[], ["--multi-pod"]])
def test_dryrun_cell_compiles(mesh_flag):
    r = _run(["--arch", "internlm2-1.8b", "--shape", "decode_32k",
              *mesh_flag])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "1 cells OK, 0 failed" in r.stdout
    assert "bottleneck=" in r.stdout
