"""Checkpoint (atomic commit, rotation, reshard-on-restore) and data
pipeline (determinism, packing, sharding) tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_step, restore, save
from repro.configs import get_arch
from repro.data import SyntheticLMDataset, TokenBatcher, make_batch_iterator


def _tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones((2,), np.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    got = restore(str(tmp_path), like=t)
    np.testing.assert_array_equal(np.asarray(got["a"]), t["a"])
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]), t["b"]["c"])


def test_atomic_commit_no_partial(tmp_path):
    """A failed write never leaves a step_* directory behind."""
    class Boom:
        shape = (2,)
        dtype = np.float32

        def __array__(self, dtype=None, copy=None):
            raise RuntimeError("disk full")

    with pytest.raises(RuntimeError):
        save(str(tmp_path), 1, {"x": Boom()})
    assert latest_step(str(tmp_path)) is None
    assert not [d for d in os.listdir(tmp_path) if d.startswith("step_")]


def test_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_manager_async_and_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = _tree()
    mgr.save(7, t)
    step, got = mgr.restore_latest(t)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), t["a"])


def test_restore_reshard_onto_mesh(tmp_path):
    """Checkpoint written unsharded restores under a mesh w/ NamedSharding
    (the reshard-on-restore path used after losing a pod)."""
    from jax.sharding import PartitionSpec as P
    t = {"w": np.arange(8, dtype=np.float32)}
    save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    got = restore(str(tmp_path), like=t, mesh=mesh,
                  pspecs={"w": P("data")})
    assert isinstance(got["w"].sharding, jax.sharding.NamedSharding)
    np.testing.assert_array_equal(np.asarray(got["w"]), t["w"])


def test_restore_dtype_cast(tmp_path):
    t32 = {"w": np.ones((4,), np.float32)}
    save(str(tmp_path), 1, t32)
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    got = restore(str(tmp_path), like=like)
    assert got["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_dataset_determinism_and_shards():
    a = SyntheticLMDataset(vocab_size=1000, seed=3)
    b = SyntheticLMDataset(vocab_size=1000, seed=3)
    ita, itb = a.token_stream(), b.token_stream()
    assert [next(ita) for _ in range(100)] == [next(itb) for _ in range(100)]
    c = SyntheticLMDataset(vocab_size=1000, seed=3, shard_id=1)
    itc = c.token_stream()
    ita2 = SyntheticLMDataset(vocab_size=1000, seed=3).token_stream()
    assert [next(itc) for _ in range(100)] != \
        [next(ita2) for _ in range(100)]


def test_batcher_shapes_and_label_shift():
    ds = SyntheticLMDataset(vocab_size=500, seed=0)
    b = next(TokenBatcher(ds, batch=3, seq_len=16))
    assert b["tokens"].shape == (3, 16)
    assert b["labels"].shape == (3, 16)
    # labels are inputs shifted by one (packed windows)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].max() < 500


def test_batch_iterator_arch_aware():
    cfg = get_arch("qwen2-vl-2b").reduced()
    it = make_batch_iterator(cfg, batch=2, seq_len=8)
    b = next(it)
    assert set(b) == {"embeds", "positions", "labels"}
    assert b["embeds"].shape == (2, 8, cfg.d_model)
    assert b["positions"].shape == (3, 2, 8)

    cfg2 = get_arch("musicgen-medium").reduced()
    b2 = next(make_batch_iterator(cfg2, batch=2, seq_len=8))
    assert b2["tokens"].shape == (2, 8, cfg2.n_codebooks)
