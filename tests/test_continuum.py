"""N-tier ContinuumPipeline: a 4-tier device/edge/fog/cloud scenario end
to end under both execution strategies, per-stage tier vectors through
the fog scenarios and the advisor, the fog-pilot pricing regression, and
auto placement of unbound stages."""
import numpy as np
import pytest

from repro.core import (ComputeResource, ContinuumPipeline,
                        EdgeToCloudPipeline, MetricsRegistry, PilotManager,
                        PlacementEngine, SimClock, SimExecutor, StageSpec,
                        ThreadedExecutor)
from repro.cost import DEFAULT_PROFILE
from repro.cost.advisor import PlacementAdvisor
from repro.sim.scenarios import (KMEANS, PLACEMENTS, Scenario,
                                 build_pipeline, run_scenario)


def _four_tier(clock=None, n=2):
    """A genuine 4-stage device→edge→fog→cloud pipeline: sense → halve →
    halve → sum, with the hops auto-shaped from the routed topology."""
    metrics = MetricsRegistry(clock=clock) if clock else None
    mgr = PilotManager(devices=(), clock=clock)
    stages = [
        StageSpec("sense",
                  lambda ctx: np.arange(128, dtype=np.float64),
                  pilot=mgr.submit_pilot(ComputeResource(tier="device",
                                                         n_workers=n))),
        StageSpec("edge_agg", lambda ctx, data=None: data[::2],
                  pilot=mgr.submit_pilot(ComputeResource(tier="edge",
                                                         n_workers=n))),
        StageSpec("fog_agg", lambda ctx, data=None: data[::2],
                  pilot=mgr.submit_pilot(ComputeResource(tier="fog",
                                                         n_workers=n))),
        StageSpec("process_cloud",
                  lambda ctx, data=None: float(np.sum(data)),
                  pilot=mgr.submit_pilot(ComputeResource(tier="cloud",
                                                         n_workers=n))),
    ]
    return ContinuumPipeline(stages=stages, metrics=metrics, clock=clock)


EXPECTED = float(np.sum(np.arange(128.0)[::2][::2]))


# ---------------------------------------------------------------------------
# the acceptance gate: 4 tiers under both strategies
# ---------------------------------------------------------------------------

def test_four_tier_pipeline_under_sim_executor():
    clock = SimClock()
    pipe = _four_tier(clock)
    assert pipe.stage_tiers == ["device", "edge", "fog", "cloud"]
    res = pipe.run(n_messages=12, timeout_s=600.0,
                   scheduler=SimExecutor(clock=clock))
    assert res.n_processed == 12 and res.n_produced == 12
    assert res.results == [EXPECTED] * 12
    # every hop between distinct tiers is shaped by its routed link, so
    # end-to-end latency covers at least the accumulated one-way latency
    route_latency = sum(
        DEFAULT_PROFILE.route(a, b).latency_s
        for a, b in zip(pipe.stage_tiers[:-1], pipe.stage_tiers[1:]))
    lat = res.metrics.latencies("produced", "processed")
    assert len(lat) == 12
    assert min(lat) >= route_latency / 2.0     # shaper charges rtt/2 one-way
    assert res.wall_s > 0.0


def test_four_tier_pipeline_under_threaded_executor():
    pipe = _four_tier()
    res = pipe.run(n_messages=12, timeout_s=60.0,
                   scheduler=ThreadedExecutor())
    assert res.n_processed == 12
    assert res.results == [EXPECTED] * 12
    assert res.metrics.summary()["count"] == 12


def test_four_tier_bit_identical_across_three_runs():
    def one():
        clock = SimClock()
        pipe = _four_tier(clock)
        svc = lambda stage, ctx, data: {"sense": 0.01, "edge_agg": 0.02,
                                        "fog_agg": 0.03,
                                        "process_cloud": 0.05}[stage]
        res = pipe.run(n_messages=16, timeout_s=600.0,
                       scheduler=SimExecutor(clock=clock,
                                             service_model=svc))
        lat = res.metrics.latencies("produced", "processed")
        return (res.n_processed, res.wall_s, tuple(sorted(lat)))

    a, b, c = one(), one(), one()
    assert a == b == c
    assert a[0] == 16


def test_intermediate_stage_hot_swap_and_errors():
    """replace_function reaches intermediate stages; unknown stages and
    stage-name collisions fail loudly."""
    clock = SimClock()
    pipe = _four_tier(clock)
    pipe.replace_function("fog_agg", lambda ctx, data=None: data[:4])
    res = pipe.run(n_messages=6, timeout_s=600.0,
                   scheduler=SimExecutor(clock=clock))
    assert res.results == [float(np.sum(np.arange(128.0)[::2][:4]))] * 6
    with pytest.raises(KeyError):
        pipe.replace_function("no-such-stage", lambda ctx: None)
    mgr = PilotManager(devices=())
    p = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=1))
    with pytest.raises(ValueError, match="unique"):
        ContinuumPipeline(stages=[
            StageSpec("a", lambda ctx: None, pilot=p),
            StageSpec("a", lambda ctx, data=None: None, pilot=p)])
    # "consumer" is the final stage's cid namespace (crash injection /
    # autoscaling address it) — reserved for intermediate stages
    with pytest.raises(ValueError, match="reserved"):
        ContinuumPipeline(stages=[
            StageSpec("a", lambda ctx: None, pilot=p),
            StageSpec("consumer", lambda ctx, data=None: None, pilot=p),
            StageSpec("b", lambda ctx, data=None: None, pilot=p)])
    with pytest.raises(ValueError, match="source"):
        ContinuumPipeline(stages=[StageSpec("only", lambda ctx: None,
                                            pilot=p)])


def test_edge_to_cloud_is_a_thin_continuum_wrapper():
    """The legacy pipeline is literally a two-stage ContinuumPipeline —
    same bodies, same state machinery, legacy attribute surface intact."""
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=3))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: np.zeros(8),
        process_cloud_function_handler=lambda ctx, data=None: 1.0)
    assert isinstance(pipe, ContinuumPipeline)
    assert [s.name for s in pipe.stages] == ["produce", "process_cloud"]
    assert pipe.stage_tiers == ["edge", "cloud"]
    assert pipe.n_edge_devices == 3 and pipe.cloud_consumers == 3
    assert pipe.pilot_cloud is pipe.stages[-1].pilot


def test_auto_placement_binds_stage_through_engine():
    """A ``placement='auto'`` stage is bound by scoring the candidates —
    the heavy workload lands on the cloud pilot, and with no candidates
    construction fails loudly."""
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    stages = [
        StageSpec("produce", lambda ctx: np.zeros(8), pilot=edge),
        StageSpec("train", lambda ctx, data=None: 0.0, placement="auto"),
    ]
    pipe = ContinuumPipeline(
        stages=stages, function_context={"task_flops": 1e12},
        candidate_pilots={"train": [edge, cloud]})
    assert pipe.stages[-1].pilot is cloud
    with pytest.raises(ValueError, match="candidate"):
        ContinuumPipeline(stages=stages)


# ---------------------------------------------------------------------------
# fog-pilot pricing regression (satellite)
# ---------------------------------------------------------------------------

def test_fog_pilot_priced_at_fog_rate_not_cloud():
    """Regression: ``PlacementEngine.pilot_flops`` used to price every
    non-edge pilot at the cloud device rate; a fog pilot must price at
    the fog tier's own device rate."""
    mgr = PilotManager(devices=())
    fog = mgr.submit_pilot(ComputeResource(tier="fog", n_workers=3))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=3))
    device = mgr.submit_pilot(ComputeResource(tier="device", n_workers=3))
    eng = PlacementEngine()
    fog_rate = DEFAULT_PROFILE.tier("fog").device.peak_flops
    cloud_rate = DEFAULT_PROFILE.tier("cloud").device.peak_flops
    assert eng.pilot_flops(fog) == pytest.approx(3 * fog_rate)
    assert eng.pilot_flops(cloud) == pytest.approx(3 * cloud_rate)
    assert eng.pilot_flops(fog) < eng.pilot_flops(cloud)
    assert eng.pilot_flops(device) == pytest.approx(
        3 * DEFAULT_PROFILE.tier("device").device.peak_flops)
    # …and the estimate's compute term follows the corrected rate
    from repro.core.placement import TaskProfile
    t = TaskProfile(flops=1e9, input_bytes=0.0)
    assert eng.estimate(t, fog).breakdown["t_compute"] == pytest.approx(
        1e9 / (3 * fog_rate))
    # a tier the profile doesn't model prices at the *slowest* known
    # rate — a fast guess would bias auto-placement onto unmodeled tiers
    mystery = mgr.submit_pilot(ComputeResource(tier="edge-site-2",
                                               n_workers=1))
    slowest = min(tp.device.peak_flops
                  for tp in DEFAULT_PROFILE.tiers.values())
    assert eng.pilot_flops(mystery) == pytest.approx(slowest)


# ---------------------------------------------------------------------------
# fog scenarios + advisor tier vectors
# ---------------------------------------------------------------------------

def test_fog_scenario_runs_a_three_stage_pipeline():
    sc = Scenario(model=KMEANS, placement="fog", wan_band="10mbit",
                  n_messages=16)
    pipe, ex, _ = build_pipeline(sc)
    assert isinstance(pipe, ContinuumPipeline)
    assert not isinstance(pipe, EdgeToCloudPipeline)
    assert [s.name for s in pipe.stages] == \
        ["produce", "process_fog", "process_cloud"]
    res = pipe.run(n_messages=16, timeout_s=3600.0, scheduler=ex)
    assert res.n_processed == 16
    # two hops → two topics; only the fog→cloud hop carries WAN bytes
    assert len(pipe._topics) == 2


def test_fog_scenario_sits_between_hybrid_and_cloud():
    """On the constrained WAN the fog placement sends only the reduced
    message over the WAN (like hybrid) but pays the extra metro hop —
    far faster than cloud, WAN-thin, a bit behind hybrid."""
    rows = {p: run_scenario(Scenario(model=KMEANS, placement=p,
                                     wan_band="10mbit", n_messages=24))
            for p in ("cloud", "hybrid", "fog")}
    assert rows["fog"].throughput_msgs_s > 5 * rows["cloud"].throughput_msgs_s
    assert rows["fog"].wan_bytes == rows["hybrid"].wan_bytes
    assert rows["fog"].throughput_msgs_s < rows["hybrid"].throughput_msgs_s
    assert rows["fog"].row()["tiers"] == ["edge", "fog", "cloud"]
    assert rows["hybrid"].row()["tiers"] == ["edge", "cloud"]


def test_fog_scenario_bit_identical_with_noise_and_speculation():
    sc = Scenario(model=KMEANS, placement="fog", wan_band="10mbit",
                  n_messages=24, service_sigma=None,
                  speculative_factor=1.2)
    rows = [run_scenario(sc).row() for _ in range(3)]
    assert rows[0] == rows[1] == rows[2]
    assert rows[0]["processed"] == 24


def test_advisor_three_stage_sweep_with_tier_vectors():
    """Acceptance pin: the advisor ranks the ≥3-stage placement sweep —
    fog cells carry the (edge, fog, cloud) tier vector — bit-identically
    across three runs."""
    assert "fog" in PLACEMENTS
    reports = [PlacementAdvisor(n_messages=16).advise("kmeans")
               for _ in range(3)]
    rows = [r.rows() for r in reports]
    assert rows[0] == rows[1] == rows[2]
    fog_cells = [c for c in reports[0].cells if c.placement == "fog"]
    assert fog_cells and all(c.tiers == ("edge", "fog", "cloud")
                             for c in fog_cells)
    assert all(len(c.tiers) >= 3 for c in fog_cells)
    device_cells = [c for c in reports[0].cells if c.placement == "device"]
    assert device_cells and all(c.tiers == ("device", "device", "cloud")
                                for c in device_cells)
    two_stage = [c for c in reports[0].cells
                 if c.placement not in ("fog", "device")]
    assert all(c.tiers == ("edge", "cloud") for c in two_stage)
    # the fog column shows up in the human table
    assert "e-f-c" in reports[0].table()
    # at 10 Mbit/s the WAN-thin placements (edge/hybrid/fog) all beat
    # shipping raw points to the cloud
    ranking = reports[0].ranking("10mbit")
    assert ranking[-1].placement == "cloud"
