"""Unified continuum cost subsystem: shared link/profile tables (the WAN
dedup regression), kernel calibration, CostModel pricing, the calibrated
lognormal service-noise model, the re-pinned Fig-3 goldens on calibrated
costs, the DES-backed PlacementAdvisor goldens, and the CI tooling
(check_skips local/CI modes, BENCH_placement schema)."""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core import ComputeResource, PilotManager
from repro.core.placement import DEFAULT_LINKS, PlacementEngine
from repro.cost import (CostModel, Calibrator, DEFAULT_PROFILE,
                        load_calibration)
from repro.cost.advisor import AdvisorReport, PlacementAdvisor
from repro.cost.profiles import WAN_BANDS as LINK_TABLE
from repro.sim.scenarios import (AUTOENCODER, ISOFOREST, KMEANS, MODELS,
                                 WAN_BANDS, Scenario, model_specs,
                                 run_scenario)

_TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_TOOLS, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# the WAN dedup satellite: one shared link table, no drifted copies
# ---------------------------------------------------------------------------

def test_wan_tables_read_from_shared_link_table():
    """Regression pin: ``core.placement.DEFAULT_LINKS`` and
    ``sim.scenarios.WAN_BANDS`` are both views of
    ``repro.cost.profiles.WAN_BANDS`` — the historical drift (placement's
    edge↔cloud link encoded 80 Mbit/s where scenarios meant 10) cannot
    come back."""
    assert DEFAULT_LINKS[("edge", "cloud")] == LINK_TABLE["10mbit"]
    assert DEFAULT_LINKS[("edge", "hpc")] == LINK_TABLE["10mbit"]
    assert set(WAN_BANDS) == set(LINK_TABLE)
    for name, (bps, rtt) in WAN_BANDS.items():
        assert bps == LINK_TABLE[name].bandwidth_bps
        assert bps == LINK_TABLE[name].bandwidth * 8.0
        assert rtt == LINK_TABLE[name].latency_s
    # the constrained band really is 10 Mbit/s with the iPerf RTT
    assert WAN_BANDS["10mbit"] == (10e6, 0.150)


def test_legacy_cost_constants_are_gone():
    """placement/scenarios no longer own module-level cost constants —
    everything flows from repro.cost profiles."""
    import repro.core.placement as placement
    import repro.sim.scenarios as scenarios
    for name in ("EDGE_FLOPS", "DEVICE_FLOPS"):
        assert not hasattr(placement, name)
        assert not hasattr(scenarios, name)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_committed_calibration_loads_and_is_sane():
    costs = load_calibration()
    assert {"kmeans", "autoencoder", "isoforest"} <= set(costs)
    for mc in costs.values():
        assert mc.kernel_flops_per_point > 0
        assert mc.kernel_bytes_per_point > 0
        assert 0.0 < mc.efficiency <= 1.0
        assert mc.sigma >= 0.0
        assert mc.output_bytes > 0
    # the paper's complexity ordering: k-means (lightest) < isolation
    # forest (mid) << autoencoder (heaviest, §III.2)
    k, i, a = (costs[n].effective_flops_per_point
               for n in ("kmeans", "isoforest", "autoencoder"))
    assert k < i < a
    assert a > 100 * i


def test_model_specs_derive_from_calibration():
    costs = load_calibration()
    for name, spec in MODELS.items():
        mc = costs[name]
        assert spec.flops_per_point == pytest.approx(
            mc.effective_flops_per_point)
        assert spec.output_bytes == mc.output_bytes
        assert spec.hybrid_reduce == mc.hybrid_reduce
        assert spec.sigma == mc.sigma
    custom = model_specs(CostModel())
    assert set(custom) == set(MODELS)


def test_fit_service_recovers_known_lognormal():
    """The measured-sample path round-trips the DES's own noise model:
    samples drawn from ``eff_service × LogNormal(-σ²/2, σ)`` (exactly
    what ``CostModel.service_model`` applies) refit to the same
    (efficiency, sigma)."""
    cal = Calibrator()
    rng = np.random.default_rng(0)
    flops, true_eff, true_sigma = 1e9, 0.2, 0.3
    peak = cal.profile.tier("cloud").device.peak_flops
    base = flops / (peak * true_eff)        # mean service time
    mu = -0.5 * true_sigma ** 2             # mean-one noise convention
    samples = base * np.exp(rng.normal(mu, true_sigma, size=500))
    eff, sigma = cal.fit_service(samples, flops_per_message=flops,
                                 tier="cloud")
    assert eff == pytest.approx(true_eff, rel=0.05)
    assert sigma == pytest.approx(true_sigma, rel=0.2)


# ---------------------------------------------------------------------------
# CostModel pricing
# ---------------------------------------------------------------------------

def test_cost_model_primitives():
    cm = CostModel()
    edge_peak = DEFAULT_PROFILE.tier("edge").device.peak_flops
    assert cm.compute_s(1e9, "edge") == pytest.approx(1e9 / edge_peak)
    assert cm.compute_s(1e9, "edge", n_workers=4) == pytest.approx(
        1e9 / (4 * edge_peak))
    # 10 Mbit/s: 1.25e6 bytes take 1 s + 150 ms latency
    assert cm.transfer_s(1.25e6, "edge", "cloud") == pytest.approx(1.150)
    assert cm.transfer_s(0, "edge", "cloud") == 0.0
    assert cm.link("edge", "edge").latency_s == 0.0
    faster = cm.with_wan("100mbit")
    assert faster.transfer_s(1.25e6, "edge", "cloud") < 0.5
    with pytest.raises(KeyError):
        cm.model_cost("no-such-model")


def test_placement_engine_prices_through_cost_model():
    """The engine's compute term must equal the CostModel's — one oracle,
    not two."""
    cm = CostModel()
    eng = PlacementEngine(cost_model=cm)
    mgr = PilotManager(devices=())
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=3))
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    n_points = 2_500
    prof = KMEANS.task_profile(n_points)
    d_cloud = eng.estimate(prof, cloud)
    assert d_cloud.breakdown["t_compute"] == pytest.approx(
        cm.model_compute_s("kmeans", n_points, "cloud", n_workers=3))
    d_edge = eng.estimate(prof, edge)
    assert d_edge.breakdown["t_compute"] == pytest.approx(
        cm.model_compute_s("kmeans", n_points, "edge", n_workers=2))


def test_service_model_noise_seeded_and_mean_one():
    cm = CostModel()
    clean = cm.service_model({"produce": 1.0, "process_cloud": 2.0})
    assert clean("produce", None, None) == 1.0
    assert clean("other", None, None) == 0.0
    m1 = cm.service_model({"produce": 1.0}, sigma=0.3, seed=5)
    m2 = cm.service_model({"produce": 1.0}, sigma=0.3, seed=5)
    a = [m1("produce", None, None) for _ in range(2_000)]
    b = [m2("produce", None, None) for _ in range(2_000)]
    assert a == b                              # seeded: bit-reproducible
    assert np.std(a) > 0.1                     # actually noisy
    assert np.mean(a) == pytest.approx(1.0, rel=0.05)   # mean-1 lognormal
    assert m1("other", None, None) == 0.0      # zero stages stay zero


def test_scenario_service_noise_reproducible_and_distinct():
    sc = Scenario(model=KMEANS, placement="cloud", wan_band="100mbit",
                  n_messages=24, service_sigma=KMEANS.sigma)
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.row() == b.row()                  # noise is seeded
    clean = run_scenario(Scenario(model=KMEANS, placement="cloud",
                                  wan_band="100mbit", n_messages=24))
    assert a.row() != clean.row()              # and actually applied


# ---------------------------------------------------------------------------
# Fig-3 goldens, re-pinned on the calibrated costs
# ---------------------------------------------------------------------------

def test_fig3_goldens_repinned_on_calibrated_costs():
    """Numeric pins of the calibrated Fig-3 cells (pure virtual-time
    arithmetic — no jit — so the values are machine-independent).  The
    qualitative trade-off is asserted alongside: k-means transfer-bound,
    autoencoder compute-bound."""
    k10 = run_scenario(Scenario(model=KMEANS, placement="cloud",
                                wan_band="10mbit", n_messages=48))
    assert k10.throughput_msgs_s == pytest.approx(1.9467832433, rel=1e-6)
    a10 = run_scenario(Scenario(model=AUTOENCODER, placement="cloud",
                                wan_band="10mbit", n_messages=32))
    assert a10.throughput_msgs_s == pytest.approx(1.2298516731, rel=1e-6)
    k_edge = run_scenario(Scenario(model=KMEANS, placement="edge",
                                   wan_band="10mbit", n_messages=48))
    assert k_edge.throughput_msgs_s > 5 * k10.throughput_msgs_s
    a100 = run_scenario(Scenario(model=AUTOENCODER, placement="cloud",
                                 wan_band="100mbit", n_messages=32))
    assert a100.throughput_msgs_s < 1.2 * a10.throughput_msgs_s


def test_isoforest_is_mid_complexity_and_transfer_bound():
    """The paper's third workload rides the same calibration: heavier than
    k-means, far lighter than the autoencoder, still transfer-bound."""
    edge = run_scenario(Scenario(model=ISOFOREST, placement="edge",
                                 wan_band="10mbit", n_messages=32))
    cloud = run_scenario(Scenario(model=ISOFOREST, placement="cloud",
                                  wan_band="10mbit", n_messages=32))
    assert edge.throughput_msgs_s > 5 * cloud.throughput_msgs_s


# ---------------------------------------------------------------------------
# PlacementAdvisor goldens (satellite): DES-backed recommendation
# ---------------------------------------------------------------------------

def test_advisor_kmeans_picks_edge_on_slow_wan():
    """Fig 3 left as a recommendation: at 10 Mbit/s the transfer-bound
    k-means must be placed on the edge (or hybrid) — never cloud — and a
    WAN upgrade helps its cloud cell by a wide margin."""
    rep = PlacementAdvisor(n_messages=32).advise("kmeans")
    assert rep.best("10mbit").placement in ("edge", "hybrid")
    cell = {(c.wan_band, c.placement): c for c in rep.cells}
    assert (cell[("100mbit", "cloud")].throughput_msgs_s
            > 3 * cell[("10mbit", "cloud")].throughput_msgs_s)
    # the engine's analytic view agrees with the DES recommendation
    est = rep.best("10mbit").tier_estimates
    assert est["edge"] < est["cloud"]


def test_advisor_autoencoder_is_placement_insensitive():
    """Fig 3 right as a recommendation: the compute-bound autoencoder's
    placement ranking is identical on every WAN band and its cloud
    throughput barely moves 10→100 Mbit/s."""
    rep = PlacementAdvisor(n_messages=32).advise("autoencoder")
    orders = [tuple(c.placement for c in rep.ranking(band))
              for band in ("10mbit", "50mbit", "100mbit")]
    assert orders[0] == orders[1] == orders[2]
    cell = {(c.wan_band, c.placement): c for c in rep.cells}
    ratio = (cell[("100mbit", "cloud")].throughput_msgs_s
             / cell[("10mbit", "cloud")].throughput_msgs_s)
    assert ratio < 1.2
    est = rep.best("10mbit").tier_estimates
    assert est["cloud"] < est["edge"]


def test_advisor_bit_identical_across_three_runs():
    rows = [PlacementAdvisor(n_messages=24).advise("kmeans").rows()
            for _ in range(3)]
    assert rows[0] == rows[1] == rows[2]
    # ranked rows: rank 1..n per band over the full tier set (the fog
    # cell is a genuine 3-stage pipeline), exactly one recommendation
    by_band = {}
    for r in rows[0]:
        by_band.setdefault(r["wan"], []).append(r)
    for band_rows in by_band.values():
        assert [r["rank"] for r in band_rows] == [1, 2, 3, 4, 5]
        assert sum(r["recommended"] for r in band_rows) == 1
    # every cell is tier-vector-stamped; the ≥3-stage fog sweep rides it
    tiers = {r["placement"]: r["tiers"] for r in rows[0]}
    assert tiers["fog"] == ["edge", "fog", "cloud"]
    assert tiers["cloud"] == ["edge", "cloud"]
    assert tiers["device"] == ["device", "device", "cloud"]


def test_pipeline_run_placement_advise():
    """``EdgeToCloudPipeline.run(placement='advise')`` returns the ranked
    report for the pipeline's own workload/shape without executing it."""
    from repro.core import EdgeToCloudPipeline
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=4))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=4))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: None,
        process_cloud_function_handler=lambda ctx, data=None: None,
        function_context={"model": "kmeans", "n_points": 2_500})
    rep = pipe.run(n_messages=32, placement="advise")
    assert isinstance(rep, AdvisorReport)
    assert rep.model == "kmeans"
    assert rep.best("10mbit").placement in ("edge", "hybrid")
    assert "recommended" in rep.table()
    # rows/table keep ascending-bandwidth band order, not lexicographic
    # (5 placements per band: edge/cloud/hybrid/fog/device)
    assert [r["wan"] for r in rep.rows()[::5]] == \
        ["10mbit", "50mbit", "100mbit"]
    with pytest.raises(ValueError):
        pipe.run(n_messages=4, placement="bogus")
    # the advisory runs its own DES grid — a scheduler can't apply
    with pytest.raises(ValueError, match="scheduler"):
        pipe.run(placement="advise", scheduler=object())
    # advising without a declared workload must fail loudly, not guess
    anon = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: None,
        process_cloud_function_handler=lambda ctx, data=None: None)
    with pytest.raises(ValueError, match="function_context"):
        anon.run(placement="advise")
    # …and without a declared message size (transfer costs scale with it)
    no_points = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: None,
        process_cloud_function_handler=lambda ctx, data=None: None,
        function_context={"model": "kmeans"})
    with pytest.raises(ValueError, match="n_points"):
        no_points.run(placement="advise")
    # a typo'd model name gets the known-models hint
    with pytest.raises(KeyError, match="known"):
        PlacementAdvisor(n_messages=4).advise("kmean")


def test_advisor_default_sigma_is_the_calibrated_one():
    """Regression pin for the service_sigma plumbing: the advisor's
    default is the *calibrated* per-model sigma (None → spec.sigma via
    ``Scenario.effective_service_sigma``), not 0.0 — tail columns
    reflect measured straggler noise unless explicitly disabled."""
    adv = PlacementAdvisor(n_messages=16)
    assert adv.service_sigma is None
    default_rows = adv.advise("kmeans").rows()
    explicit = PlacementAdvisor(n_messages=16,
                                service_sigma=KMEANS.sigma)
    assert default_rows == explicit.advise("kmeans").rows()
    clean = PlacementAdvisor(n_messages=16, service_sigma=0.0)
    assert default_rows != clean.advise("kmeans").rows()
    # the Scenario-level contract the advisor rides on
    assert Scenario(model=KMEANS).effective_service_sigma == 0.0
    assert Scenario(model=KMEANS, service_sigma=None)\
        .effective_service_sigma == KMEANS.sigma
    assert KMEANS.sigma > 0.0


def test_advisor_multi_objective_columns_and_latency_budget():
    """The multi-objective path: p50/p95/p99 + WAN-byte columns are
    populated and ordered, and kmeans→edge stays top-ranked at 10 Mbit/s
    under a latency budget that kills the cloud cell."""
    rep = PlacementAdvisor(n_messages=32).advise("kmeans",
                                                 latency_budget=2.0)
    assert rep.latency_budget == 2.0
    for c in rep.cells:
        assert (0.0 <= c.latency_p50_s <= c.latency_p95_s
                <= c.latency_p99_s)
        assert c.wan_bytes == pytest.approx(c.wan_mbytes * 1e6)
    best = rep.best("10mbit")
    assert best.placement == "edge" and best.feasible
    # the 10 Mbit cloud cell blows a 2 s p95 budget → flagged, ranked last
    cloud = next(c for c in rep.ranking("10mbit")
                 if c.placement == "cloud")
    assert not cloud.feasible
    assert rep.ranking("10mbit")[-1] is cloud
    # budget filtering never *drops* cells: full grid still reported
    assert len(rep.ranking("10mbit")) == 5


def test_advisor_infeasible_budget_is_ranked_but_flagged():
    """An impossible budget must not return an empty recommendation: the
    full ranking survives, every cell flagged infeasible, and ``best``
    still names the least-bad placement."""
    rep = PlacementAdvisor(n_messages=16).advise(
        "kmeans", latency_budget=1e-9, wan_budget=1e-9)
    assert rep.cells and all(not c.feasible for c in rep.cells)
    assert rep.feasible_cells() == []
    best = rep.best("10mbit")
    assert best.placement == "edge"           # still the right direction
    assert not best.feasible                  # …but honestly flagged
    rows = rep.rows()
    assert len(rows) == 15
    assert all(r["feasible"] is False for r in rows)
    assert sum(r["recommended"] for r in rows) == 3   # one per band
    assert "[over budget]" in rep.table()


def test_advisor_wan_budget_prefers_thin_placements():
    """A WAN budget under the cloud cell's raw-point bytes forces the
    recommendation onto edge/hybrid even on the fast band, where cloud
    would otherwise be throughput-competitive."""
    rep = PlacementAdvisor(n_messages=16).advise("kmeans", wan_budget=5.0)
    for band in ("10mbit", "50mbit", "100mbit"):
        best = rep.best(band)
        assert best.placement in ("edge", "hybrid")
        assert best.feasible
        cloud = next(c for c in rep.ranking(band)
                     if c.placement == "cloud")
        assert not cloud.feasible             # ~20 MB of raw points


def test_advisor_sweeps_hybrid_reduce_per_band():
    """``hybrid_reduce=`` sweeps the edge pre-aggregation factor the same
    way placements are swept: one hybrid cell per factor per band, more
    aggressive reduction → fewer WAN bytes, monotonically."""
    rep = PlacementAdvisor(n_messages=16).advise(
        "kmeans", hybrid_reduce=(5, 10, 20))
    for band in ("10mbit", "50mbit", "100mbit"):
        hybrids = [c for c in rep.ranking(band)
                   if c.placement == "hybrid"]
        assert sorted(c.hybrid_reduce for c in hybrids) == [5, 10, 20]
        by_red = {c.hybrid_reduce: c for c in hybrids}
        assert (by_red[20].wan_bytes < by_red[10].wan_bytes
                < by_red[5].wan_bytes)
        # the fog placement pre-aggregates too (on the fog tier), so the
        # sweep applies there as well — same factors, same monotonicity
        fogs = {c.hybrid_reduce: c for c in rep.ranking(band)
                if c.placement == "fog"}
        assert sorted(fogs) == [5, 10, 20]
        assert (fogs[20].wan_bytes < fogs[10].wan_bytes
                < fogs[5].wan_bytes)
        # edge/cloud cells don't carry a reduce factor
        assert all(c.hybrid_reduce is None for c in rep.ranking(band)
                   if c.placement not in ("hybrid", "fog"))
    # rows stay schema-shaped and deterministic under the sweep
    again = PlacementAdvisor(n_messages=16).advise(
        "kmeans", hybrid_reduce=(5, 10, 20))
    assert rep.rows() == again.rows()


def test_pipeline_run_threads_budget_knobs_to_advisor():
    """``pipe.run(placement='advise', latency_budget=..., ...)`` reaches
    the advisor; the knobs are rejected for normal execution runs."""
    from repro.core import EdgeToCloudPipeline
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=4))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=4))
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: None,
        process_cloud_function_handler=lambda ctx, data=None: None,
        function_context={"model": "kmeans", "n_points": 2_500})
    rep = pipe.run(n_messages=16, placement="advise", latency_budget=2.0,
                   hybrid_reduce=[5, 10])
    assert rep.latency_budget == 2.0
    assert {c.hybrid_reduce for c in rep.cells
            if c.placement == "hybrid"} == {5, 10}
    with pytest.raises(ValueError, match="advise"):
        pipe.run(n_messages=4, wan_budget=1.0)


def test_advisor_sweeps_a_custom_profile_band_table():
    """A custom ContinuumProfile's WAN bands drive both the default band
    sweep and the emulated transfer (not just compute re-pricing)."""
    import dataclasses

    from repro.cost.profiles import LinkModel
    slow = dataclasses.replace(
        DEFAULT_PROFILE,
        wan_bands={"1mbit": LinkModel(1e6 / 8.0, 0.2),
                   "10mbit": LINK_TABLE["10mbit"]},
        default_wan="1mbit")
    rep = PlacementAdvisor(CostModel(profile=slow),
                           n_messages=8).advise("kmeans")
    assert sorted({c.wan_band for c in rep.cells}) == ["10mbit", "1mbit"]
    cell = {(c.wan_band, c.placement): c for c in rep.cells}
    # the 1 Mbit band's cloud cell really is ~10x slower on transfer
    assert (cell[("1mbit", "cloud")].throughput_msgs_s
            < 0.2 * cell[("10mbit", "cloud")].throughput_msgs_s)


# ---------------------------------------------------------------------------
# CI tooling (satellites): check_skips modes + BENCH_placement schema
# ---------------------------------------------------------------------------

def test_check_skips_local_vs_ci_modes():
    tool = _load_tool("check_skips")
    hyp = ["SKIPPED [1] tests/test_properties.py: could not import "
           "'hypothesis': No module named 'hypothesis'"]
    other = ["SKIPPED [1] tests/test_x.py: No module named 'torch'"]
    marker = ["SKIPPED [2] tests/test_y.py: needs >1 device"]
    # CI (strict): any missing dependency fails, including known gaps
    assert tool.check(hyp, strict=True) == 1
    assert tool.check(other, strict=True) == 1
    assert tool.check(marker, strict=True) == 0
    # local: the known image gap stays visible but quiet …
    assert tool.check(hyp, strict=False) == 0
    # … while an *unknown* missing dependency still fails
    assert tool.check(other, strict=False) == 1
    # a path merely *containing* the known-gap word must not mask a new
    # missing dependency (the match is on the import-error clause) …
    sneaky = ["SKIPPED [1] tests/test_hypothesis_broker.py: "
              "No module named 'scipy'"]
    assert tool.check(sneaky, strict=False) == 1
    # … nor a package that merely *starts with* the known-gap name …
    prefixed = ["SKIPPED [1] tests/test_z.py: "
                "No module named 'hypothesis_jsonschema'"]
    assert tool.check(prefixed, strict=False) == 1
    # … while alternative phrasings of the real gap stay locally quiet
    phrased = ["SKIPPED [1] tests/test_y.py: hypothesis is not installed"]
    assert tool.check(phrased, strict=False) == 0
    # --warn-only never fails
    assert tool.check(other, strict=False, warn_only=True) == 0


def test_advisor_rows_match_committed_schema():
    tool = _load_tool("check_bench_schema")
    with open(os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                           "BENCH_placement.schema.json")) as f:
        schema = json.load(f)
    rows = PlacementAdvisor(n_messages=8).advise("isoforest").rows()
    rows = json.loads(json.dumps(rows, default=float))
    errors = []
    tool._check(rows, schema, "$", errors)
    assert errors == []


# ---------------------------------------------------------------------------
# slow lane: live roofline calibration + threaded/sim parity
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_live_roofline_calibration_matches_committed():
    """Re-measuring the kernels' HLO flops on this host must agree with
    the committed calibration (loose band: jax/XLA version drift changes
    fusion decisions, not orders of magnitude)."""
    cal = Calibrator()
    committed = load_calibration()
    for name in ("kmeans", "autoencoder"):
        flops_pp, bytes_pp = cal.measure_kernel(name)
        assert flops_pp == pytest.approx(
            committed[name].kernel_flops_per_point, rel=0.5)
        assert bytes_pp > 0


@pytest.mark.slow
def test_calibration_drift_report_refits_live():
    """The calibration-drift lane's engine: a live refit of
    efficiency/sigma paired against the committed calibration — the
    achieved-fraction-of-peak numbers CI uploads as an artifact.  The
    kernel flops must agree with the committed roofline measurement (the
    deterministic half); the service fit is host-dependent and only needs
    to be a sane fraction of peak."""
    tool = _load_tool("calibration_drift")
    report = tool.drift_report(models=["kmeans"], n_messages=2)
    assert report["meta"]["n_messages"] == 2
    (row,) = report["models"]
    assert row["model"] == "kmeans"
    # same band as the CI gate below — the two lanes must agree on what
    # counts as kernel drift
    assert 0.5 <= row["kernel_flops_ratio"] <= 2.0
    # host-dependent by design: only sanity, never a band (the CI lane
    # deliberately refuses to gate the live service fit)
    assert row["achieved_fraction_of_peak"] > 0.0
    assert row["committed_efficiency"] == \
        load_calibration()["kmeans"].efficiency
    assert row["sigma"] >= 0.0
    # the CLI wrapper round-trips and honors the kernel-drift gate
    assert tool.main(["--models", "kmeans", "--messages", "2",
                      "--max-kernel-drift", "2.0"]) == 0


@pytest.mark.slow
def test_threaded_paced_throughput_matches_sim_prediction():
    """The satellite's parity gate: the same pipeline paced by the same
    calibrated service model must deliver comparable throughput on real
    threads (ThreadedExecutor) and under the DES (SimExecutor)."""
    from repro.core import (EdgeToCloudPipeline, MetricsRegistry, SimClock,
                            SimExecutor, ThreadedExecutor)

    def build(clock=None):
        metrics = MetricsRegistry(clock=clock) if clock else None
        mgr = PilotManager(devices=(), clock=clock)
        edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
        cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
        payload = np.arange(64, dtype=np.float64)
        return EdgeToCloudPipeline(
            pilot_cloud_processing=cloud, pilot_edge=edge,
            produce_function_handler=lambda ctx: payload,
            process_cloud_function_handler=lambda ctx, data=None: 0.0,
            n_edge_devices=2, cloud_consumers=2,
            metrics=metrics, clock=clock)

    stage_s = {"produce": 0.02, "process_cloud": 0.06}
    service = CostModel().service_model(stage_s)
    n = 16

    clock = SimClock()
    sim_res = build(clock).run(
        n_messages=n, timeout_s=600.0,
        scheduler=SimExecutor(clock=clock, service_model=service))
    assert sim_res.n_processed == n
    predicted = n / sim_res.wall_s

    threaded_res = build().run(
        n_messages=n, timeout_s=60.0,
        scheduler=ThreadedExecutor(service_model=service))
    assert threaded_res.n_processed == n
    live = n / threaded_res.wall_s
    # tolerance band: thread scheduling overhead only slows the live run
    # (never speeds it past the prediction), and even a loaded CI runner
    # stays within ~3x at these stage costs
    assert 0.3 < live / predicted < 1.3


@pytest.mark.slow
def test_threaded_and_sim_speculation_agree_on_who_wins():
    """Speculation parity (extends the threaded-vs-sim pattern above):
    the same calibrated workload with the same noisy service model must
    show the same who-wins direction under
    ``ThreadedExecutor(speculative_factor=...)`` (inline
    first-completion-wins races on real threads) and ``SimExecutor``
    (event-scheduled backup races).  At the calibrated k-means sigma,
    stragglers barely overshoot the threshold, so the primary wins
    almost every race: losses strictly dominate wins in both worlds
    (exact counts differ — thread interleaving reorders the rng draws).
    The surplus consumers (4 over 2 partitions) are the idle capacity
    the capacity-aware backups steal in both worlds."""
    from repro.core import (EdgeToCloudPipeline, MetricsRegistry, SimClock,
                            SimExecutor, ThreadedExecutor)

    def build(clock=None):
        metrics = MetricsRegistry(clock=clock) if clock else None
        mgr = PilotManager(devices=(), clock=clock)
        edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
        cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                                 n_workers=4))
        payload = np.arange(64, dtype=np.float64)
        return EdgeToCloudPipeline(
            pilot_cloud_processing=cloud, pilot_edge=edge,
            produce_function_handler=lambda ctx: payload,
            process_cloud_function_handler=lambda ctx, data=None: 0.0,
            n_edge_devices=2, cloud_consumers=4,
            metrics=metrics, clock=clock)

    def make_service():
        # scaled-down calibrated shape: cloud-heavy stage costs with the
        # calibrated k-means noise
        return CostModel().service_model(
            {"produce": 0.005, "process_cloud": 0.02},
            sigma=KMEANS.sigma, seed=11)

    factor, n = 1.1, 48

    clock = SimClock()
    sim_res = build(clock).run(
        n_messages=n, timeout_s=600.0,
        scheduler=SimExecutor(clock=clock, service_model=make_service(),
                              speculative_factor=factor))
    assert sim_res.n_processed == n
    sim_m = sim_res.metrics

    threaded_res = build().run(
        n_messages=n, timeout_s=120.0,
        scheduler=ThreadedExecutor(service_model=make_service(),
                                   speculative_factor=factor))
    assert threaded_res.n_processed == n
    thr_m = threaded_res.metrics

    for m in (sim_m, thr_m):
        launches = m.counter("runtime.speculative_launches")
        wins = m.counter("runtime.speculative_wins")
        losses = m.counter("runtime.speculative_losses")
        cancelled = m.counter("runtime.speculative_cancelled")
        assert launches > 0                    # stragglers actually raced
        assert wins + losses + cancelled == launches
        assert losses > wins                   # the shared direction


# ---------------------------------------------------------------------------
# the precision placement axis (tentpole): quantized variants as models
# ---------------------------------------------------------------------------

def test_calibration_carries_precision_variants():
    """The committed calibration registers the reduced-precision kmeans
    variants as first-class models with their precision stamped."""
    cal = load_calibration()
    assert {"kmeans", "kmeans_bf16", "kmeans_int8"} <= set(cal)
    assert cal["kmeans"].precision == "fp32"
    assert cal["kmeans_bf16"].precision == "bf16"
    assert cal["kmeans_int8"].precision == "int8"
    # precision survives the ModelSpec resolution the advisor rides
    specs = model_specs()
    assert specs["kmeans_int8"].precision == "int8"
    assert specs["kmeans_int8"].task_profile(2500).precision == "int8"
    # variants share the fp32 kernel's transfer profile (same output)
    assert cal["kmeans_int8"].output_bytes == cal["kmeans"].output_bytes


def test_device_tier_prices_precision_speedups():
    """The device SoC is an FPU-less MCU with a micro-NPU: int8 runs two
    orders of magnitude denser than software fp32, and the cost model
    prices compute_s accordingly."""
    from repro.cost.profiles import DEVICE_SOC
    assert DEVICE_SOC.speedup("fp32") == 1.0
    assert DEVICE_SOC.speedup("int8") == 100.0
    with pytest.raises(ValueError, match="precision"):
        DEVICE_SOC.speedup("fp64")
    cm = CostModel()
    f = 1e9
    assert cm.compute_s(f, "device", 1, "int8") == pytest.approx(
        cm.compute_s(f, "device", 1, "fp32") / 100.0)
    # cloud/edge accelerators keep the generic 2x/4x datapath multipliers
    assert cm.tier_flops("cloud", 1, "bf16") == \
        pytest.approx(2.0 * cm.tier_flops("cloud"))


def test_advisor_precision_split_on_device_tier():
    """Acceptance pin: under a 2 s p95 budget at 10 Mbit/s the fp32
    k-means is infeasible on the device tier (software floats on the
    MCU) while the int8 variant is feasible and ranked — with the
    accuracy column stamped on every cell."""
    adv = PlacementAdvisor(n_messages=32)
    fp32 = adv.advise("kmeans", bands=("10mbit",), latency_budget=2.0)
    int8 = adv.advise("kmeans_int8", bands=("10mbit",), latency_budget=2.0)
    dev_fp32 = next(c for c in fp32.cells if c.placement == "device")
    dev_int8 = next(c for c in int8.cells if c.placement == "device")
    assert not dev_fp32.feasible and dev_fp32.latency_p95_s > 2.0
    assert dev_int8.feasible and dev_int8.latency_p95_s <= 2.0
    # the accuracy-vs-latency trade-off columns
    assert dev_fp32.precision == "fp32"
    assert dev_fp32.agreement_vs_fp32 == 1.0
    assert dev_int8.precision == "int8"
    assert 0.99 <= dev_int8.agreement_vs_fp32 < 1.0
    # the feasible int8 device cell is genuinely ranked, not flagged last
    ranked = int8.ranking("10mbit")
    assert ranked.index(dev_int8) < len(ranked) - 1
    rows = int8.rows()
    assert all(r["precision"] == "int8" for r in rows)
    assert all(0.99 <= r["agreement_vs_fp32"] < 1.0 for r in rows)
