"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.broker import Broker
from repro.core.monitoring import MetricsRegistry
from repro.core.placement import (DEFAULT_LINKS, LinkModel, PlacementEngine,
                                  TaskProfile, link_between)
from repro.kernels import ref
from repro.ml.isoforest import _c as iso_c
from repro.optim import clip_by_global_norm, cosine_schedule
from repro.optim.compression import int8_compress, int8_decompress

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# broker invariants
# ---------------------------------------------------------------------------

@given(n_msgs=st.integers(1, 40), n_parts=st.integers(1, 6),
       seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_broker_conserves_messages_and_order(n_msgs, n_parts, seed):
    """Every produced message lands in exactly one partition; offsets are
    dense and ordered; total bytes in == sum of message sizes."""
    b = Broker()
    t = b.create_topic("t", n_partitions=n_parts)
    rng = np.random.default_rng(seed)
    sizes = []
    for i in range(n_msgs):
        data = rng.standard_normal((int(rng.integers(1, 50)),))
        m = t.produce(data)
        sizes.append(m.nbytes)
    ends = t.end_offsets()
    assert sum(ends) == n_msgs
    for p, end in enumerate(ends):
        offs = [t.partitions[p].log[i].offset for i in range(end)]
        assert offs == list(range(end))
    assert t.metrics.counter(f"topic.{t.name}.bytes_in") == sum(sizes)


# ---------------------------------------------------------------------------
# quantization invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
@settings(**SETTINGS)
def test_int8_quantization_error_bound(xs):
    g = jnp.asarray(xs, jnp.float32)
    q, scale = int8_compress(g)
    back = int8_decompress(q, scale)
    # error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) / 2 + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_cosine_schedule_bounds(step):
    lr = cosine_schedule(1e-3, warmup=100, total=10_000)(step)
    assert 0.0 < float(lr) <= 1e-3 + 1e-9


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=32),
       st.floats(0.1, 10.0))
@settings(**SETTINGS)
def test_clip_by_global_norm_invariant(xs, max_norm):
    g = {"w": jnp.asarray(xs, jnp.float32)}
    clipped, gnorm = clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.sqrt(jnp.sum(clipped["w"] ** 2)))
    assert new_norm <= max_norm * 1.01 + 1e-5


# ---------------------------------------------------------------------------
# attention / softmax invariants
# ---------------------------------------------------------------------------

@given(s=st.integers(2, 24), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_causal_attention_prefix_invariance(s, d, seed):
    """Causal attention at position i ignores tokens > i: truncating the
    suffix never changes earlier outputs."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, s, 2, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, 2, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, 2, d)), jnp.float32)
    full = ref.flash_attention_ref(q, k, v, causal=True)
    cut = s // 2
    part = ref.flash_attention_ref(q[:, :cut], k[:, :cut], v[:, :cut],
                                   causal=True)
    np.testing.assert_allclose(np.asarray(full[:, :cut]),
                               np.asarray(part), atol=1e-5, rtol=1e-5)


@given(n=st.integers(1, 200), f=st.integers(1, 40),
       k=st.integers(1, 30), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_kmeans_assignment_is_nearest(n, f, k, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    cent = jnp.asarray(rng.standard_normal((k, f)), jnp.float32)
    ids, dmin = ref.kmeans_assign_ref(pts, cent)
    # brute-force check
    d_all = np.linalg.norm(np.asarray(pts)[:, None] - np.asarray(cent),
                           axis=-1)
    np.testing.assert_allclose(np.asarray(dmin), d_all.min(1), atol=1e-3)
    chosen = d_all[np.arange(n), np.asarray(ids)]
    np.testing.assert_allclose(chosen, d_all.min(1), atol=1e-3)


# ---------------------------------------------------------------------------
# placement invariants
# ---------------------------------------------------------------------------

@given(flops=st.floats(0, 1e15), nbytes=st.floats(0, 1e9))
@settings(**SETTINGS)
def test_placement_estimates_monotone(flops, nbytes):
    """More flops or more bytes never decreases estimated time."""
    from repro.core import ComputeResource, PilotManager
    mgr = PilotManager()
    p = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    eng = PlacementEngine()
    base = eng.estimate(TaskProfile(flops=flops, input_bytes=nbytes,
                                    input_tier="edge"), p).est_time_s
    more_f = eng.estimate(TaskProfile(flops=flops * 2 + 1,
                                      input_bytes=nbytes,
                                      input_tier="edge"), p).est_time_s
    more_b = eng.estimate(TaskProfile(flops=flops,
                                      input_bytes=nbytes * 2 + 1,
                                      input_tier="edge"), p).est_time_s
    assert more_f >= base - 1e-12
    assert more_b >= base - 1e-12


@given(st.sampled_from(["edge", "cloud", "hpc"]),
       st.sampled_from(["edge", "cloud", "hpc"]))
@settings(**SETTINGS)
def test_link_model_symmetric(a, b):
    la = link_between(a, b, DEFAULT_LINKS)
    lb = link_between(b, a, DEFAULT_LINKS)
    assert la == lb


# ---------------------------------------------------------------------------
# monitoring invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 5), st.floats(0, 10)),
                min_size=1, max_size=50))
@settings(**SETTINGS)
def test_metrics_latency_nonnegative(events):
    reg = MetricsRegistry(clock=lambda: test_metrics_latency_nonnegative._t)
    test_metrics_latency_nonnegative._t = 0.0
    for msg_i, dt in events:
        reg.stamp(f"m{msg_i}", "produced")
        test_metrics_latency_nonnegative._t += abs(dt)
        reg.stamp(f"m{msg_i}", "processed")
    for lat in reg.latencies():
        assert lat >= 0


# ---------------------------------------------------------------------------
# isolation-forest path length maths
# ---------------------------------------------------------------------------

@given(st.integers(2, 10_000))
@settings(**SETTINGS)
def test_iso_c_monotone(n):
    assert float(iso_c(n + 1)) >= float(iso_c(n)) - 1e-5
    assert float(iso_c(n)) > 0


# ---------------------------------------------------------------------------
# SSD invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 30), s=st.sampled_from([16, 32]))
@settings(max_examples=10, deadline=None)
def test_ssd_prefix_causality(seed, s):
    """SSD is causal: output at t depends only on inputs <= t."""
    rng = np.random.default_rng(seed)
    b, nh, hd, g, ds = 1, 2, 8, 1, 8
    xh = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, s, nh)), jnp.float32)
    A = -jnp.ones((nh,), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((b, s, g, ds)), jnp.float32)
    C_ = jnp.asarray(rng.standard_normal((b, s, g, ds)), jnp.float32)
    D = jnp.zeros((nh,), jnp.float32)
    y_full, _ = ref.ssd_ref(xh, dt, A, B_, C_, D)
    cut = s // 2
    y_half, _ = ref.ssd_ref(xh[:, :cut], dt[:, :cut], A, B_[:, :cut],
                            C_[:, :cut], D)
    np.testing.assert_allclose(np.asarray(y_full[:, :cut]),
                               np.asarray(y_half), atol=1e-4, rtol=1e-4)
