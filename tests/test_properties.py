"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# no custom reason=: pytest's default "could not import 'hypothesis'"
# message is what tools/check_skips.py keys its missing-dependency and
# known-image-gap detection on
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.broker import Broker, ConsumerGroup, WanShaper
from repro.core.monitoring import MetricsRegistry
from repro.sim.clock import SimClock
from repro.core.placement import (DEFAULT_LINKS, LinkModel, PlacementEngine,
                                  TaskProfile, link_between)
from repro.kernels import ref
from repro.ml.isoforest import _c as iso_c
from repro.optim import clip_by_global_norm, cosine_schedule
from repro.optim.compression import int8_compress, int8_decompress

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# broker invariants
# ---------------------------------------------------------------------------

@given(n_msgs=st.integers(1, 40), n_parts=st.integers(1, 6),
       seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_broker_conserves_messages_and_order(n_msgs, n_parts, seed):
    """Every produced message lands in exactly one partition; offsets are
    dense and ordered; total bytes in == sum of message sizes."""
    b = Broker()
    t = b.create_topic("t", n_partitions=n_parts)
    rng = np.random.default_rng(seed)
    sizes = []
    for i in range(n_msgs):
        data = rng.standard_normal((int(rng.integers(1, 50)),))
        m = t.produce(data)
        sizes.append(m.nbytes)
    ends = t.end_offsets()
    assert sum(ends) == n_msgs
    for p, end in enumerate(ends):
        offs = [t.partitions[p].log[i].offset for i in range(end)]
        assert offs == list(range(end))
    assert t.metrics.counter(f"topic.{t.name}.bytes_in") == sum(sizes)


@given(n_msgs=st.integers(1, 30), n_parts=st.integers(1, 5),
       n_consumers=st.integers(1, 4), seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_consumer_group_at_least_once_no_offset_gaps(n_msgs, n_parts,
                                                     n_consumers, seed):
    """Under virtual time, with random consumer crashes/joins mid-stream,
    the group delivers every offset at least once (gaps are impossible:
    commits only advance past processed offsets) and commits never move
    backwards."""
    clock = SimClock()
    b = Broker(clock=clock)
    t = b.create_topic("t", n_partitions=n_parts)
    g = ConsumerGroup(t)
    rng = np.random.default_rng(seed)
    consumers = [f"c{i}" for i in range(n_consumers)]
    for c in consumers:
        g.join(c)
    for i in range(n_msgs):
        t.produce(np.array([i]))
    seen = set()
    deliveries = 0
    alive = list(consumers)
    for _ in range(40 * n_msgs + 400):
        if g.lag() == 0:
            break
        # late re-join of a previously crashed member
        if len(alive) < n_consumers and rng.random() < 0.15:
            back = [c for c in consumers if c not in alive][0]
            alive.append(back)
            g.join(back)
        cid = alive[rng.integers(0, len(alive))]
        before = list(g.committed)
        msg, _ = g.poll_nowait(cid)
        if msg is None:
            clock.advance(0.01)
            continue
        deliveries += 1
        seen.add(int(msg.value()[0]))
        if len(alive) > 1 and rng.random() < 0.2:
            # crash *before* the commit: the offset must be redelivered
            # to a surviving member after the rebalance
            alive.remove(cid)
            g.leave(cid)
        else:
            g.commit(msg)
            assert all(a >= b_ for a, b_ in zip(g.committed, before)), \
                "commit moved backwards"
    assert g.lag() == 0
    assert deliveries >= n_msgs          # at-least-once
    assert seen == set(range(n_msgs))    # every offset delivered, no gaps


@given(n_msgs=st.integers(1, 50), n_parts=st.integers(1, 4),
       n_consumers=st.integers(1, 4), batch=st.integers(1, 8),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_log_truncation_preserves_at_least_once(n_msgs, n_parts,
                                                n_consumers, batch, seed):
    """With log truncation on, across random commit/crash/rejoin/
    late-second-group interleavings: nothing at or above any group's
    committed offset is ever reclaimed (the log start never passes a
    group's committed position), absolute offsets survive truncation,
    and the group still delivers every message at least once."""
    clock = SimClock()
    b = Broker(clock=clock)
    t = b.create_topic("t", n_partitions=n_parts, truncate_batch=batch)
    g = ConsumerGroup(t, group_id="g1")
    groups = [g]
    rng = np.random.default_rng(seed)
    consumers = [f"c{i}" for i in range(n_consumers)]
    for c in consumers:
        g.join(c)
    for i in range(n_msgs):
        t.produce(np.array([i]))
    seen = set()
    deliveries = 0
    alive = list(consumers)
    second = None

    def check_invariants():
        starts = t.log_start_offsets()
        ends = t.end_offsets()
        for p in range(n_parts):
            for grp in groups:
                assert starts[p] <= grp.committed[p], \
                    "truncation reclaimed an uncommitted offset"
            # retained messages keep their absolute offsets, densely
            part = t.partitions[p]
            offs = [m.offset for m in part.log]
            assert offs == list(range(starts[p], ends[p]))

    for _ in range(40 * n_msgs + 400):
        check_invariants()
        if g.lag() == 0:
            break
        # a late second group joins mid-stream: it must start at the log
        # start (replaying the retained tail) and from then on bound
        # further truncation
        if second is None and rng.random() < 0.05:
            second = ConsumerGroup(t, group_id="g2")
            groups.append(second)
            second.join("z0")
            assert second.committed == t.log_start_offsets()
        if second is not None and rng.random() < 0.3:
            msg, _ = second.poll_nowait("z0")
            if msg is not None:
                second.commit(msg)
        if len(alive) < n_consumers and rng.random() < 0.15:
            back = [c for c in consumers if c not in alive][0]
            alive.append(back)
            g.join(back)
        cid = alive[rng.integers(0, len(alive))]
        msg, _ = g.poll_nowait(cid)
        if msg is None:
            clock.advance(0.01)
            continue
        deliveries += 1
        seen.add(int(msg.value()[0]))
        if len(alive) > 1 and rng.random() < 0.2:
            # crash *before* the commit: the offset must be redelivered
            # to a surviving member after the rebalance — truncation must
            # not have reclaimed it meanwhile
            alive.remove(cid)
            g.leave(cid)
        else:
            g.commit(msg)
    check_invariants()
    assert g.lag() == 0
    assert deliveries >= n_msgs          # at-least-once
    assert seen == set(range(n_msgs))    # every offset delivered, no gaps
    if n_consumers == 1 and second is None and n_msgs >= batch * n_parts:
        assert t.truncated_msgs > 0      # retention actually exercised


@given(nbytes=st.integers(1, 10**7), extra=st.integers(0, 10**6),
       bw_mbit=st.floats(1.0, 200.0), rtt_ms=st.floats(0.0, 500.0))
@settings(**SETTINGS)
def test_wan_shaper_monotone_in_size(nbytes, extra, bw_mbit, rtt_ms):
    """delay_for is monotone in message size (a fresh shaper each side so
    the token bucket doesn't couple the two measurements)."""
    kw = dict(bandwidth_bps=bw_mbit * 1e6, rtt_s=rtt_ms / 1e3, sleep=False)
    d_small = WanShaper(**kw).delay_for(nbytes, now=0.0)
    d_big = WanShaper(**kw).delay_for(nbytes + extra, now=0.0)
    assert d_big >= d_small - 1e-12
    assert d_small >= rtt_ms / 1e3 / 2.0 - 1e-12


@given(sizes=st.lists(st.integers(1, 10**6), min_size=2, max_size=20),
       bw_mbit=st.floats(1.0, 200.0))
@settings(**SETTINGS)
def test_wan_shaper_serializes_link(sizes, bw_mbit):
    """Back-to-back messages queue behind each other: total occupancy of
    the link equals the sum of the individual transmit times, and each
    message's clear time is at least the previous one's."""
    sh = WanShaper(bandwidth_bps=bw_mbit * 1e6, rtt_s=0.0, sleep=False)
    clears = [sh.delay_for(n, now=0.0) for n in sizes]
    assert all(b >= a - 1e-9 for a, b in zip(clears, clears[1:]))
    total_tx = sum(n * 8.0 / (bw_mbit * 1e6) for n in sizes)
    np.testing.assert_allclose(clears[-1], total_tx, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# quantization invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
@settings(**SETTINGS)
def test_int8_quantization_error_bound(xs):
    g = jnp.asarray(xs, jnp.float32)
    q, scale = int8_compress(g)
    back = int8_decompress(q, scale)
    # error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) / 2 + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_cosine_schedule_bounds(step):
    lr = cosine_schedule(1e-3, warmup=100, total=10_000)(step)
    assert 0.0 < float(lr) <= 1e-3 + 1e-9


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=32),
       st.floats(0.1, 10.0))
@settings(**SETTINGS)
def test_clip_by_global_norm_invariant(xs, max_norm):
    g = {"w": jnp.asarray(xs, jnp.float32)}
    clipped, gnorm = clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.sqrt(jnp.sum(clipped["w"] ** 2)))
    assert new_norm <= max_norm * 1.01 + 1e-5


# ---------------------------------------------------------------------------
# attention / softmax invariants
# ---------------------------------------------------------------------------

@given(s=st.integers(2, 24), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_causal_attention_prefix_invariance(s, d, seed):
    """Causal attention at position i ignores tokens > i: truncating the
    suffix never changes earlier outputs."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, s, 2, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, 2, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, 2, d)), jnp.float32)
    full = ref.flash_attention_ref(q, k, v, causal=True)
    cut = s // 2
    part = ref.flash_attention_ref(q[:, :cut], k[:, :cut], v[:, :cut],
                                   causal=True)
    np.testing.assert_allclose(np.asarray(full[:, :cut]),
                               np.asarray(part), atol=1e-5, rtol=1e-5)


@given(n=st.integers(1, 200), f=st.integers(1, 40),
       k=st.integers(1, 30), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_kmeans_assignment_is_nearest(n, f, k, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    cent = jnp.asarray(rng.standard_normal((k, f)), jnp.float32)
    ids, dmin = ref.kmeans_assign_ref(pts, cent)
    # brute-force check
    d_all = np.linalg.norm(np.asarray(pts)[:, None] - np.asarray(cent),
                           axis=-1)
    np.testing.assert_allclose(np.asarray(dmin), d_all.min(1), atol=1e-3)
    chosen = d_all[np.arange(n), np.asarray(ids)]
    np.testing.assert_allclose(chosen, d_all.min(1), atol=1e-3)


# ---------------------------------------------------------------------------
# placement invariants
# ---------------------------------------------------------------------------

@given(flops=st.floats(0, 1e15), nbytes=st.floats(0, 1e9))
@settings(**SETTINGS)
def test_placement_estimates_monotone(flops, nbytes):
    """More flops or more bytes never decreases estimated time."""
    from repro.core import ComputeResource, PilotManager
    mgr = PilotManager()
    p = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    eng = PlacementEngine()
    base = eng.estimate(TaskProfile(flops=flops, input_bytes=nbytes,
                                    input_tier="edge"), p).est_time_s
    more_f = eng.estimate(TaskProfile(flops=flops * 2 + 1,
                                      input_bytes=nbytes,
                                      input_tier="edge"), p).est_time_s
    more_b = eng.estimate(TaskProfile(flops=flops,
                                      input_bytes=nbytes * 2 + 1,
                                      input_tier="edge"), p).est_time_s
    assert more_f >= base - 1e-12
    assert more_b >= base - 1e-12


@given(st.sampled_from(["edge", "cloud", "hpc"]),
       st.sampled_from(["edge", "cloud", "hpc"]))
@settings(**SETTINGS)
def test_link_model_symmetric(a, b):
    la = link_between(a, b, DEFAULT_LINKS)
    lb = link_between(b, a, DEFAULT_LINKS)
    assert la == lb


# ---------------------------------------------------------------------------
# monitoring invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 5), st.floats(0, 10)),
                min_size=1, max_size=50))
@settings(**SETTINGS)
def test_metrics_latency_nonnegative(events):
    reg = MetricsRegistry(clock=lambda: test_metrics_latency_nonnegative._t)
    test_metrics_latency_nonnegative._t = 0.0
    for msg_i, dt in events:
        reg.stamp(f"m{msg_i}", "produced")
        test_metrics_latency_nonnegative._t += abs(dt)
        reg.stamp(f"m{msg_i}", "processed")
    for lat in reg.latencies():
        assert lat >= 0


# ---------------------------------------------------------------------------
# isolation-forest path length maths
# ---------------------------------------------------------------------------

@given(st.integers(2, 10_000))
@settings(**SETTINGS)
def test_iso_c_monotone(n):
    assert float(iso_c(n + 1)) >= float(iso_c(n)) - 1e-5
    assert float(iso_c(n)) > 0


# ---------------------------------------------------------------------------
# SSD invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 30), s=st.sampled_from([16, 32]))
@settings(max_examples=10, deadline=None)
def test_ssd_prefix_causality(seed, s):
    """SSD is causal: output at t depends only on inputs <= t."""
    rng = np.random.default_rng(seed)
    b, nh, hd, g, ds = 1, 2, 8, 1, 8
    xh = jnp.asarray(rng.standard_normal((b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, s, nh)), jnp.float32)
    A = -jnp.ones((nh,), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((b, s, g, ds)), jnp.float32)
    C_ = jnp.asarray(rng.standard_normal((b, s, g, ds)), jnp.float32)
    D = jnp.zeros((nh,), jnp.float32)
    y_full, _ = ref.ssd_ref(xh, dt, A, B_, C_, D)
    cut = s // 2
    y_half, _ = ref.ssd_ref(xh[:, :cut], dt[:, :cut], A, B_[:, :cut],
                            C_[:, :cut], D)
    np.testing.assert_allclose(np.asarray(y_full[:, :cut]),
                               np.asarray(y_half), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# compacting event-heap invariants (the DES hot path)
# ---------------------------------------------------------------------------

from repro.sim import EventScheduler  # noqa: E402


@given(data=st.data())
@settings(**SETTINGS)
def test_event_heap_order_and_len_under_interleaving(data):
    """Under any interleaving of at/after/cancel/step, ``len(sched)``
    equals the number of scheduled-but-unfired-and-uncancelled events,
    and events fire in (time, insertion order) — cancelled entries are
    never executed and never perturb the tie-break of survivors."""
    sched = EventScheduler()
    fired = []
    model = {}                           # ev_id -> (t, insertion_seq)
    handles = {}
    next_id = 0
    ops = data.draw(st.lists(
        st.sampled_from(["at", "after", "cancel", "step"]),
        min_size=1, max_size=120))
    for op in ops:
        if op in ("at", "after"):
            i = next_id
            next_id += 1
            fn = lambda i=i: fired.append(i)      # noqa: E731
            if op == "at":
                t = data.draw(st.sampled_from(
                    [0.0, 0.5, 1.0, 1.5, 2.0, 5.0]))
                t = max(t, sched.clock.now())     # at() clamps to now
                handles[i] = sched.at(t, fn)
            else:
                d = data.draw(st.sampled_from([0.0, 0.5, 2.0]))
                t = sched.clock.now() + d
                handles[i] = sched.after(d, fn)
            model[i] = (t, i)
        elif op == "cancel" and model:
            i = data.draw(st.sampled_from(sorted(model)))
            handles[i].cancel()
            del model[i]
        elif op == "step":
            ran = sched.step()
            if model:
                expect = min(model, key=model.get)
                assert ran and fired[-1] == expect
                del model[expect]
            else:
                assert not ran
        assert len(sched) == len(model)
    # drain: the survivors fire in model order, nothing extra, len hits 0
    rest = sorted(model, key=model.get)
    n_before = len(fired)
    sched.run()
    assert fired[n_before:] == rest
    assert len(sched) == 0


@given(n_total=st.integers(80, 200), n_keep=st.integers(1, 10),
       seed=st.integers(0, 500))
@settings(**SETTINGS)
def test_event_heap_compaction_drops_nothing_reorders_nothing(
        n_total, n_keep, seed):
    """Mass cancellation crosses the compaction threshold (dead > 64 and
    dead > live): the rebuilt heap must still fire exactly the surviving
    events, in (time, insertion) order, with ``len`` intact throughout."""
    rng = np.random.default_rng(seed)
    sched = EventScheduler()
    fired = []
    times = rng.integers(0, 8, size=n_total) * 0.5
    handles = [sched.at(float(t), lambda i=i: fired.append(i))
               for i, t in enumerate(times)]
    keep = set(rng.choice(n_total, size=n_keep, replace=False).tolist())
    for i, h in enumerate(handles):
        if i not in keep:
            h.cancel()
        assert len(sched) == n_total - (i + 1 - len(keep & set(range(i + 1))))
    assert sched.compactions >= 1        # the sweep actually compacted
    assert len(sched) == len(keep)
    sched.run()
    assert fired == sorted(keep, key=lambda i: (times[i], i))
    assert len(sched) == 0


@given(data=st.data())
@settings(**SETTINGS)
def test_event_heap_cancel_then_run_until_is_consistent(data):
    """run(until=) interleaved with cancellation: executed count, firing
    order and the clock's final position all agree with the model."""
    sched = EventScheduler()
    fired = []
    n = data.draw(st.integers(1, 60))
    ts = [data.draw(st.sampled_from([0.0, 1.0, 2.0, 3.0, 4.0]))
          for _ in range(n)]
    handles = [sched.at(t, lambda i=i: fired.append(i))
               for i, t in enumerate(ts)]
    cancelled = set()
    for i in range(n):
        if data.draw(st.booleans()):
            handles[i].cancel()
            cancelled.add(i)
    until = data.draw(st.sampled_from([0.5, 1.5, 2.5, 5.0]))
    ran = sched.run(until=until)
    live = [i for i in range(n) if i not in cancelled]
    expect_now = [i for i in live if ts[i] <= until]
    assert ran == len(expect_now)
    assert fired == sorted(expect_now, key=lambda i: (ts[i], i))
    assert sched.clock.now() == until    # bounded run covers its window
    sched.run()
    assert fired == sorted(expect_now, key=lambda i: (ts[i], i)) + sorted(
        (i for i in live if ts[i] > until), key=lambda i: (ts[i], i))
