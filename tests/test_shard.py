"""Sharded DES tests: seed splitting, partitioning helpers, boundary
injection, shard-count determinism, and the conservative-window
causality property.

The property tests follow the repo's stubbed-hypothesis idiom (the
container has no ``hypothesis``): seed-parametrized
``np.random.default_rng`` loops drawing randomized configurations.
"""
import math

import numpy as np
import pytest

from repro.core import ComputeResource, PilotManager
from repro.core.broker import Broker
from repro.core.faas import ContinuumPipeline, StageSpec
from repro.core.monitoring import MetricsRegistry
from repro.core.placement import PlacementEngine
from repro.sim.clock import SimClock
from repro.sim.shard import (ShardCoordinator, build_scale_shard,
                             lookahead_s, merge_rows, run_scale_sharded,
                             shard_seed, split_blocks, tier_cut_builders)

# ---------------------------------------------------------------------------
# seed splitting
# ---------------------------------------------------------------------------


def test_shard_seed_pinned():
    # pinned SplitMix64 outputs: the per-shard streams are part of the
    # determinism contract, so the mix itself must never drift
    assert shard_seed(0, 0) == 16294208416658607535
    assert shard_seed(0, 1) == 7960286522194355700
    assert shard_seed(0, 2) == 487617019471545679
    assert shard_seed(12345, 7) == 7959005890829367068


def test_shard_seed_streams_distinct_and_64bit():
    seen = set()
    for seed in range(8):
        for sid in range(64):
            z = shard_seed(seed, sid)
            assert 0 <= z < 2 ** 64
            seen.add(z)
    assert len(seen) == 8 * 64          # no collisions across the grid


def test_shard_seed_differs_from_naive_offset():
    # the point of the split: stream (seed, sid) is not stream
    # (seed + sid, 0) of the same family
    assert shard_seed(0, 1) != shard_seed(1, 0)


# ---------------------------------------------------------------------------
# partitioning helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_split_blocks_properties(seed):
    rng = np.random.default_rng(seed)
    for _ in range(50):
        n = int(rng.integers(0, 200))
        k = int(rng.integers(1, 17))
        blocks = split_blocks(n, k)
        assert len(blocks) == k
        # exact disjoint cover of range(n), in order
        flat = [i for lo, hi in blocks for i in range(lo, hi)]
        assert flat == list(range(n))
        sizes = [hi - lo for lo, hi in blocks]
        assert max(sizes) - min(sizes) <= 1


def test_split_blocks_monotone_in_n():
    # consumers >= devices globally must imply it per shard: block i of
    # the larger n always covers at least block i of the smaller n
    for k in (1, 2, 3, 5, 8):
        for devices in (3, 8, 17):
            for consumers in (devices, devices + 1, 4 * devices):
                dev = split_blocks(devices, k)
                con = split_blocks(consumers, k)
                for (dlo, dhi), (clo, chi) in zip(dev, con):
                    assert chi - clo >= dhi - dlo


def test_split_blocks_rejects_bad_k():
    with pytest.raises(ValueError):
        split_blocks(10, 0)


def test_lookahead_from_cost_model():
    cost = PlacementEngine().cost
    la = lookahead_s(cost, [("edge", "cloud")])
    # pure routed link latency of the edge->cloud WAN hop
    assert la == cost.route("edge", "cloud").transfer_s(0.0)
    assert la > 0.0
    # min over the cut set
    multi = lookahead_s(cost, [("edge", "cloud"), ("device", "edge")])
    assert multi == min(
        cost.route("edge", "cloud").transfer_s(0.0),
        cost.route("device", "edge").transfer_s(0.0))
    # no cut links -> fully independent shards -> one unbounded window
    assert lookahead_s(cost, []) == math.inf


# ---------------------------------------------------------------------------
# boundary injection
# ---------------------------------------------------------------------------


def test_inject_skips_ingress_accounting():
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    broker = Broker(metrics=metrics, clock=clock)
    topic = broker.create_topic("boundary", n_partitions=2)
    msg = topic.inject(b"x" * 32, msg_id="m-1", partition=1, ready_at=4.0,
                       produced_t=2.5)
    # ingress counters belong to the producing shard: injection must not
    # double-count bytes/messages on the receiving side
    assert metrics.counter("topic.boundary.bytes_in") == 0.0
    assert metrics.counter("topic.boundary.msgs_in") == 0.0
    part = topic.partitions[1]
    assert part.log[-1] is msg
    assert part.ready_at[-1] == 4.0
    # the produced stamp carries the original production time across the
    # process boundary (end-to-end latency stays exact)
    assert metrics.trace("m-1").stamps["produced"] == 2.5


def test_scale_shard_refuses_partition_coupling():
    # consumers < devices couples partitions through shared consumers:
    # the documented too-chatty-to-shard condition
    with pytest.raises(ValueError, match="too chatty"):
        run_scale_sharded(arrival="poisson", messages=10, devices=4,
                          consumers=2, rate_hz=100.0, payload_bytes=8,
                          service_s=0.0, seed=0, shards=2)
    with pytest.raises(ValueError, match="shards"):
        run_scale_sharded(arrival="poisson", messages=10, devices=4,
                          consumers=4, rate_hz=100.0, payload_bytes=8,
                          service_s=0.0, seed=0, shards=8)


def test_zero_task_stage_and_bad_partitions_raise():
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=2))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=2))
    stages = [StageSpec("produce", lambda ctx: b"", pilot=edge, n_tasks=0),
              StageSpec("process", lambda ctx, data=None: None,
                        pilot=cloud, n_tasks=2)]
    # a zero-task source stage is legal (the tier-cut downstream shard)
    # but then n_partitions must be given explicitly and positive
    with pytest.raises(ValueError):
        ContinuumPipeline(stages=stages, clock=SimClock())
    pipe = ContinuumPipeline(stages=stages, n_partitions=3,
                             clock=SimClock())
    assert pipe.n_partitions == 3
    assert pipe.stage_tasks(0) == 0
    mgr.release_all()


# ---------------------------------------------------------------------------
# shard-count determinism (the regression the CI parity lane gates)
# ---------------------------------------------------------------------------

_DET_KEYS = ("processed", "duplicates", "truncated_msgs", "makespan_s",
             "lat_p50_s", "lat_p95_s", "wan_bytes")


def _sharded_cell(shards, mode="inline", **overrides):
    cfg = dict(arrival="poisson", messages=2000, devices=6, consumers=9,
               rate_hz=1000.0, payload_bytes=48, service_s=0.002, seed=11,
               shards=shards, mode=mode)
    cfg.update(overrides)
    return run_scale_sharded(**cfg)


def test_shard_counts_1_2_4_bit_identical():
    rows = {k: _sharded_cell(k) for k in (1, 2, 4)}
    base = rows[1]
    assert base["processed"] == 2000
    for k in (2, 4):
        for key in _DET_KEYS:
            assert rows[k][key] == base[key], (
                f"{key} drifts at {k} shards: {rows[k][key]!r} "
                f"!= {base[key]!r}")
    # aggregate accounting is self-consistent
    assert rows[4]["cpu_critical_s"] <= rows[4]["cpu_s_total"] + 1e-9
    assert rows[4]["windows"] == 1      # no cross-shard links: one window


def test_shard_mp_matches_inline():
    a = _sharded_cell(2, mode="inline")
    b = _sharded_cell(2, mode="mp")
    for key in _DET_KEYS:
        assert a[key] == b[key]


def test_shard_streaming_sketch_merge_identical():
    a = _sharded_cell(1, streaming=True)
    b = _sharded_cell(3, streaming=True)
    for key in _DET_KEYS:
        assert a[key] == b[key]


def test_merge_rows_exact_percentiles():
    # the merged multiset rank formula must match the single-list one
    rows = [
        {"processed": 2, "duplicates": 0, "events": 5, "truncated_msgs": 0,
         "wan_bytes": 10.0, "first_produced": 0.5, "last_processed": 3.0,
         "latencies": [0.3, 0.1]},
        {"processed": 3, "duplicates": 1, "events": 7, "truncated_msgs": 2,
         "wan_bytes": 20.0, "first_produced": 0.2, "last_processed": 4.0,
         "latencies": [0.2, 0.5, 0.4]},
    ]
    merged = merge_rows(rows, streaming=False)
    lat = sorted([0.3, 0.1, 0.2, 0.5, 0.4])
    assert merged["processed"] == 5
    assert merged["duplicates"] == 1
    assert merged["truncated_msgs"] == 2
    assert merged["wan_bytes"] == 30.0
    assert merged["makespan_s"] == pytest.approx(4.0 - 0.2)
    assert merged["lat_p50_s"] == lat[len(lat) // 2]
    assert merged["lat_p95_s"] == lat[min(len(lat) - 1,
                                          int(0.95 * len(lat)))]


# ---------------------------------------------------------------------------
# conservative-window causality (property, stubbed-hypothesis style)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_tier_cut_windows_never_violate_causality(seed):
    """Randomized tier-cut runs: with window <= lookahead (the WAN's
    one-way latency), no cross-shard message is ever visible — let alone
    consumed — before its ``ready_at``, and every message still arrives.
    """
    rng = np.random.default_rng(seed)
    devices = int(rng.integers(2, 6))
    consumers = int(rng.integers(devices, 2 * devices + 1))
    messages = int(rng.integers(100, 400))
    rate_hz = float(rng.uniform(50.0, 400.0))
    payload = int(rng.integers(16, 256))
    rtt_s = float(rng.uniform(0.02, 0.2))
    lookahead = rtt_s / 2.0             # WanShaper: one-way = rtt/2
    window = lookahead * float(rng.uniform(0.3, 1.0))
    cfg = dict(messages=messages, devices=devices, consumers=consumers,
               rate_hz=rate_hz, payload_bytes=payload, seed=seed,
               bandwidth_bps=80e6, rtt_s=rtt_s,
               timeout_s=messages / rate_hz + 60.0)
    coord = ShardCoordinator(tier_cut_builders(cfg), window_s=window,
                             mode="inline")
    rows = coord.run()
    edge, cloud = coord.runners
    # the protocol actually windowed (not one degenerate barrier) and
    # every message crossed the boundary and got processed
    assert coord.windows > 1
    assert len(cloud.injected) == messages
    assert rows[1]["processed"] == messages
    # ingress bytes are counted exactly once, by the producing shard
    assert rows[0]["wan_bytes"] == float(messages * payload)
    assert rows[1]["wan_bytes"] == 0.0
    m = cloud.metrics
    for msg_id, (t_inject, ready_at) in cloud.injected.items():
        # conservative delivery: injected at a barrier at or before the
        # message's visibility time ...
        assert t_inject <= ready_at + 1e-12
        tr = m.trace(msg_id)
        assert tr is not None
        # ... and never consumed before it
        for event in ("broker_out", "consumed", "processed"):
            t = tr.stamps.get(event)
            if t is not None:
                assert t >= ready_at - 1e-12, (
                    f"{event} at {t} before ready_at {ready_at}")
    # end-to-end latency can never beat the WAN's one-way latency
    lat = m.latencies("produced", "processed")
    assert len(lat) == messages
    assert min(lat) >= lookahead - 1e-12


@pytest.mark.parametrize("seed", [0, 1])
def test_tier_cut_deterministic_across_reruns(seed):
    cfg = dict(messages=150, devices=3, consumers=4, rate_hz=150.0,
               payload_bytes=32, seed=seed, bandwidth_bps=50e6,
               rtt_s=0.08, timeout_s=60.0)

    def run_once():
        coord = ShardCoordinator(tier_cut_builders(cfg), window_s=0.03,
                                 mode="inline")
        rows = coord.run()
        return merge_rows(rows, streaming=False)

    a, b = run_once(), run_once()
    for key in _DET_KEYS:
        assert a[key] == b[key]


def test_build_scale_shard_message_totals():
    # each shard draws the *global* arrival cumsum and takes its own
    # device block's interleave slices — so per-shard message targets
    # are the block slice lengths and sum exactly to the global total
    cfg = dict(shards=3, arrival="poisson", messages=500, devices=4,
               consumers=4, rate_hz=500.0, payload_bytes=8, service_s=0.0,
               seed=7, streaming=False, truncate_logs=None, trace=None)
    totals = []
    for sid in range(3):
        runner = build_scale_shard(dict(cfg, shard_id=sid))
        totals.append(runner.handle.state.n_messages)
        runner.handle.finish()
    blocks = split_blocks(cfg["devices"], 3)
    expect = [sum(len(range(g, cfg["messages"], cfg["devices"]))
                  for g in range(lo, hi)) for lo, hi in blocks]
    assert totals == expect
    assert sum(totals) == cfg["messages"]
