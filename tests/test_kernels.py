"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode)
against its ref.py pure-jnp oracle (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kmeans import kmeans_assign
from repro.kernels.ssd import ssd_chunk_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, sk, h, hkv, d, causal, window)
    (1, 128, 128, 2, 2, 64, True, None),
    (2, 256, 256, 4, 2, 64, True, None),        # GQA 2x
    (1, 384, 384, 8, 1, 32, True, None),        # MQA
    (1, 128, 128, 4, 4, 128, False, None),      # bidirectional
    (2, 200, 200, 2, 2, 64, True, 64),          # unaligned + window
    (1, 512, 512, 2, 1, 64, True, 128),         # long + window
    (1, 96, 96, 2, 2, 16, True, None),          # small head_dim, sub-block
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    b, sq, sk, h, hkv, d, causal, window = case
    q = jnp.asarray(RNG.standard_normal((b, sq, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_block_shapes():
    """Different BlockSpec tilings give identical results."""
    q = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    base = flash_attention(q, k, v, block_q=128, block_k=128,
                           interpret=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# kmeans assignment
# ---------------------------------------------------------------------------

KMEANS_CASES = [
    (100, 32, 25), (1000, 32, 25), (257, 7, 3), (4096, 64, 100),
    (25, 32, 25), (513, 128, 128), (2500, 32, 25),
]


@pytest.mark.parametrize("case", KMEANS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_vs_ref(case, dtype):
    n, f, k = case
    pts = jnp.asarray(RNG.standard_normal((n, f)) * 5, dtype)
    cent = jnp.asarray(RNG.standard_normal((k, f)) * 5, dtype)
    ids, dmin = kmeans_assign(pts, cent, interpret=True)
    ids_r, dmin_r = ref.kmeans_assign_ref(pts, cent)
    # argmin ties under low precision: allow id mismatch only if distances
    # are ~equal
    mism = np.asarray(ids) != np.asarray(ids_r)
    if mism.any():
        np.testing.assert_allclose(np.asarray(dmin)[mism],
                                   np.asarray(dmin_r)[mism],
                                   atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(dmin, np.float32),
                               np.asarray(dmin_r, np.float32),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, nh, hd, g, ds, chunk)
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 1, 16, 32),
    (1, 256, 8, 64, 2, 32, 64),
    (1, 256, 24, 64, 1, 128, 64),     # mamba2-130m dims
    (2, 128, 4, 32, 4, 16, 128),      # chunk == seq
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_vs_ref(case, dtype):
    b, s, nh, hd, g, ds, chunk = case
    xh = jnp.asarray(RNG.standard_normal((b, s, nh, hd)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), dtype)
    C_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), dtype)
    D = jnp.asarray(RNG.standard_normal((nh,)), jnp.float32)
    y, fin = ssd_chunk_scan(xh, dt, A, B_, C_, D, chunk=chunk,
                            interpret=True)
    y_r, fin_r = ref.ssd_ref(xh, dt, A, B_, C_, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_r),
                               atol=1e-3, rtol=1e-3)


def test_ssd_matches_layers_impl():
    """kernels/ssd == models/layers.ssd_chunked (the model's jnp path)."""
    from repro.models.layers import ssd_chunked
    b, s, nh, hd, g, ds, chunk = 2, 128, 4, 32, 1, 16, 32
    xh = jnp.asarray(RNG.standard_normal((b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), jnp.float32)
    C_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), jnp.float32)
    D = jnp.asarray(RNG.standard_normal((nh,)), jnp.float32)
    y_k, fin_k = ssd_chunk_scan(xh, dt, A, B_, C_, D, chunk=chunk,
                                interpret=True)
    y_l, fin_l = ssd_chunked(xh, dt, A, B_, C_, D, chunk,
                             return_state=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_l),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fin_k), np.asarray(fin_l),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ops wrappers route correctly
# ---------------------------------------------------------------------------

def test_ops_wrappers():
    q = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, q, q)
    assert out.shape == q.shape
    pts = jnp.asarray(RNG.standard_normal((100, 32)), jnp.float32)
    cent = jnp.asarray(RNG.standard_normal((25, 32)), jnp.float32)
    ids, dmin = ops.kmeans_assign(pts, cent)
    assert ids.shape == (100,) and dmin.shape == (100,)


def test_model_uses_pallas_attention():
    """gqa_forward(impl='pallas') matches impl='dense'."""
    from repro.configs import get_arch
    from repro.models import transformer as T
    cfg = get_arch("internlm2-1.8b").reduced()
    params = T.init_params(jax.random.key(0), cfg)
    inputs = {"tokens": jnp.ones((1, 128), jnp.int32),
              "labels": jnp.zeros((1, 128), jnp.int32)}
    ld, _ = T.forward(params, cfg, inputs, impl="dense", remat=False)
    lp, _ = T.forward(params, cfg, inputs, impl="pallas", remat=False)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                               atol=2e-4, rtol=2e-4)
