"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode)
against its ref.py pure-jnp oracle (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kmeans import kmeans_assign
from repro.kernels.ssd import ssd_chunk_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, sk, h, hkv, d, causal, window)
    (1, 128, 128, 2, 2, 64, True, None),
    (2, 256, 256, 4, 2, 64, True, None),        # GQA 2x
    (1, 384, 384, 8, 1, 32, True, None),        # MQA
    (1, 128, 128, 4, 4, 128, False, None),      # bidirectional
    (2, 200, 200, 2, 2, 64, True, 64),          # unaligned + window
    (1, 512, 512, 2, 1, 64, True, 128),         # long + window
    (1, 96, 96, 2, 2, 16, True, None),          # small head_dim, sub-block
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    b, sq, sk, h, hkv, d, causal, window = case
    q = jnp.asarray(RNG.standard_normal((b, sq, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_block_shapes():
    """Different BlockSpec tilings give identical results."""
    q = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    base = flash_attention(q, k, v, block_q=128, block_k=128,
                           interpret=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# kmeans assignment
# ---------------------------------------------------------------------------

KMEANS_CASES = [
    (100, 32, 25), (1000, 32, 25), (257, 7, 3), (4096, 64, 100),
    (25, 32, 25), (513, 128, 128), (2500, 32, 25),
]


@pytest.mark.parametrize("case", KMEANS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_vs_ref(case, dtype):
    n, f, k = case
    pts = jnp.asarray(RNG.standard_normal((n, f)) * 5, dtype)
    cent = jnp.asarray(RNG.standard_normal((k, f)) * 5, dtype)
    ids, dmin = kmeans_assign(pts, cent, interpret=True)
    ids_r, dmin_r = ref.kmeans_assign_ref(pts, cent)
    # argmin ties under low precision: allow id mismatch only if distances
    # are ~equal
    mism = np.asarray(ids) != np.asarray(ids_r)
    if mism.any():
        np.testing.assert_allclose(np.asarray(dmin)[mism],
                                   np.asarray(dmin_r)[mism],
                                   atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(dmin, np.float32),
                               np.asarray(dmin_r, np.float32),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, nh, hd, g, ds, chunk)
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 1, 16, 32),
    (1, 256, 8, 64, 2, 32, 64),
    (1, 256, 24, 64, 1, 128, 64),     # mamba2-130m dims
    (2, 128, 4, 32, 4, 16, 128),      # chunk == seq
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_vs_ref(case, dtype):
    b, s, nh, hd, g, ds, chunk = case
    xh = jnp.asarray(RNG.standard_normal((b, s, nh, hd)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), dtype)
    C_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), dtype)
    D = jnp.asarray(RNG.standard_normal((nh,)), jnp.float32)
    y, fin = ssd_chunk_scan(xh, dt, A, B_, C_, D, chunk=chunk,
                            interpret=True)
    y_r, fin_r = ref.ssd_ref(xh, dt, A, B_, C_, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_r),
                               atol=1e-3, rtol=1e-3)


def test_ssd_matches_layers_impl():
    """kernels/ssd == models/layers.ssd_chunked (the model's jnp path)."""
    from repro.models.layers import ssd_chunked
    b, s, nh, hd, g, ds, chunk = 2, 128, 4, 32, 1, 16, 32
    xh = jnp.asarray(RNG.standard_normal((b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, nh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    B_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), jnp.float32)
    C_ = jnp.asarray(RNG.standard_normal((b, s, g, ds)), jnp.float32)
    D = jnp.asarray(RNG.standard_normal((nh,)), jnp.float32)
    y_k, fin_k = ssd_chunk_scan(xh, dt, A, B_, C_, D, chunk=chunk,
                                interpret=True)
    y_l, fin_l = ssd_chunked(xh, dt, A, B_, C_, D, chunk,
                             return_state=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_l),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fin_k), np.asarray(fin_l),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ops wrappers route correctly
# ---------------------------------------------------------------------------

def test_ops_wrappers():
    q = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, q, q)
    assert out.shape == q.shape
    pts = jnp.asarray(RNG.standard_normal((100, 32)), jnp.float32)
    cent = jnp.asarray(RNG.standard_normal((25, 32)), jnp.float32)
    ids, dmin = ops.kmeans_assign(pts, cent)
    assert ids.shape == (100,) and dmin.shape == (100,)


def test_model_uses_pallas_attention():
    """gqa_forward(impl='pallas') matches impl='dense'."""
    from repro.configs import get_arch
    from repro.models import transformer as T
    cfg = get_arch("internlm2-1.8b").reduced()
    params = T.init_params(jax.random.key(0), cfg)
    inputs = {"tokens": jnp.ones((1, 128), jnp.int32),
              "labels": jnp.zeros((1, 128), jnp.int32)}
    ld, _ = T.forward(params, cfg, inputs, impl="dense", remat=False)
    lp, _ = T.forward(params, cfg, inputs, impl="pallas", remat=False)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# fused kmeans assign+update (tentpole): Pallas kernel, precision axis
# ---------------------------------------------------------------------------

FUSED_CASES = [(257, 7, 3), (1000, 32, 25), (25, 32, 25), (513, 128, 128),
               (2500, 32, 25)]


def _blob(n, f, k):
    pts = jnp.asarray(RNG.standard_normal((n, f)) * 5, jnp.float32)
    return pts, pts[:k]


@pytest.mark.parametrize("case", FUSED_CASES)
@pytest.mark.parametrize("precision", ["fp32", "bf16", "int8"])
def test_kmeans_fused_kernel_matches_jnp_lowering(case, precision):
    """The fused Pallas kernel (interpret mode) and the fused jnp lowering
    are the same computation: ids exact, counts exact, updated centroids
    within accumulation-order tolerance."""
    from repro.ml.kmeans import _assign_update
    n, f, k = case
    pts, cent = _blob(n, f, k)
    counts0 = jnp.zeros((k,), jnp.float32)
    jcent, jc, jids, jd = _assign_update(cent, counts0, pts,
                                         impl="fused", precision=precision)
    pcent, pc, pids, pd = _assign_update(cent, counts0, pts,
                                         impl="pallas", precision=precision)
    np.testing.assert_array_equal(np.asarray(jids), np.asarray(pids))
    np.testing.assert_array_equal(np.asarray(jc), np.asarray(pc))
    np.testing.assert_allclose(np.asarray(jcent), np.asarray(pcent),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jd), np.asarray(pd),
                               atol=0.05, rtol=1e-3)


@pytest.mark.parametrize("precision", ["fp32", "bf16", "int8"])
def test_kmeans_fused_vs_two_pass_impl_parity(precision):
    """impl='fused' (distance pass + scatter-add) and impl='jnp' (the
    historical two-pass one-hot matmul) agree bit-for-bit on ids/counts
    and to accumulation tolerance on the updated centroids."""
    from repro.ml.kmeans import _assign_update
    pts, cent = _blob(2500, 32, 25)
    counts0 = jnp.full((25,), 7.0, jnp.float32)
    fcent, fc, fids, _ = _assign_update(cent, counts0, pts,
                                        impl="fused", precision=precision)
    jcent, jc, jids, _ = _assign_update(cent, counts0, pts,
                                        impl="jnp", precision=precision)
    np.testing.assert_array_equal(np.asarray(fids), np.asarray(jids))
    np.testing.assert_array_equal(np.asarray(fc), np.asarray(jc))
    np.testing.assert_allclose(np.asarray(fcent), np.asarray(jcent),
                               rtol=1e-5, atol=1e-4)


def test_kmeans_fused_kernel_counts_every_point():
    """Padded tail rows must not leak into the accumulators: counts sum
    to exactly n for a deliberately non-block-aligned n."""
    pts, cent = _blob(257, 7, 3)
    ids, dmin, sums, counts = ops.kmeans_assign_update(pts, cent)
    assert float(jnp.sum(counts)) == 257.0
    np.testing.assert_allclose(
        np.asarray(jnp.sum(sums, axis=0)), np.asarray(jnp.sum(pts, axis=0)),
        rtol=1e-5, atol=1e-3)


def test_kmeans_assign_skips_repad_when_aligned():
    """Satellite perf fix: _pad2 is a no-op (same array object) when the
    input is already block-aligned."""
    from repro.kernels.kmeans import _pad2
    a = jnp.ones((256, 128), jnp.float32)
    assert _pad2(a, 256, 128) is a
    b = _pad2(jnp.ones((100, 32), jnp.float32), 128, 128)
    assert b.shape == (128, 128)
    assert float(jnp.sum(b)) == 100 * 32        # zero padding


def test_kmeans_int8_quantization_roundtrip():
    """quant helpers: symmetric per-feature scales bound the dequant error
    by scale/2, and fake_quantize == dequantize(quantize)."""
    from repro.kernels import quant
    pts, cent = _blob(500, 16, 8)
    scales = quant.symmetric_scales(pts, cent)
    assert scales.shape == (16,) and bool(jnp.all(scales > 0))
    q = quant.quantize(pts, scales)
    assert q.dtype == jnp.int8
    dq = quant.dequantize(q, scales)
    assert bool(jnp.all(jnp.abs(dq - pts) <= 0.5 * scales[None, :] + 1e-7))
    np.testing.assert_array_equal(np.asarray(quant.fake_quantize(pts, scales)),
                                  np.asarray(dq))
    # shared scales cover the centroids too
    qc = quant.quantize(cent, scales)
    assert int(jnp.max(jnp.abs(qc.astype(jnp.int32)))) <= 127


def test_kmeans_precision_agreement_on_probe():
    """Acceptance pin: the reduced-precision variants agree with fp32 on
    >= 99% of assignments on the fixed MiniAppGenerator probe."""
    from repro.ml.kmeans import assignment_agreement
    assert assignment_agreement("bf16") >= 0.99
    assert assignment_agreement("int8") >= 0.99
    assert assignment_agreement("fp32") == 1.0


def test_kmeans_autotune_block_n_deterministic_and_cached():
    """The block_n sweep picks from the candidate set, caches per shape,
    and is deterministic under an injected timer."""
    from repro.kernels import kmeans as kk
    state = {"t": 0.0, "step": 1.0, "calls": 0}

    def fake_clock():
        # ever-growing tick: earlier-swept candidates time faster, so the
        # first candidate deterministically wins
        state["calls"] += 1
        state["t"] += state["step"]
        state["step"] *= 2.0
        return state["t"]

    kk._autotune_cache.clear()
    best = kk.autotune_block_n(1000, 32, 25, precision="fp32",
                               interpret=True, candidates=(128, 256),
                               probe_n=512, timer=fake_clock)
    assert best == 128
    n_calls = state["calls"]
    assert n_calls > 0
    again = kk.autotune_block_n(1000, 32, 25, precision="fp32",
                                interpret=True, candidates=(128, 256),
                                probe_n=512, timer=fake_clock)
    assert again == best and state["calls"] == n_calls     # cache hit
