"""Vocab-padding semantics: padded archs (minicpm3 73448→73472,
hymba 32001→32128, mamba2 50280→50304) must train/serve exactly as if
unpadded — pad logits are masked from the loss and never win argmax."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ArchConfig
from repro.models import transformer as T


def _padded_cfg():
    """Tiny config with a deliberately unaligned vocab."""
    base = get_arch("internlm2-1.8b").reduced()
    return dataclasses.replace(base, vocab_size=251)   # pads to 256


def test_padded_vocab_sizes():
    assert get_arch("minicpm3-4b").padded_vocab_size == 73472
    assert get_arch("hymba-1.5b").padded_vocab_size == 32128
    assert get_arch("mamba2-130m").padded_vocab_size == 50304
    assert get_arch("internlm2-1.8b").padded_vocab_size == 92544  # already


def test_embed_and_head_padded_shapes():
    cfg = _padded_cfg()
    params = T.init_params(jax.random.key(0), cfg)
    assert params["embed"].shape == (256, cfg.d_model)
    assert params["head"].shape == (cfg.d_model, 256)
    # pad rows/cols are zero
    assert float(jnp.abs(params["embed"][251:]).sum()) == 0.0
    assert float(jnp.abs(params["head"][:, 251:]).sum()) == 0.0


def test_pad_logits_masked_from_loss_and_grad():
    cfg = _padded_cfg()
    params = T.init_params(jax.random.key(0), cfg)
    inputs = {"tokens": jnp.ones((2, 16), jnp.int32) * 5,
              "labels": jnp.ones((2, 16), jnp.int32) * 7}
    grads, metrics = jax.grad(
        lambda p: T.loss_fn(p, cfg, inputs), has_aux=True)(params)
    assert bool(jnp.isfinite(metrics["loss"]))
    # no gradient flows into the pad columns of the head
    assert float(jnp.abs(grads["head"][:, 251:]).sum()) == 0.0
    # ... but real columns do get gradient
    assert float(jnp.abs(grads["head"][:, :251]).sum()) > 0.0


def test_loss_equals_unpadded_reference():
    """Same weights, vocab 251 (padded to 256) vs a manual 251-logit CE."""
    cfg = _padded_cfg()
    params = T.init_params(jax.random.key(0), cfg)
    inputs = {"tokens": jnp.arange(16, dtype=jnp.int32)[None] % 251,
              "labels": (jnp.arange(16, dtype=jnp.int32)[None] + 1) % 251}
    loss, _ = T.loss_fn(params, cfg, inputs)
    logits, _ = T.forward(params, cfg, inputs)
    lg = logits[..., :251].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, -1)
    gold = jnp.take_along_axis(lg, inputs["labels"][..., None],
                               -1)[..., 0]
    ref = (lse - gold).mean()
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)
