"""Fig-3 scenario harness: emulated edge-to-cloud pipeline runs.

Replays a full geo-distributed pipeline — Mini-App producers on edge
devices, the partitioned broker with a WAN-shaped intercontinental hop,
consumer-group processing on the chosen tier, consumer crashes and
rebalances — as a single-threaded discrete-event simulation over
:class:`~repro.sim.clock.SimClock`.  The *real* framework objects carry the
dataflow (``Broker``/``Topic``/``ConsumerGroup``/``WanShaper``/
``MetricsRegistry``), so broker offsets, at-least-once redelivery, byte
accounting and linked metrics are the production code paths, only time is
virtual.  A sweep of {model} × {placement} × {WAN band} that takes hours
of real pipeline time (paper Fig 2/3) replays in milliseconds with
bit-reproducible metrics.

Placement modalities (the paper's deployment modalities, §II-C):

* ``cloud``  — raw points cross the WAN; the model runs on the cloud tier.
* ``edge``   — the model runs next to the generator; only the (small)
  model output crosses the WAN.
* ``hybrid`` — an edge pre-aggregation stage shrinks each message by
  ``hybrid_reduce`` before the WAN hop; the model finishes on the cloud.

Cost model: compute time = task FLOPs / tier FLOP/s with the same
``EDGE_FLOPS`` / ``DEVICE_FLOPS`` constants the :class:`PlacementEngine`
prices placements with, so emulated throughput and the engine's
``compare_tiers`` estimates are mutually consistent (tested in
``tests/test_sim.py``).
"""
from __future__ import annotations

import time as _walltime
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.broker import Broker, ConsumerGroup, WanShaper
from repro.core.monitoring import MetricsRegistry
from repro.core.placement import (DEVICE_FLOPS, EDGE_FLOPS, LinkModel,
                                  PlacementEngine, TaskProfile)
from repro.ml.datagen import N_FEATURES, message_nbytes
from repro.sim.clock import SimClock
from repro.sim.scheduler import EventScheduler

# the paper's iPerf band plus the constrained 10 Mbit/s point used for the
# placement-sensitivity experiments; (bandwidth bits/s, RTT seconds)
WAN_BANDS: Dict[str, Tuple[float, float]] = {
    "10mbit": (10e6, 0.150),
    "50mbit": (50e6, 0.150),
    "100mbit": (100e6, 0.140),
}

PLACEMENTS = ("edge", "cloud", "hybrid")


@dataclass(frozen=True)
class ModelSpec:
    """Analytic cost of one processing model, per data point."""
    name: str
    flops_per_point: float          # full model cost
    output_bytes: int               # serialized model output per message
    hybrid_reduce: int = 10         # edge pre-aggregation shrink factor
    preprocess_flops_per_point: float = 200.0

    def task_profile(self, n_points: int) -> TaskProfile:
        """The what-the-placement-engine-sees view of one message."""
        return TaskProfile(
            flops=self.flops_per_point * n_points,
            input_bytes=float(message_nbytes(n_points)),
            input_tier="edge",
            output_bytes=float(self.output_bytes),
            output_tier="cloud")


# k-means assignment+update: ~2·k·d FLOPs/point × a handful of Lloyd
# iterations — cheap per byte, i.e. transfer-bound (paper Fig 3 left).
KMEANS = ModelSpec("kmeans", flops_per_point=8_000.0,
                   output_bytes=25 * N_FEATURES * 8)
# autoencoder minibatch training: forward+backward over the dense stack ×
# epochs — expensive per byte, i.e. compute-bound (paper Fig 3 right):
# even the 10 Mbit/s link feeds points faster than the cloud tier trains
# on them, so placement is WAN-insensitive.
AUTOENCODER = ModelSpec("autoencoder", flops_per_point=6e7,
                        output_bytes=2_048)
MODELS: Dict[str, ModelSpec] = {m.name: m for m in (KMEANS, AUTOENCODER)}


@dataclass(frozen=True)
class FailureSpec:
    """Crash consumer ``consumer_idx`` at virtual time ``at_s``; a
    replacement (fresh member id, resuming from committed offsets) joins
    ``restart_after_s`` later unless None."""
    at_s: float
    consumer_idx: int = 0
    restart_after_s: Optional[float] = 1.0


@dataclass(frozen=True)
class Scenario:
    model: ModelSpec = KMEANS
    placement: str = "cloud"                  # edge | cloud | hybrid
    wan_band: str = "100mbit"                 # key into WAN_BANDS
    n_messages: int = 64
    n_devices: int = 4                        # edge devices == partitions
    n_consumers: Optional[int] = None         # default: n_devices
    n_points: int = 2_500                     # points per message
    gen_s_per_point: float = 2e-6             # Mini-App generation cost
    failures: Tuple[FailureSpec, ...] = ()
    seed: int = 0
    t_max_s: float = 36_000.0                 # virtual-time safety cap

    def label(self) -> str:
        return (f"{self.model.name}/{self.placement}/{self.wan_band}"
                f"{'/fail' if self.failures else ''}")


@dataclass
class ScenarioResult:
    scenario: Scenario
    n_processed: int
    n_duplicates: int
    makespan_s: float                 # virtual seconds, first gen → last done
    throughput_msgs_s: float
    latency_mean_s: float
    latency_p95_s: float
    wan_mbytes: float
    placement_estimates: Dict[str, float]     # PlacementEngine per-tier est.
    wall_ms: float = 0.0              # real milliseconds spent emulating
    metrics: MetricsRegistry = field(default=None, repr=False)

    def row(self) -> Dict[str, object]:
        """Deterministic summary — identical across runs at the same seed
        (``wall_ms`` is wall time and deliberately excluded)."""
        s = self.scenario
        return {
            "model": s.model.name, "placement": s.placement,
            "wan": s.wan_band, "messages": s.n_messages,
            "processed": self.n_processed, "dups": self.n_duplicates,
            "makespan_s": self.makespan_s,
            "msgs_per_s": self.throughput_msgs_s,
            "lat_mean_s": self.latency_mean_s,
            "lat_p95_s": self.latency_p95_s,
            "wan_mb": self.wan_mbytes,
        }


def _edge_compute_s(sc: Scenario) -> float:
    """Per-message edge-stage service time for the scenario's placement."""
    m = sc.model
    if sc.placement == "edge":
        return m.flops_per_point * sc.n_points / EDGE_FLOPS
    if sc.placement == "hybrid":
        return m.preprocess_flops_per_point * sc.n_points / EDGE_FLOPS
    return 0.0


def _cloud_compute_s(sc: Scenario) -> float:
    """Per-message cloud-stage service time (one consumer slot)."""
    m = sc.model
    if sc.placement == "edge":
        # results only need ingesting/merging on the cloud side
        return m.output_bytes / 8 * 50.0 / DEVICE_FLOPS
    points = sc.n_points if sc.placement == "cloud" \
        else max(sc.n_points // m.hybrid_reduce, 1)
    return m.flops_per_point * points / DEVICE_FLOPS


def _payload(sc: Scenario) -> np.ndarray:
    """What actually crosses the broker for this placement (real numpy
    serialization, so WAN byte accounting is exact)."""
    if sc.placement == "edge":
        return np.zeros(max(sc.model.output_bytes // 8, 1), np.float64)
    if sc.placement == "hybrid":
        return np.zeros((max(sc.n_points // sc.model.hybrid_reduce, 1),
                         N_FEATURES), np.float64)
    return np.zeros((sc.n_points, N_FEATURES), np.float64)


def placement_estimates(sc: Scenario) -> Dict[str, float]:
    """PlacementEngine per-tier completion-time estimates for one message
    of this scenario, priced over this scenario's WAN band."""
    from repro.core.pilot import ComputeResource, PilotManager
    bw_bps, rtt = WAN_BANDS[sc.wan_band]
    links = {("edge", "cloud"): LinkModel(bandwidth=bw_bps / 8.0,
                                          latency_s=rtt),
             ("edge", "hpc"): LinkModel(bandwidth=bw_bps / 8.0,
                                        latency_s=rtt)}
    eng = PlacementEngine(links=links)
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=sc.n_devices))
    n_cons = sc.n_consumers or sc.n_devices
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=n_cons))
    return eng.compare_tiers(sc.model.task_profile(sc.n_points),
                             [edge, cloud])


class _Sim:
    """One scenario's event-driven pipeline state."""

    def __init__(self, sc: Scenario):
        if sc.wan_band not in WAN_BANDS:
            raise ValueError(f"unknown wan_band {sc.wan_band!r}; "
                             f"known: {sorted(WAN_BANDS)}")
        self.sc = sc
        self.clock = SimClock()
        self.sched = EventScheduler(self.clock)
        self.metrics = MetricsRegistry(clock=self.clock)
        self.broker = Broker(metrics=self.metrics, clock=self.clock)
        bw_bps, rtt = WAN_BANDS[sc.wan_band]
        self.shaper = WanShaper(bandwidth_bps=bw_bps, rtt_s=rtt, sleep=False)
        self.topic = self.broker.create_topic(
            "e2c", n_partitions=sc.n_devices, shaper=self.shaper)
        self.group = ConsumerGroup(self.topic, "cloud-processing")
        self.rng = np.random.default_rng(sc.seed)
        self.n_consumers = sc.n_consumers or sc.n_devices
        self.alive: Dict[str, bool] = {}
        self.produced = 0
        self.seen_ids: set = set()
        self.duplicates = 0
        self.done = False
        self.t_edge = _edge_compute_s(sc)
        self.t_cloud = _cloud_compute_s(sc)
        self.gen_s = sc.gen_s_per_point * sc.n_points
        # per-device message budget (paper: messages split across devices)
        base, extra = divmod(sc.n_messages, sc.n_devices)
        self.per_device = [base + (1 if i < extra else 0)
                           for i in range(sc.n_devices)]

    # -- edge side ---------------------------------------------------------

    def start(self) -> None:
        for d in range(self.sc.n_devices):
            if self.per_device[d]:
                # deterministic per-device phase offset (devices don't boot
                # in lockstep); drawn in device order from the seeded rng
                offset = float(self.rng.uniform(0.0, self.gen_s + 1e-9))
                self.sched.at(offset, lambda d=d: self._device_step(d))
        for c in range(self.n_consumers):
            cid = f"consumer-{c}"
            self.alive[cid] = True
            self.group.join(cid)
            self.sched.at(0.0, lambda cid=cid: self._consumer_poll(cid))
        for f in self.sc.failures:
            self.sched.at(f.at_s, lambda f=f: self._crash(f))

    def _device_step(self, d: int) -> None:
        if self.per_device[d] <= 0 or self.done:
            return
        # generate, run the edge stage, then hand to the broker
        self.sched.after(self.gen_s + self.t_edge,
                         lambda: self._device_produce(d))

    def _device_produce(self, d: int) -> None:
        if self.done:
            return
        self.per_device[d] -= 1
        self.produced += 1
        self.topic.produce(_payload(self.sc), partition=d)
        self._device_step(d)

    # -- cloud side --------------------------------------------------------

    def _consumer_poll(self, cid: str) -> None:
        if self.done or not self.alive.get(cid, False):
            return
        msg, ready = self.group.poll_nowait(cid)
        if msg is None:
            now = self.clock.now()
            # in-flight WAN messages have an exact wakeup; otherwise idle-
            # tick (coarse is fine: a streaming consumer re-polls straight
            # from _consumer_done, never through this path)
            retry = ready if ready is not None else now + 0.05
            self.sched.at(max(retry, now), lambda: self._consumer_poll(cid))
            return
        self.sched.after(self.t_cloud,
                         lambda: self._consumer_done(cid, msg))

    def _consumer_done(self, cid: str, msg) -> None:
        if not self.alive.get(cid, False):
            return                      # crashed mid-service: no commit
        self.group.commit(msg)
        if msg.msg_id in self.seen_ids:
            self.duplicates += 1
            self.metrics.incr("sim.duplicates")
        else:
            self.seen_ids.add(msg.msg_id)
            self.metrics.stamp(msg.msg_id, "processed", bytes=msg.nbytes)
        if (len(self.seen_ids) >= self.sc.n_messages
                and self.produced >= self.sc.n_messages):
            self.done = True
            return
        self._consumer_poll(cid)

    # -- failures ----------------------------------------------------------

    def _crash(self, f: FailureSpec) -> None:
        cid = f"consumer-{f.consumer_idx}"
        if not self.alive.get(cid, False):
            return
        self.alive[cid] = False
        self.group.leave(cid)           # rebalance; uncommitted redeliver
        self.metrics.event("consumer_crashed", consumer=cid)
        if f.restart_after_s is not None:
            new_cid = f"{cid}-r"
            self.sched.after(f.restart_after_s,
                             lambda: self._restart(new_cid))

    def _restart(self, cid: str) -> None:
        self.alive[cid] = True
        self.group.join(cid)
        self.metrics.event("consumer_restarted", consumer=cid)
        self._consumer_poll(cid)


def run_scenario(sc: Scenario) -> ScenarioResult:
    """Emulate one scenario to completion; returns deterministic metrics."""
    if sc.placement not in PLACEMENTS:
        raise ValueError(f"placement must be one of {PLACEMENTS}")
    t_wall = _walltime.perf_counter()
    sim = _Sim(sc)
    sim.start()
    sim.sched.run(until=sc.t_max_s, max_events=5_000_000)

    lat = sim.metrics.latencies("produced", "processed")
    lat.sort()
    first = sim.metrics.first_stamp("produced") or 0.0
    last = sim.metrics.last_stamp("processed") or 0.0
    makespan = max(last - first, 1e-9)
    n_done = len(sim.seen_ids)
    return ScenarioResult(
        scenario=sc,
        n_processed=n_done,
        n_duplicates=sim.duplicates,
        makespan_s=makespan,
        throughput_msgs_s=n_done / makespan,
        latency_mean_s=float(np.mean(lat)) if lat else 0.0,
        latency_p95_s=lat[min(len(lat) - 1, int(0.95 * len(lat)))]
        if lat else 0.0,
        wan_mbytes=sim.metrics.counter("topic.e2c.bytes_in") / 1e6,
        placement_estimates=placement_estimates(sc),
        wall_ms=(_walltime.perf_counter() - t_wall) * 1e3,
        metrics=sim.metrics)


def sweep(models: Sequence[ModelSpec] = (KMEANS, AUTOENCODER),
          placements: Sequence[str] = PLACEMENTS,
          bands: Sequence[str] = ("10mbit", "50mbit", "100mbit"),
          *, n_messages: int = 64, n_devices: int = 4,
          n_points: int = 2_500, seed: int = 0,
          failures: Tuple[FailureSpec, ...] = ()) -> List[ScenarioResult]:
    """The Fig-3 grid: {models} × {placements} × {WAN bands}."""
    out = []
    for m in models:
        for p in placements:
            for b in bands:
                out.append(run_scenario(Scenario(
                    model=m, placement=p, wan_band=b,
                    n_messages=n_messages, n_devices=n_devices,
                    n_points=n_points, seed=seed, failures=failures)))
    return out


def format_table(results: Sequence[ScenarioResult]) -> str:
    """The paper's throughput/latency trade-off table."""
    hdr = (f"{'model':>12} {'placement':>9} {'wan':>8} {'done':>5} "
           f"{'dups':>4} {'msg/s':>9} {'lat-mean s':>10} {'lat-p95 s':>9} "
           f"{'WAN MB':>8} {'wall ms':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in results:
        s = r.scenario
        lines.append(
            f"{s.model.name:>12} {s.placement:>9} {s.wan_band:>8} "
            f"{r.n_processed:>5} {r.n_duplicates:>4} "
            f"{r.throughput_msgs_s:>9.3f} {r.latency_mean_s:>10.3f} "
            f"{r.latency_p95_s:>9.3f} {r.wan_mbytes:>8.2f} "
            f"{r.wall_ms:>8.1f}")
    return "\n".join(lines)
