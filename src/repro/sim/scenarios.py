"""Fig-3 scenarios: emulated edge-to-cloud pipeline runs — on the *real*
pipeline.

Each scenario builds a genuine :class:`~repro.core.faas.EdgeToCloudPipeline`
(real ``Broker``/``Topic``/``ConsumerGroup``/``WanShaper``/
``MetricsRegistry``/pilots) and runs it with
``run(scheduler=SimExecutor(...))`` — the single-threaded discrete-event
strategy from :mod:`repro.core.executor`.  There is no harness replica of
the pipeline logic any more: broker offsets, at-least-once redelivery,
dedup, byte accounting, consumer-group rebalances and linked metrics are
the production code paths, only time is virtual.  A sweep of {model} ×
{placement} × {WAN band} that takes hours of real pipeline time (paper
Fig 2/3) replays in milliseconds with bit-reproducible metrics.

Placement modalities (the paper's deployment modalities, §II-C, plus the
continuum's intermediate tier):

* ``cloud``  — raw points cross the WAN; the model runs on the cloud tier.
* ``edge``   — the model runs next to the generator; only the (small)
  model output crosses the WAN.
* ``hybrid`` — an edge pre-aggregation stage shrinks each message by
  ``hybrid_reduce`` before the WAN hop; the model finishes on the cloud.
* ``fog``    — a genuine 3-stage :class:`~repro.core.faas.ContinuumPipeline`:
  raw points ride the edge→fog metro link, the pre-aggregation runs *on
  the fog tier*, and only the reduced message crosses the WAN to the
  cloud model — the per-stage tier vector is ``(edge, fog, cloud)``.

Every scenario row carries its per-stage tier vector
(``ScenarioResult.row()["tiers"]``) so sweeps over arbitrary topologies
stay self-describing.

Cost model: everything is priced by the unified :mod:`repro.cost`
subsystem. ``WAN_BANDS`` below is an import-time snapshot of the shared
:data:`repro.cost.profiles.WAN_BANDS` link table (the same one
``PlacementEngine``'s ``DEFAULT_LINKS`` reads — pinned equal by a
regression test), and the built-in ``ModelSpec``s (``KMEANS`` /
``AUTOENCODER`` / ``ISOFOREST``) are derived from the committed kernel
calibration — FLOP costs measured from the compiled ``repro.ml`` kernels
via roofline HLO analysis, not hand-tuned constants — so emulated
throughput, the engine's ``compare_tiers`` estimates and the
:class:`~repro.cost.advisor.PlacementAdvisor` are all mutually consistent.

``Scenario(service_sigma=...)`` enables the calibrated lognormal
service-time noise (e.g. ``service_sigma=KMEANS.sigma``): stage charges
jitter straggler-realistically but remain bit-reproducible for a seed.

Dynamism scenarios: ``failures`` injects consumer crashes (or silent node
loss the heartbeat monitor must detect) mid-run; ``autoscale`` attaches a
lag-driven :class:`~repro.core.elastic.AutoScaler` to the consuming pilot,
stepped inside the DES, with the consumer pool following its resizes.
"""
from __future__ import annotations

import time as _walltime
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.broker import WanShaper
from repro.core.elastic import AutoScaler, ScalePolicy
from repro.core.executor import SimExecutor
from repro.core.faas import ContinuumPipeline, EdgeToCloudPipeline, StageSpec
from repro.core.monitoring import MetricsRegistry
from repro.core.pilot import ComputeResource, PilotManager
from repro.core.placement import PlacementEngine, TaskProfile
from repro.cost.calibrate import (DEFAULT_GEN_S_PER_POINT,
                                  DEFAULT_HYBRID_REDUCE,
                                  DEFAULT_PREPROCESS_FLOPS_PER_POINT)
from repro.cost.model import INGEST_FLOPS_PER_VALUE, CostModel, \
    default_cost_model
from repro.cost.profiles import WAN_BANDS as _WAN_LINKS
from repro.cost.readvisor import ReAdvisor, ReAdviseSpec
from repro.ml.datagen import N_FEATURES, message_nbytes

# the paper's iPerf band plus the constrained 10 Mbit/s point used for the
# placement-sensitivity experiments; (bandwidth bits/s, RTT seconds) —
# derived from the shared repro.cost.profiles.WAN_BANDS link table
WAN_BANDS: Dict[str, Tuple[float, float]] = {
    name: (link.bandwidth_bps, link.latency_s)
    for name, link in _WAN_LINKS.items()
}

PLACEMENTS = ("edge", "cloud", "hybrid", "fog", "device")


@dataclass(frozen=True)
class ModelSpec:
    """Cost of one processing model, per data point.

    ``flops_per_point`` is *peak-rate-equivalent* work (kernel HLO flops ×
    per-message invocations / achieved efficiency) so service time is
    simply ``flops / tier peak rate``; ``sigma`` is the calibrated
    lognormal service-noise parameter (opt in via
    ``Scenario(service_sigma=spec.sigma)``).
    """
    name: str
    flops_per_point: float          # full model cost (peak-equivalent)
    output_bytes: int               # serialized model output per message
    # edge pre-aggregation defaults shared with ModelCost (defined once,
    # in the cost subsystem)
    hybrid_reduce: int = DEFAULT_HYBRID_REDUCE
    preprocess_flops_per_point: float = DEFAULT_PREPROCESS_FLOPS_PER_POINT
    sigma: float = 0.0              # lognormal service-noise (log-space)
    # kernel precision variant (fp32 | bf16 | int8): model compute is
    # priced at the executing tier's precision-scaled peak rate
    precision: str = "fp32"

    def task_profile(self, n_points: int) -> TaskProfile:
        """The what-the-placement-engine-sees view of one message."""
        return TaskProfile(
            flops=self.flops_per_point * n_points,
            input_bytes=float(message_nbytes(n_points)),
            input_tier="edge",
            output_bytes=float(self.output_bytes),
            output_tier="cloud",
            precision=self.precision)


def model_specs(cost: Optional[CostModel] = None) -> Dict[str, ModelSpec]:
    """Build the scenario ``ModelSpec`` table from a calibration — the
    committed kernel calibration by default."""
    cost = cost or default_cost_model()
    return {
        name: ModelSpec(
            name=name,
            flops_per_point=mc.effective_flops_per_point,
            output_bytes=mc.output_bytes,
            hybrid_reduce=mc.hybrid_reduce,
            preprocess_flops_per_point=mc.preprocess_flops_per_point,
            sigma=mc.sigma,
            precision=mc.precision)
        for name, mc in cost.costs.items()
    }


MODELS: Dict[str, ModelSpec] = model_specs()
# k-means assignment+update is cheap per byte — transfer-bound (paper
# Fig 3 left); the autoencoder (100 PyOD epochs per batch) is expensive
# per byte — compute-bound (Fig 3 right): even the 10 Mbit/s link feeds
# points faster than the cloud tier trains on them; the isolation forest
# sits in between (still transfer-bound).
KMEANS = MODELS["kmeans"]
AUTOENCODER = MODELS["autoencoder"]
ISOFOREST = MODELS["isoforest"]


class ArrivalProcess:
    """Open-loop traffic model: where closed-loop sources produce as fast
    as the pipeline drains (throughput measures the *pipeline*), an
    arrival process pre-draws the absolute times at which messages enter
    the system (traffic intensity is a property of the *workload* — the
    realistic shape for continuum orchestration studies, where bursts
    must genuinely queue).  ``times(n, seed)`` returns ``n`` sorted
    absolute arrival seconds, bit-reproducible for a seed."""

    def times(self, n: int, seed: int) -> np.ndarray:
        raise NotImplementedError

    # -- Lewis–Shedler thinning (shared by the nonhomogeneous processes) --

    def _thin(self, n: int, seed: int, lam_max: float, lam) -> np.ndarray:
        """Draw ``n`` arrivals of a nonhomogeneous Poisson process with
        intensity ``lam(t) <= lam_max`` by thinning a homogeneous
        ``lam_max`` process."""
        rng = np.random.default_rng(seed)
        out = np.empty(n, np.float64)
        t, i = 0.0, 0
        while i < n:
            # batched candidate draws: one rng round-trip per ~4n points
            gaps = rng.exponential(1.0 / lam_max, size=max(n, 1024))
            us = rng.random(size=gaps.shape[0])
            for g, u in zip(gaps, us):
                t += g
                if u * lam_max <= lam(t):
                    out[i] = t
                    i += 1
                    if i == n:
                        break
        return out


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate_hz`` (aggregate, across all
    devices): i.i.d. exponential gaps."""
    rate_hz: float

    def __post_init__(self):
        if self.rate_hz <= 0.0:
            raise ValueError("rate_hz must be > 0")

    def times(self, n: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return np.cumsum(rng.exponential(1.0 / self.rate_hz, size=n))


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Day/night load curve: intensity swings sinusoidally between
    ``base_rate_hz`` (trough) and ``peak_rate_hz`` over ``period_s``,
    starting at the trough — the survey's canonical diurnal shape."""
    base_rate_hz: float
    peak_rate_hz: float
    period_s: float

    def __post_init__(self):
        if self.base_rate_hz <= 0.0 or self.period_s <= 0.0:
            raise ValueError("base_rate_hz and period_s must be > 0")
        if self.peak_rate_hz < self.base_rate_hz:
            raise ValueError("peak_rate_hz must be >= base_rate_hz")

    def times(self, n: int, seed: int) -> np.ndarray:
        base, peak = self.base_rate_hz, self.peak_rate_hz
        w = 2.0 * np.pi / self.period_s

        def lam(t):
            return base + (peak - base) * 0.5 * (1.0 - np.cos(w * t))

        return self._thin(n, seed, peak, lam)


@dataclass(frozen=True)
class FlashCrowdArrivals(ArrivalProcess):
    """Flash-crowd burst: steady ``base_rate_hz`` background with a
    ``burst_rate_hz`` spike in ``[burst_at_s, burst_at_s +
    burst_duration_s)`` — the traffic shape per-stage autoscaling exists
    for."""
    base_rate_hz: float
    burst_rate_hz: float
    burst_at_s: float
    burst_duration_s: float

    def __post_init__(self):
        if self.base_rate_hz <= 0.0 or self.burst_duration_s <= 0.0 \
                or self.burst_at_s < 0.0:
            raise ValueError("base_rate_hz and burst_duration_s must be "
                             "> 0, burst_at_s >= 0")
        if self.burst_rate_hz < self.base_rate_hz:
            raise ValueError("burst_rate_hz must be >= base_rate_hz")

    def times(self, n: int, seed: int) -> np.ndarray:
        base, burst = self.base_rate_hz, self.burst_rate_hz
        t0, t1 = self.burst_at_s, self.burst_at_s + self.burst_duration_s

        def lam(t):
            return burst if t0 <= t < t1 else base

        return self._thin(n, seed, burst, lam)


@dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay of a *recorded* arrival-timestamp trace — production-shaped
    load (Azure-Functions / Google-cluster-style) instead of a synthetic
    process.  File format: one float arrival timestamp (seconds, sorted
    or not) per line; blank lines and ``#`` header/comment lines are
    ignored (the committed example under ``benchmarks/traces/`` carries a
    ``# units=seconds seed=... n=...`` header).  Timestamps are sorted
    and re-based to start at 0.  When more arrivals are requested than
    the trace holds it is extended periodically — each repetition
    shifted by the trace period (last timestamp plus one mean gap) — so
    the recorded burst structure tiles instead of flat-lining.
    ``time_scale`` stretches (>1) or compresses (<1) the recorded
    timeline.  Replay is fully deterministic; ``seed`` is ignored."""
    path: str
    time_scale: float = 1.0

    def __post_init__(self):
        if self.time_scale <= 0.0:
            raise ValueError("time_scale must be > 0")

    def _load(self) -> np.ndarray:
        vals: List[float] = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                vals.append(float(line))
        if not vals:
            raise ValueError(f"trace {self.path!r} holds no timestamps")
        ts = np.sort(np.asarray(vals, np.float64))
        if not np.isfinite(ts).all():
            raise ValueError(f"trace {self.path!r} has non-finite "
                             f"timestamps")
        return ts - ts[0]

    def times(self, n: int, seed: int) -> np.ndarray:
        ts = self._load()
        m = ts.shape[0]
        if n <= m:
            out = ts[:n].copy()
        else:
            # periodic extension: tile the trace, each repetition shifted
            # by its period so the last recorded gap wraps to the first
            period = ts[-1] + (ts[-1] / max(m - 1, 1) if m > 1 else 1.0)
            reps = -(-n // m)
            out = np.concatenate([ts + k * period for k in range(reps)])[:n]
        return out * self.time_scale


def arrival_process(kind: str, rate_hz: float,
                    trace: Optional[str] = None) -> ArrivalProcess:
    """Shared open-loop arrival-process factory (the scale benchmarks,
    the geo benchmark and the sharded DES all build the same processes
    from the same knobs, so their deterministic draws agree): ``poisson``
    at ``rate_hz``; ``diurnal`` swinging rate_hz/4 ↔ rate_hz over a 20 s
    period; ``flash`` with a rate_hz/4 background and a 4×rate_hz burst
    in [2 s, 4 s); ``trace`` replaying the timestamp file at ``trace``."""
    if kind == "poisson":
        return PoissonArrivals(rate_hz=rate_hz)
    if kind == "diurnal":
        return DiurnalArrivals(base_rate_hz=rate_hz / 4.0,
                               peak_rate_hz=rate_hz, period_s=20.0)
    if kind == "flash":
        return FlashCrowdArrivals(base_rate_hz=rate_hz / 4.0,
                                  burst_rate_hz=rate_hz * 4.0,
                                  burst_at_s=2.0, burst_duration_s=2.0)
    if kind == "trace":
        if trace is None:
            raise ValueError("arrival_process('trace', ...) needs trace=")
        return TraceArrivals(path=trace)
    raise ValueError(f"unknown arrival kind {kind!r}")


def arrival_plan(sc: "Scenario") -> Optional[List[np.ndarray]]:
    """The scenario's per-device open-loop arrival plan (None when the
    scenario is closed-loop): one aggregate draw of ``n_messages``
    arrival times, dealt round-robin across the devices — each device's
    stream stays sorted, and the interleaved aggregate reproduces the
    process exactly."""
    if sc.arrival is None:
        return None
    times = sc.arrival.times(sc.n_messages, sc.seed)
    return [times[i::sc.n_devices] for i in range(sc.n_devices)]


@dataclass(frozen=True)
class FailureSpec:
    """Crash consumer ``consumer_idx`` at virtual time ``at_s``; a
    replacement (fresh member id, resuming from committed offsets) joins
    ``restart_after_s`` later unless None.  ``kind="crash"`` raises inside
    the consumer (immediate rebalance); ``kind="silent"`` makes the node
    go dark so only the heartbeat monitor can detect the loss."""
    at_s: float
    consumer_idx: int = 0
    restart_after_s: Optional[float] = 1.0
    kind: str = "crash"             # crash | silent


@dataclass(frozen=True)
class DriftSpec:
    """One mid-run environment drift event, scheduled as an ordinary DES
    event at virtual time ``at_s`` (drifted runs stay bit-identical).

    ``kind="band"``: re-price hop ``hop``'s live link (default: the last
    hop — the WAN crossing).  Name a band via ``band`` (looked up in the
    scenario profile's ``wan_bands``, or ``metro_bands`` when
    ``table="metro"``) or give explicit ``bandwidth_bps``/``rtt_s``.
    ``kind="churn"``: grow (``delta > 0``) or shrink (``delta < 0``)
    ``stage``'s consumer fleet (default: the final stage).
    ``kind="outage"``: every consumer of stages bound to ``tier`` dies
    at once.  ``restore_after_s`` undoes the drift that much later
    (band: old numbers back; churn: reverse delta; outage: same
    head-counts respawn as fresh members)."""
    at_s: float
    kind: str = "band"              # band | churn | outage
    hop: int = -1                   # band: which hop's shaper
    band: Optional[str] = None      # band: name into the band table
    table: str = "wan"              # band-name table: wan | metro
    bandwidth_bps: Optional[float] = None
    rtt_s: Optional[float] = None
    stage: Optional[str] = None     # churn: which consumer stage
    delta: int = 0                  # churn: consumers to add/remove
    tier: Optional[str] = None      # outage: which tier goes dark
    restore_after_s: Optional[float] = None


@dataclass(frozen=True)
class Scenario:
    """One Fig-3 cell.  ``cost`` re-prices tier rates and WAN links; it
    does *not* reach inside ``model`` — when sweeping a custom
    calibration, pair it with a matching spec
    (``model=model_specs(cost)[name]``), as the PlacementAdvisor does."""
    model: ModelSpec = KMEANS                 # calibrated k-means
    placement: str = "cloud"          # edge | cloud | hybrid | fog | device
    wan_band: str = "100mbit"                 # key into WAN_BANDS
    n_messages: int = 64
    n_devices: int = 4                        # edge devices == partitions
    n_consumers: Optional[int] = None         # default: n_devices
    n_fog: Optional[int] = None               # fog-stage tasks (fog only)
    n_points: int = 2_500                     # points per message
    gen_s_per_point: float = DEFAULT_GEN_S_PER_POINT  # Mini-App gen cost
    failures: Tuple[FailureSpec, ...] = ()
    autoscale: Optional[ScalePolicy] = None   # lag-driven resize in the DES
    # per-stage policies: ((stage_name, policy), ...) — every named
    # consumer stage gets its own lag-driven AutoScaler (the final stage
    # may instead/additionally use the legacy `autoscale` knob)
    autoscale_stages: Tuple[Tuple[str, ScalePolicy], ...] = ()
    autoscale_interval_s: float = 0.2
    # open-loop traffic: messages enter at the process's drawn times
    # instead of back-to-back (None = closed-loop; producer boot offsets
    # are then skipped — arrival times already carry the phases)
    arrival: Optional[ArrivalProcess] = None
    seed: int = 0
    t_max_s: float = 36_000.0                 # virtual-time safety cap
    # lognormal stage noise: 0 = off (the noise-free Fig-3 pins),
    # None = the model's *calibrated* sigma from calibration.json (what
    # the tail-aware PlacementAdvisor runs with)
    service_sigma: Optional[float] = 0.0
    # straggler speculation: a cloud/edge Service charge running past
    # factor × trailing median spawns a backup, first completion wins
    # (0 = off; mirrors TaskRuntime.speculative_factor under the DES)
    speculative_factor: float = 0.0
    cost: Optional[CostModel] = None          # default: shared calibration
    # mid-run environment drift (band degradation / churn / outage),
    # applied by the SimExecutor as ordinary scheduled events
    drift: Tuple[DriftSpec, ...] = ()
    # online re-advisory: watch the named stage's observed hop delay and
    # hot-swap its placement when the ranking flips beyond hysteresis
    readvise: Optional[ReAdviseSpec] = None
    # edge→fog metro band (key into the profile's metro_bands); None =
    # the profile default — makes the fog hop sweepable like WAN bands
    metro_band: Optional[str] = None

    @property
    def cost_model(self) -> CostModel:
        cm = self.cost or default_cost_model()
        if self.metro_band is not None:
            cm = cm.with_metro(self.metro_band)
        return cm

    @property
    def effective_service_sigma(self) -> float:
        """The sigma actually applied: explicit value, or the model's
        calibrated one when ``service_sigma`` is None."""
        return (self.model.sigma if self.service_sigma is None
                else self.service_sigma)

    def label(self) -> str:
        return (f"{self.model.name}/{self.placement}/{self.wan_band}"
                f"{'/fail' if self.failures else ''}"
                f"{'/autoscale' if self.autoscale or self.autoscale_stages else ''}"
                f"{'/open-loop' if self.arrival else ''}"
                f"{'/drift' if self.drift else ''}"
                f"{'/readvise' if self.readvise else ''}")


@dataclass
class ScenarioResult:
    scenario: Scenario
    n_processed: int
    n_duplicates: int
    makespan_s: float                 # virtual seconds, first gen → last done
    throughput_msgs_s: float
    latency_mean_s: float
    latency_p95_s: float
    wan_mbytes: float
    placement_estimates: Dict[str, float]     # PlacementEngine per-tier est.
    autoscale_events: List[dict] = field(default_factory=list)
    wall_ms: float = 0.0              # real milliseconds spent emulating
    metrics: MetricsRegistry = field(default=None, repr=False)
    latency_p50_s: float = 0.0        # tail decomposition (multi-objective)
    latency_p99_s: float = 0.0
    wan_bytes: float = 0.0            # exact bytes through the topic
    # per-stage execution tier vector, read off the *built* pipeline's
    # pilots (the one source of truth — never a per-placement literal)
    tiers: Tuple[str, ...] = ()
    spec_launches: int = 0            # straggler speculation accounting
    spec_wins: int = 0                # (wins + losses + cancelled == launches)
    spec_losses: int = 0
    spec_cancelled: int = 0
    # online re-advisory: one entry per applied hot-swap, with virtual
    # decision/apply timestamps (deterministic columns)
    swaps: List[dict] = field(default_factory=list)
    drift_events: int = 0             # drift events injected into the run

    def row(self) -> Dict[str, object]:
        """Deterministic summary — identical across runs at the same seed
        (``wall_ms`` is wall time and deliberately excluded)."""
        s = self.scenario
        return {
            "model": s.model.name, "placement": s.placement,
            "tiers": list(self.tiers),
            "wan": s.wan_band, "messages": s.n_messages,
            "processed": self.n_processed, "dups": self.n_duplicates,
            "makespan_s": self.makespan_s,
            "msgs_per_s": self.throughput_msgs_s,
            "lat_mean_s": self.latency_mean_s,
            "lat_p50_s": self.latency_p50_s,
            "lat_p95_s": self.latency_p95_s,
            "lat_p99_s": self.latency_p99_s,
            "wan_mb": self.wan_mbytes,
            "wan_bytes": self.wan_bytes,
            "autoscale_actions": len(self.autoscale_events),
            "spec_launches": self.spec_launches,
            "spec_wins": self.spec_wins,
            "spec_losses": self.spec_losses,
            "spec_cancelled": self.spec_cancelled,
            "drift_events": self.drift_events,
            "swaps": [dict(s) for s in self.swaps],
        }


def _edge_compute_s(sc: Scenario) -> float:
    """Per-message edge-stage service time for the scenario's placement."""
    m = sc.model
    if sc.placement == "edge":
        return sc.cost_model.compute_s(m.flops_per_point * sc.n_points,
                                       "edge", precision=m.precision)
    if sc.placement == "hybrid":
        return sc.cost_model.compute_s(
            m.preprocess_flops_per_point * sc.n_points, "edge")
    return 0.0


def _fog_compute_s(sc: Scenario) -> float:
    """Per-message fog-stage service time (pre-aggregation on the fog
    tier; fog placement only)."""
    return sc.cost_model.compute_s(
        sc.model.preprocess_flops_per_point * sc.n_points, "fog")


def _device_compute_s(sc: Scenario) -> float:
    """Per-message device-stage service time: the full model on the
    sensing SoC, priced at the SoC's peak for the model's kernel
    precision — the fp32-infeasible / int8-feasible split the precision
    placement axis exists for."""
    m = sc.model
    return sc.cost_model.compute_s(m.flops_per_point * sc.n_points,
                                   "device", precision=m.precision)


def _cloud_compute_s(sc: Scenario) -> float:
    """Per-message cloud-stage service time (one consumer slot)."""
    m = sc.model
    if sc.placement in ("edge", "device"):
        # results only need ingesting/merging on the cloud side
        return sc.cost_model.ingest_bytes_s(m.output_bytes, "cloud")
    points = sc.n_points if sc.placement == "cloud" \
        else max(sc.n_points // m.hybrid_reduce, 1)
    return sc.cost_model.compute_s(m.flops_per_point * points, "cloud",
                                   precision=m.precision)


def _reduced_payload(sc: Scenario) -> np.ndarray:
    return np.zeros((max(sc.n_points // sc.model.hybrid_reduce, 1),
                     N_FEATURES), np.float64)


def _output_payload(sc: Scenario) -> np.ndarray:
    return np.zeros(max(sc.model.output_bytes // 8, 1), np.float64)


def _payload(sc: Scenario) -> np.ndarray:
    """What the *source* stage puts on its first broker hop (real numpy
    serialization, so byte accounting is exact): edge placement publishes
    only the model output, hybrid the edge-reduced message, cloud and fog
    the raw points (fog reduces downstream, on the fog tier); device
    placement's first hop is the on-device handoff of the raw points to
    the SoC's model stage (the WAN only ever sees the model output)."""
    if sc.placement == "edge":
        return _output_payload(sc)
    if sc.placement == "hybrid":
        return _reduced_payload(sc)
    return np.zeros((sc.n_points, N_FEATURES), np.float64)


def _service_model(sc: Scenario):
    """Stage → virtual service seconds, priced by the shared CostModel
    (optionally with the calibrated lognormal noise)."""
    produce_s = sc.gen_s_per_point * sc.n_points + _edge_compute_s(sc)
    stages = {"produce": produce_s, "process_cloud": _cloud_compute_s(sc)}
    if sc.placement == "fog":
        stages["process_fog"] = _fog_compute_s(sc)
    if sc.placement == "device":
        stages["process_device"] = _device_compute_s(sc)
    return sc.cost_model.service_model(
        stages, sigma=sc.effective_service_sigma, seed=sc.seed)


def _stage_flops(sc: Scenario, stage: str) -> float:
    """Per-message FLOPs of a consumer stage, tier-independent — the
    tier-aware service model (and the ReAdvisor's scoring) price these at
    whatever tier the stage is bound to *at charge time*."""
    m = sc.model
    if stage == "process_fog":
        return m.preprocess_flops_per_point * sc.n_points
    if stage == "process_device":
        return m.flops_per_point * sc.n_points
    if stage != "process_cloud":
        raise ValueError(f"no per-message FLOPs known for stage {stage!r}")
    if sc.placement in ("edge", "device"):
        # only the published model output needs ingesting/merging
        return (m.output_bytes / 8.0) * INGEST_FLOPS_PER_VALUE
    points = sc.n_points if sc.placement == "cloud" \
        else max(sc.n_points // m.hybrid_reduce, 1)
    return m.flops_per_point * points


def _stage_precision(sc: Scenario, stage: str) -> str:
    """Kernel precision a stage's FLOPs run at: the model's calibrated
    precision wherever the stage executes the model itself; fp32 for
    pre-aggregation and output-ingest stages."""
    if stage == "process_device":
        return sc.model.precision
    if stage == "process_cloud" and sc.placement not in ("edge", "device"):
        return sc.model.precision
    return "fp32"


def _readvise_service_model(sc: Scenario, pipe):
    """Service model for re-advised runs: the watched stage's FLOPs are
    priced at its *live* pilot's tier at charge time, so a hot-swap
    re-prices service with no model rebuild; every other stage keeps its
    fixed pre-priced time from :func:`_service_model`."""
    name = sc.readvise.stage
    names = [s.name for s in pipe.stages]
    try:
        idx = names.index(name)
    except ValueError:
        raise ValueError(f"readvise stage {name!r} not in pipeline "
                         f"stages {names}") from None
    if idx == 0:
        raise ValueError("cannot re-advise stage 0 (the sources)")
    fixed = _service_model(sc)
    tiered = sc.cost_model.tier_service_model(
        {name: _stage_flops(sc, name)},
        resolve=lambda stage: (pipe.stages[idx].pilot.tier, 1),
        sigma=sc.effective_service_sigma, seed=sc.seed,
        stage_precision={name: _stage_precision(sc, name)})

    def model(stage, ctx, payload):
        if stage == name:
            return tiered(stage, ctx, payload)
        return fixed(stage, ctx, payload)

    return model


def _resolve_drift(sc: Scenario) -> Tuple[DriftSpec, ...]:
    """Fill band-name drift events with concrete numbers from the
    scenario profile's band tables (the executor applies numbers, not
    names) — unknown names/tables fail at build time, not mid-run."""
    out = []
    prof = sc.cost_model.profile
    for d in sc.drift:
        if d.kind == "band" and d.band is not None:
            if d.table == "wan":
                table = prof.wan_bands
            elif d.table == "metro":
                table = prof.metro_bands
            else:
                raise ValueError(f"unknown drift band table {d.table!r}; "
                                 f"known: wan, metro")
            if d.band not in table:
                raise ValueError(f"unknown {d.table} band {d.band!r}; "
                                 f"known: {sorted(table)}")
            link = table[d.band]
            d = _dc_replace(d, bandwidth_bps=link.bandwidth_bps,
                            rtt_s=link.latency_s)
        out.append(d)
    return tuple(out)


def _wan_link(sc: Scenario):
    """The scenario's WAN band from *its* cost model's profile (a custom
    ContinuumProfile re-prices the transfer side too, not just compute)."""
    bands = sc.cost_model.profile.wan_bands
    if sc.wan_band not in bands:
        raise ValueError(f"unknown wan_band {sc.wan_band!r}; "
                         f"known: {sorted(bands)}")
    return bands[sc.wan_band]


def placement_estimates(sc: Scenario) -> Dict[str, float]:
    """PlacementEngine per-tier completion-time estimates for one message
    of this scenario, priced over this scenario's WAN band — the full
    tier set (device, edge, fog, cloud), so the analytic view ranks the
    same candidates the DES sweeps.  The device estimate runs at the
    SoC's precision-scaled peak (``TaskProfile.precision``)."""
    wan = _wan_link(sc)
    links = {("edge", "cloud"): wan, ("edge", "hpc"): wan,
             ("fog", "cloud"): wan}
    eng = PlacementEngine(links=links, cost_model=sc.cost_model)
    mgr = PilotManager(devices=())
    device = mgr.submit_pilot(ComputeResource(tier="device",
                                              n_workers=sc.n_devices))
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=sc.n_devices))
    fog = mgr.submit_pilot(ComputeResource(
        tier="fog", n_workers=sc.n_fog or sc.n_devices))
    n_cons = sc.n_consumers or sc.n_devices
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=n_cons))
    return eng.compare_tiers(sc.model.task_profile(sc.n_points),
                             [device, edge, fog, cloud])


def build_pipeline(sc: Scenario):
    """Construct the genuine pipeline + SimExecutor for one scenario.
    Returns ``(pipeline, executor, manager)`` — run with
    ``pipeline.run(n_messages=sc.n_messages, scheduler=executor)``.

    ``edge``/``cloud``/``hybrid`` build the two-stage
    :class:`EdgeToCloudPipeline` wrapper; ``fog`` builds a genuine
    3-stage :class:`ContinuumPipeline` (edge → fog → cloud) whose first
    hop rides the edge→fog metro link and whose second hop rides the
    scenario's WAN band; ``device`` builds a 3-stage pipeline whose
    first hop is the on-device handoff (raw points over the device
    tier's intra link) into the SoC model stage, and whose second hop
    ships only the model output over the WAN."""
    from repro.sim.clock import SimClock
    if sc.placement not in PLACEMENTS:
        raise ValueError(f"placement must be one of {PLACEMENTS}")
    wan = _wan_link(sc)
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=sc.n_devices))
    n_cons = sc.n_consumers or sc.n_devices
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=n_cons))
    bw_bps, rtt = wan.bandwidth_bps, wan.latency_s
    payload = _payload(sc)
    # band-true pricing view: the pipeline's engine (what rebind_stage
    # re-prices hop shapers with) and the ReAdvisor's predictions both
    # route edge->cloud over *this scenario's* WAN band
    band_cost = sc.cost_model.with_wan(sc.wan_band)
    engine = PlacementEngine(cost_model=band_cost)
    # service times are priced by the service model, not heartbeats;
    # only explicit "silent" failure injection should trip the monitor
    heartbeat_s = (30.0 if any(f.kind == "silent" for f in sc.failures)
                   else sc.t_max_s)
    wan_shaper = WanShaper(bandwidth_bps=bw_bps, rtt_s=rtt, sleep=False)
    if sc.placement == "fog":
        fog = mgr.submit_pilot(ComputeResource(
            tier="fog", n_workers=sc.n_fog or sc.n_devices))
        metro = sc.cost_model.profile.link("edge", "fog")
        reduced = _reduced_payload(sc)
        pipe = ContinuumPipeline(
            stages=[
                StageSpec("produce", lambda ctx: payload,
                          pilot=edge, n_tasks=sc.n_devices),
                StageSpec("process_fog",
                          lambda ctx, data=None: reduced, pilot=fog,
                          n_tasks=sc.n_fog or sc.n_devices),
                StageSpec("process_cloud",
                          lambda ctx, data=None: None, pilot=cloud,
                          n_tasks=n_cons),
            ],
            n_partitions=sc.n_devices, topic_name="e2c",
            shapers=[WanShaper(bandwidth_bps=metro.bandwidth_bps,
                               rtt_s=metro.latency_s, sleep=False),
                     wan_shaper],
            metrics=metrics, clock=clock,
            placement_engine=engine,
            speculative_factor=sc.speculative_factor,
            heartbeat_timeout_s=heartbeat_s)
    elif sc.placement == "device":
        device = mgr.submit_pilot(ComputeResource(
            tier="device", n_workers=sc.n_devices))
        intra = sc.cost_model.profile.tier("device").intra_link
        out_payload = _output_payload(sc)
        pipe = ContinuumPipeline(
            stages=[
                StageSpec("produce", lambda ctx: payload,
                          pilot=device, n_tasks=sc.n_devices),
                StageSpec("process_device",
                          lambda ctx, data=None: out_payload,
                          pilot=device, n_tasks=sc.n_devices),
                StageSpec("process_cloud",
                          lambda ctx, data=None: None, pilot=cloud,
                          n_tasks=n_cons),
            ],
            n_partitions=sc.n_devices, topic_name="e2c",
            shapers=[WanShaper(bandwidth_bps=intra.bandwidth_bps,
                               rtt_s=intra.latency_s, sleep=False),
                     wan_shaper],
            metrics=metrics, clock=clock,
            placement_engine=engine,
            speculative_factor=sc.speculative_factor,
            heartbeat_timeout_s=heartbeat_s)
    else:
        pipe = EdgeToCloudPipeline(
            pilot_cloud_processing=cloud, pilot_edge=edge,
            produce_function_handler=lambda ctx: payload,
            process_cloud_function_handler=lambda ctx, data=None: None,
            n_edge_devices=sc.n_devices, n_partitions=sc.n_devices,
            cloud_consumers=n_cons, topic_name="e2c",
            wan_shaper=wan_shaper,
            metrics=metrics, clock=clock, placement_engine=engine,
            speculative_factor=sc.speculative_factor,
            heartbeat_timeout_s=heartbeat_s)
    scaler = None
    if sc.autoscale is not None:
        scaler = AutoScaler(mgr, cloud, lag_fn=pipe.current_lag,
                            policy=sc.autoscale, metrics=metrics,
                            interval_s=sc.autoscale_interval_s, clock=clock)
    # per-stage policies: each named consumer stage gets its own scaler
    # watching *its* group's lag and resizing *its* pilot
    stage_names = [s.name for s in pipe.stages]
    scalers = {}
    for name, policy in sc.autoscale_stages:
        si = stage_names.index(name)
        scalers[name] = AutoScaler(
            mgr, pipe.stages[si].pilot,
            lag_fn=(lambda i=si: pipe.stage_lag(i)),
            policy=policy, metrics=metrics,
            interval_s=sc.autoscale_interval_s, clock=clock)
    if sc.arrival is not None:
        # open loop: the drawn arrival times carry the device phases
        offsets = []
    else:
        # deterministic per-device phase offsets (devices don't boot in
        # lockstep), drawn in device order from the seeded rng
        rng = np.random.default_rng(sc.seed)
        gen_s = sc.gen_s_per_point * sc.n_points
        offsets = [float(rng.uniform(0.0, gen_s + 1e-9))
                   for _ in range(sc.n_devices)]
    # online re-advisory: build the watcher over the scenario's (band-
    # adjusted) cost model with one pilot per candidate tier — existing
    # pilots are reused, missing tiers get a fresh consumer-sized pilot
    rv = None
    if sc.readvise is not None:
        spec = sc.readvise
        pilots = {"edge": edge, "cloud": cloud}
        if sc.placement == "fog":
            pilots["fog"] = fog
        elif sc.placement == "device":
            pilots["device"] = device
        targets = {}
        for tier in spec.targets:
            if tier not in pilots:
                pilots[tier] = mgr.submit_pilot(ComputeResource(
                    tier=tier, n_workers=n_cons))
            targets[tier] = pilots[tier]
        rv = ReAdvisor(band_cost, stage=spec.stage,
                       flops=_stage_flops(sc, spec.stage),
                       targets=targets, interval_s=spec.interval_s,
                       hysteresis=spec.hysteresis,
                       min_samples=spec.min_samples,
                       cooldown_s=spec.cooldown_s,
                       max_swaps=spec.max_swaps,
                       apply_delay_s=spec.apply_delay_s)
    service = (_readvise_service_model(sc, pipe) if rv is not None
               else _service_model(sc))
    ex = SimExecutor(clock=clock, service_model=service,
                     producer_offsets=offsets, crash_plan=sc.failures,
                     autoscaler=scaler, autoscalers=scalers,
                     autoscale_interval_s=sc.autoscale_interval_s,
                     drift_plan=_resolve_drift(sc), readvisor=rv)
    return pipe, ex, mgr


def run_scenario(sc: Scenario) -> ScenarioResult:
    """Emulate one scenario to completion on the real pipeline; returns
    deterministic metrics."""
    t_wall = _walltime.perf_counter()
    pipe, ex, _ = build_pipeline(sc)
    plan = arrival_plan(sc)
    if plan is not None:
        res = pipe.run(timeout_s=sc.t_max_s, collect_results=False,
                       scheduler=ex, arrival_plan=plan)
    else:
        res = pipe.run(n_messages=sc.n_messages, timeout_s=sc.t_max_s,
                       collect_results=False, scheduler=ex)
    metrics = res.metrics

    lat = metrics.latencies("produced", "processed")
    lat.sort()
    first = metrics.first_stamp("produced") or 0.0
    last = metrics.last_stamp("processed") or 0.0
    makespan = max(last - first, 1e-9)
    n_done = res.n_processed
    histories: List[dict] = []
    if ex.autoscaler is not None:
        histories.extend(ex.autoscaler.history)
    for s in ex.autoscalers.values():
        histories.extend(s.history)

    def pct(q):
        return lat[min(len(lat) - 1, int(q * len(lat)))] if lat else 0.0

    # the hop that enters the cloud tier is the WAN crossing in every
    # placement (fog's first hop is the metro link, not WAN)
    wan_bytes = metrics.counter(f"topic.{pipe._topics[-1].name}.bytes_in")
    return ScenarioResult(
        scenario=sc,
        tiers=tuple(pipe.stage_tiers),
        n_processed=n_done,
        n_duplicates=int(metrics.counter("pipeline.duplicates_dropped")),
        makespan_s=makespan,
        throughput_msgs_s=n_done / makespan,
        latency_mean_s=float(np.mean(lat)) if lat else 0.0,
        latency_p50_s=pct(0.50),
        latency_p95_s=pct(0.95),
        latency_p99_s=pct(0.99),
        wan_mbytes=wan_bytes / 1e6,
        wan_bytes=wan_bytes,
        spec_launches=int(metrics.counter("runtime.speculative_launches")),
        spec_wins=int(metrics.counter("runtime.speculative_wins")),
        spec_losses=int(metrics.counter("runtime.speculative_losses")),
        spec_cancelled=int(metrics.counter("runtime.speculative_cancelled")),
        placement_estimates=placement_estimates(sc),
        autoscale_events=histories,
        swaps=(list(ex.readvisor.swap_log)
               if ex.readvisor is not None else []),
        drift_events=len(sc.drift),
        wall_ms=(_walltime.perf_counter() - t_wall) * 1e3,
        metrics=metrics)


def sweep(models: Sequence[ModelSpec] = (KMEANS, AUTOENCODER),
          placements: Sequence[str] = PLACEMENTS,
          bands: Sequence[str] = ("10mbit", "50mbit", "100mbit"),
          *, n_messages: int = 64, n_devices: int = 4,
          n_points: int = 2_500, seed: int = 0,
          failures: Tuple[FailureSpec, ...] = (),
          service_sigma: Optional[float] = 0.0,
          speculative_factor: float = 0.0) -> List[ScenarioResult]:
    """The Fig-3 grid: {models} × {placements} × {WAN bands}."""
    out = []
    for m in models:
        for p in placements:
            for b in bands:
                out.append(run_scenario(Scenario(
                    model=m, placement=p, wan_band=b,
                    n_messages=n_messages, n_devices=n_devices,
                    n_points=n_points, seed=seed, failures=failures,
                    service_sigma=service_sigma,
                    speculative_factor=speculative_factor)))
    return out


def format_table(results: Sequence[ScenarioResult]) -> str:
    """The paper's throughput/latency trade-off table."""
    hdr = (f"{'model':>12} {'placement':>9} {'wan':>8} {'done':>5} "
           f"{'dups':>4} {'msg/s':>9} {'lat-mean s':>10} {'lat-p95 s':>9} "
           f"{'WAN MB':>8} {'wall ms':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in results:
        s = r.scenario
        lines.append(
            f"{s.model.name:>12} {s.placement:>9} {s.wan_band:>8} "
            f"{r.n_processed:>5} {r.n_duplicates:>4} "
            f"{r.throughput_msgs_s:>9.3f} {r.latency_mean_s:>10.3f} "
            f"{r.latency_p95_s:>9.3f} {r.wan_mbytes:>8.2f} "
            f"{r.wall_ms:>8.1f}")
    return "\n".join(lines)
