"""Fig-3 scenarios: emulated edge-to-cloud pipeline runs — on the *real*
pipeline.

Each scenario builds a genuine :class:`~repro.core.faas.EdgeToCloudPipeline`
(real ``Broker``/``Topic``/``ConsumerGroup``/``WanShaper``/
``MetricsRegistry``/pilots) and runs it with
``run(scheduler=SimExecutor(...))`` — the single-threaded discrete-event
strategy from :mod:`repro.core.executor`.  There is no harness replica of
the pipeline logic any more: broker offsets, at-least-once redelivery,
dedup, byte accounting, consumer-group rebalances and linked metrics are
the production code paths, only time is virtual.  A sweep of {model} ×
{placement} × {WAN band} that takes hours of real pipeline time (paper
Fig 2/3) replays in milliseconds with bit-reproducible metrics.

Placement modalities (the paper's deployment modalities, §II-C):

* ``cloud``  — raw points cross the WAN; the model runs on the cloud tier.
* ``edge``   — the model runs next to the generator; only the (small)
  model output crosses the WAN.
* ``hybrid`` — an edge pre-aggregation stage shrinks each message by
  ``hybrid_reduce`` before the WAN hop; the model finishes on the cloud.

Cost model: the scenario's *service model* prices the produce and cloud
stages from task FLOPs / tier FLOP/s with the same ``EDGE_FLOPS`` /
``DEVICE_FLOPS`` constants the :class:`PlacementEngine` uses, so emulated
throughput and the engine's ``compare_tiers`` estimates are mutually
consistent (tested in ``tests/test_sim.py``).

Dynamism scenarios: ``failures`` injects consumer crashes (or silent node
loss the heartbeat monitor must detect) mid-run; ``autoscale`` attaches a
lag-driven :class:`~repro.core.elastic.AutoScaler` to the consuming pilot,
stepped inside the DES, with the consumer pool following its resizes.
"""
from __future__ import annotations

import time as _walltime
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.broker import WanShaper
from repro.core.elastic import AutoScaler, ScalePolicy
from repro.core.executor import SimExecutor
from repro.core.faas import EdgeToCloudPipeline
from repro.core.monitoring import MetricsRegistry
from repro.core.pilot import ComputeResource, PilotManager
from repro.core.placement import (DEVICE_FLOPS, EDGE_FLOPS, LinkModel,
                                  PlacementEngine, TaskProfile)
from repro.ml.datagen import N_FEATURES, message_nbytes
from repro.sim.clock import SimClock

# the paper's iPerf band plus the constrained 10 Mbit/s point used for the
# placement-sensitivity experiments; (bandwidth bits/s, RTT seconds)
WAN_BANDS: Dict[str, Tuple[float, float]] = {
    "10mbit": (10e6, 0.150),
    "50mbit": (50e6, 0.150),
    "100mbit": (100e6, 0.140),
}

PLACEMENTS = ("edge", "cloud", "hybrid")


@dataclass(frozen=True)
class ModelSpec:
    """Analytic cost of one processing model, per data point."""
    name: str
    flops_per_point: float          # full model cost
    output_bytes: int               # serialized model output per message
    hybrid_reduce: int = 10         # edge pre-aggregation shrink factor
    preprocess_flops_per_point: float = 200.0

    def task_profile(self, n_points: int) -> TaskProfile:
        """The what-the-placement-engine-sees view of one message."""
        return TaskProfile(
            flops=self.flops_per_point * n_points,
            input_bytes=float(message_nbytes(n_points)),
            input_tier="edge",
            output_bytes=float(self.output_bytes),
            output_tier="cloud")


# k-means assignment+update: ~2·k·d FLOPs/point × a handful of Lloyd
# iterations — cheap per byte, i.e. transfer-bound (paper Fig 3 left).
KMEANS = ModelSpec("kmeans", flops_per_point=8_000.0,
                   output_bytes=25 * N_FEATURES * 8)
# autoencoder minibatch training: forward+backward over the dense stack ×
# epochs — expensive per byte, i.e. compute-bound (paper Fig 3 right):
# even the 10 Mbit/s link feeds points faster than the cloud tier trains
# on them, so placement is WAN-insensitive.
AUTOENCODER = ModelSpec("autoencoder", flops_per_point=6e7,
                        output_bytes=2_048)
MODELS: Dict[str, ModelSpec] = {m.name: m for m in (KMEANS, AUTOENCODER)}


@dataclass(frozen=True)
class FailureSpec:
    """Crash consumer ``consumer_idx`` at virtual time ``at_s``; a
    replacement (fresh member id, resuming from committed offsets) joins
    ``restart_after_s`` later unless None.  ``kind="crash"`` raises inside
    the consumer (immediate rebalance); ``kind="silent"`` makes the node
    go dark so only the heartbeat monitor can detect the loss."""
    at_s: float
    consumer_idx: int = 0
    restart_after_s: Optional[float] = 1.0
    kind: str = "crash"             # crash | silent


@dataclass(frozen=True)
class Scenario:
    model: ModelSpec = KMEANS
    placement: str = "cloud"                  # edge | cloud | hybrid
    wan_band: str = "100mbit"                 # key into WAN_BANDS
    n_messages: int = 64
    n_devices: int = 4                        # edge devices == partitions
    n_consumers: Optional[int] = None         # default: n_devices
    n_points: int = 2_500                     # points per message
    gen_s_per_point: float = 2e-6             # Mini-App generation cost
    failures: Tuple[FailureSpec, ...] = ()
    autoscale: Optional[ScalePolicy] = None   # lag-driven resize in the DES
    autoscale_interval_s: float = 0.2
    seed: int = 0
    t_max_s: float = 36_000.0                 # virtual-time safety cap

    def label(self) -> str:
        return (f"{self.model.name}/{self.placement}/{self.wan_band}"
                f"{'/fail' if self.failures else ''}"
                f"{'/autoscale' if self.autoscale else ''}")


@dataclass
class ScenarioResult:
    scenario: Scenario
    n_processed: int
    n_duplicates: int
    makespan_s: float                 # virtual seconds, first gen → last done
    throughput_msgs_s: float
    latency_mean_s: float
    latency_p95_s: float
    wan_mbytes: float
    placement_estimates: Dict[str, float]     # PlacementEngine per-tier est.
    autoscale_events: List[dict] = field(default_factory=list)
    wall_ms: float = 0.0              # real milliseconds spent emulating
    metrics: MetricsRegistry = field(default=None, repr=False)

    def row(self) -> Dict[str, object]:
        """Deterministic summary — identical across runs at the same seed
        (``wall_ms`` is wall time and deliberately excluded)."""
        s = self.scenario
        return {
            "model": s.model.name, "placement": s.placement,
            "wan": s.wan_band, "messages": s.n_messages,
            "processed": self.n_processed, "dups": self.n_duplicates,
            "makespan_s": self.makespan_s,
            "msgs_per_s": self.throughput_msgs_s,
            "lat_mean_s": self.latency_mean_s,
            "lat_p95_s": self.latency_p95_s,
            "wan_mb": self.wan_mbytes,
            "autoscale_actions": len(self.autoscale_events),
        }


def _edge_compute_s(sc: Scenario) -> float:
    """Per-message edge-stage service time for the scenario's placement."""
    m = sc.model
    if sc.placement == "edge":
        return m.flops_per_point * sc.n_points / EDGE_FLOPS
    if sc.placement == "hybrid":
        return m.preprocess_flops_per_point * sc.n_points / EDGE_FLOPS
    return 0.0


def _cloud_compute_s(sc: Scenario) -> float:
    """Per-message cloud-stage service time (one consumer slot)."""
    m = sc.model
    if sc.placement == "edge":
        # results only need ingesting/merging on the cloud side
        return m.output_bytes / 8 * 50.0 / DEVICE_FLOPS
    points = sc.n_points if sc.placement == "cloud" \
        else max(sc.n_points // m.hybrid_reduce, 1)
    return m.flops_per_point * points / DEVICE_FLOPS


def _payload(sc: Scenario) -> np.ndarray:
    """What actually crosses the broker for this placement (real numpy
    serialization, so WAN byte accounting is exact)."""
    if sc.placement == "edge":
        return np.zeros(max(sc.model.output_bytes // 8, 1), np.float64)
    if sc.placement == "hybrid":
        return np.zeros((max(sc.n_points // sc.model.hybrid_reduce, 1),
                         N_FEATURES), np.float64)
    return np.zeros((sc.n_points, N_FEATURES), np.float64)


def _service_model(sc: Scenario):
    """Stage → virtual service seconds, priced like the PlacementEngine."""
    produce_s = sc.gen_s_per_point * sc.n_points + _edge_compute_s(sc)
    cloud_s = _cloud_compute_s(sc)

    def model(stage, ctx, payload):
        if stage == "produce":
            return produce_s
        if stage == "process_cloud":
            return cloud_s
        return 0.0

    return model


def placement_estimates(sc: Scenario) -> Dict[str, float]:
    """PlacementEngine per-tier completion-time estimates for one message
    of this scenario, priced over this scenario's WAN band."""
    bw_bps, rtt = WAN_BANDS[sc.wan_band]
    links = {("edge", "cloud"): LinkModel(bandwidth=bw_bps / 8.0,
                                          latency_s=rtt),
             ("edge", "hpc"): LinkModel(bandwidth=bw_bps / 8.0,
                                        latency_s=rtt)}
    eng = PlacementEngine(links=links)
    mgr = PilotManager(devices=())
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=sc.n_devices))
    n_cons = sc.n_consumers or sc.n_devices
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=n_cons))
    return eng.compare_tiers(sc.model.task_profile(sc.n_points),
                             [edge, cloud])


def build_pipeline(sc: Scenario):
    """Construct the genuine pipeline + SimExecutor for one scenario.
    Returns ``(pipeline, executor, manager)`` — run with
    ``pipeline.run(n_messages=sc.n_messages, scheduler=executor)``."""
    if sc.placement not in PLACEMENTS:
        raise ValueError(f"placement must be one of {PLACEMENTS}")
    if sc.wan_band not in WAN_BANDS:
        raise ValueError(f"unknown wan_band {sc.wan_band!r}; "
                         f"known: {sorted(WAN_BANDS)}")
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    mgr = PilotManager(devices=(), clock=clock)
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=sc.n_devices))
    n_cons = sc.n_consumers or sc.n_devices
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=n_cons))
    bw_bps, rtt = WAN_BANDS[sc.wan_band]
    payload = _payload(sc)
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: payload,
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=sc.n_devices, n_partitions=sc.n_devices,
        cloud_consumers=n_cons, topic_name="e2c",
        wan_shaper=WanShaper(bandwidth_bps=bw_bps, rtt_s=rtt, sleep=False),
        metrics=metrics, clock=clock,
        # service times are priced by the service model, not heartbeats;
        # only explicit "silent" failure injection should trip the monitor
        heartbeat_timeout_s=(30.0 if any(f.kind == "silent"
                                         for f in sc.failures)
                             else sc.t_max_s))
    scaler = None
    if sc.autoscale is not None:
        scaler = AutoScaler(mgr, cloud, lag_fn=pipe.current_lag,
                            policy=sc.autoscale, metrics=metrics,
                            interval_s=sc.autoscale_interval_s, clock=clock)
    # deterministic per-device phase offsets (devices don't boot in
    # lockstep), drawn in device order from the seeded rng
    rng = np.random.default_rng(sc.seed)
    gen_s = sc.gen_s_per_point * sc.n_points
    offsets = [float(rng.uniform(0.0, gen_s + 1e-9))
               for _ in range(sc.n_devices)]
    ex = SimExecutor(clock=clock, service_model=_service_model(sc),
                     producer_offsets=offsets, crash_plan=sc.failures,
                     autoscaler=scaler,
                     autoscale_interval_s=sc.autoscale_interval_s)
    return pipe, ex, mgr


def run_scenario(sc: Scenario) -> ScenarioResult:
    """Emulate one scenario to completion on the real pipeline; returns
    deterministic metrics."""
    t_wall = _walltime.perf_counter()
    pipe, ex, _ = build_pipeline(sc)
    res = pipe.run(n_messages=sc.n_messages, timeout_s=sc.t_max_s,
                   collect_results=False, scheduler=ex)
    metrics = res.metrics

    lat = metrics.latencies("produced", "processed")
    lat.sort()
    first = metrics.first_stamp("produced") or 0.0
    last = metrics.last_stamp("processed") or 0.0
    makespan = max(last - first, 1e-9)
    n_done = res.n_processed
    scaler = ex.autoscaler
    return ScenarioResult(
        scenario=sc,
        n_processed=n_done,
        n_duplicates=int(metrics.counter("pipeline.duplicates_dropped")),
        makespan_s=makespan,
        throughput_msgs_s=n_done / makespan,
        latency_mean_s=float(np.mean(lat)) if lat else 0.0,
        latency_p95_s=lat[min(len(lat) - 1, int(0.95 * len(lat)))]
        if lat else 0.0,
        wan_mbytes=metrics.counter(
            f"topic.{pipe._topic.name}.bytes_in") / 1e6,
        placement_estimates=placement_estimates(sc),
        autoscale_events=list(scaler.history) if scaler else [],
        wall_ms=(_walltime.perf_counter() - t_wall) * 1e3,
        metrics=metrics)


def sweep(models: Sequence[ModelSpec] = (KMEANS, AUTOENCODER),
          placements: Sequence[str] = PLACEMENTS,
          bands: Sequence[str] = ("10mbit", "50mbit", "100mbit"),
          *, n_messages: int = 64, n_devices: int = 4,
          n_points: int = 2_500, seed: int = 0,
          failures: Tuple[FailureSpec, ...] = ()) -> List[ScenarioResult]:
    """The Fig-3 grid: {models} × {placements} × {WAN bands}."""
    out = []
    for m in models:
        for p in placements:
            for b in bands:
                out.append(run_scenario(Scenario(
                    model=m, placement=p, wan_band=b,
                    n_messages=n_messages, n_devices=n_devices,
                    n_points=n_points, seed=seed, failures=failures)))
    return out


def format_table(results: Sequence[ScenarioResult]) -> str:
    """The paper's throughput/latency trade-off table."""
    hdr = (f"{'model':>12} {'placement':>9} {'wan':>8} {'done':>5} "
           f"{'dups':>4} {'msg/s':>9} {'lat-mean s':>10} {'lat-p95 s':>9} "
           f"{'WAN MB':>8} {'wall ms':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in results:
        s = r.scenario
        lines.append(
            f"{s.model.name:>12} {s.placement:>9} {s.wan_band:>8} "
            f"{r.n_processed:>5} {r.n_duplicates:>4} "
            f"{r.throughput_msgs_s:>9.3f} {r.latency_mean_s:>10.3f} "
            f"{r.latency_p95_s:>9.3f} {r.wan_mbytes:>8.2f} "
            f"{r.wall_ms:>8.1f}")
    return "\n".join(lines)
