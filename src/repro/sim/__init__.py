"""Deterministic virtual-time emulation for the edge-to-cloud continuum.

The paper's companion work (*Exploring Task Placement for Edge-to-Cloud
Applications using Emulation*, arXiv 2104.03368) argues the placement
trade-off space — model complexity × WAN band × partition layout ×
failure schedule — is only explorable at scale through emulation.  This
package provides the three pieces that make that possible here:

* :mod:`repro.sim.clock` — the injected-clock API.  :class:`SimClock` is a
  virtual clock; :class:`SystemClock` is the wall-clock default.  Every
  core layer (broker, runtime, pilot liveness, autoscaler, monitoring,
  pipeline) takes a ``clock=`` and never calls ``time.*`` directly.
* :mod:`repro.sim.scheduler` — :class:`EventScheduler`, a classic
  discrete-event loop over the virtual clock with deterministic
  (time, insertion-order) event ordering.
* :mod:`repro.sim.scenarios` — the Fig-3 scenario harness: geo-distributed
  pipeline runs (k-means / autoencoder × edge / cloud / hybrid placement ×
  WAN bands × failure schedules) replayed in milliseconds of wall time
  with bit-reproducible metrics.

``scenarios`` is re-exported lazily (PEP 562) because it imports
``repro.core`` which itself imports :mod:`repro.sim.clock`.
"""
from repro.sim.clock import (SYSTEM_CLOCK, Clock, SimClock, SystemClock,
                             as_clock)
from repro.sim.scheduler import PARK, Actor, ActorKilled, EventScheduler

_SCENARIO_NAMES = ("ModelSpec", "Scenario", "ScenarioResult", "FailureSpec",
                   "WAN_BANDS", "KMEANS", "AUTOENCODER", "ISOFOREST",
                   "MODELS", "PLACEMENTS", "model_specs", "run_scenario",
                   "sweep", "format_table",
                   "ArrivalProcess", "PoissonArrivals", "DiurnalArrivals",
                   "FlashCrowdArrivals", "TraceArrivals", "arrival_plan")
# SimExecutor lives in repro.core.executor (it drives the real pipeline);
# re-exported here lazily because repro.core imports repro.sim.clock.
_EXECUTOR_NAMES = ("SimExecutor", "ThreadedExecutor")

__all__ = ["Clock", "SystemClock", "SimClock", "SYSTEM_CLOCK", "as_clock",
           "EventScheduler", "Actor", "ActorKilled", "PARK",
           *_EXECUTOR_NAMES, *_SCENARIO_NAMES]


def __getattr__(name):
    if name in _SCENARIO_NAMES:
        from repro.sim import scenarios
        return getattr(scenarios, name)
    if name in _EXECUTOR_NAMES:
        from repro.core import executor
        return getattr(executor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
