"""Sharded DES: conservative time-window parallel simulation across
processes, partitioned by topology.

The single-process DES (ROADMAP item 1) is CPU-bound: one thread drains
one :class:`~repro.sim.scheduler.EventScheduler`.  This module splits a
run into **shards** — each shard a complete
:class:`~repro.core.faas.ContinuumPipeline` over a disjoint slice of the
topology, driven by its own ``EventScheduler``/``SimExecutor`` on its own
virtual clock — and synchronizes them with the classic *conservative
time-window* protocol:

* **Lookahead.** The minimum latency of any routed inter-shard link
  (:func:`lookahead_s`, priced from ``CostModel``'s
  ``route(a, b).transfer_s``) bounds how early a message produced in one
  shard can become visible in another.  With window ``W <= lookahead``,
  a message produced inside window ``k`` (``[T_k, T_k + W)``) carries
  ``ready_at >= T_k + lookahead >= T_{k+1}`` — so delivering it at the
  ``T_{k+1}`` barrier, *before* any shard simulates past ``T_{k+1}``,
  can never violate causality.  Shards advance in lock-step windows and
  exchange boundary batches at every barrier.

* **Boundary queues.** Cross-shard broker topics become explicit
  boundary queues: after each window a shard scans its export hops'
  partition logs past a watermark and ships ``(ready_at, Message)``
  batches (plus the original ``produced`` stamp time) over
  ``multiprocessing`` pipes; the receiving shard appends them with
  :meth:`~repro.core.broker.Topic.inject` — explicit ``ready_at``, no
  double-charged shaper delay, no double-counted bytes.

* **Determinism.** Every random draw is derived from ``(seed,
  shard_id)`` via :func:`shard_seed` (a SplitMix64 split — the
  Philox-style independent-stream construction), and globally-shared
  draws (the scale benchmark's arrival process) are drawn *once* from
  the global seed and sliced by global device index — so the
  deterministic columns are bit-identical regardless of worker count.

Two partitionings ship:

* :func:`build_scale_shard` — the scale benchmark's device-partition
  cut: each shard owns a contiguous block of devices *and* the matching
  block of consumers, a complete sub-pipeline with **no** cross-shard
  links (lookahead = ∞ → a single window).  Requires
  ``consumers >= devices`` (each partition then has a dedicated
  consumer, so per-partition timelines are independent and the merged
  latency multiset is bit-identical to single-process).
* :func:`build_tier_cut_shard` — the pipeline cut at the edge→cloud
  WAN hop: shard 0 owns the sources and the WAN shaper, shard 1 the
  consumers; lookahead = the WAN's min one-way latency; finite windows
  exercise the full boundary-queue protocol (this is the cut the
  causality property test drives).

When is a workload too chatty to shard?  When state is *shared* across
the cut — e.g. a WAN shaper's token bucket serializes all partitions
through one ``_available_at``, or consumers < devices couples several
partitions through one consumer's service queue.  Splitting either
changes the schedule, so :func:`run_scale_sharded` refuses such
configurations instead of silently de-synchronizing.
"""
from __future__ import annotations

import math
import multiprocessing as mp
import resource
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.broker import WanShaper
from repro.core.executor import SimExecutor
from repro.core.faas import ContinuumPipeline, EdgeToCloudPipeline, StageSpec
from repro.core.monitoring import LatencySketch, MetricsRegistry
from repro.core.pilot import ComputeResource, PilotManager
from repro.sim.clock import SimClock
from repro.sim.scenarios import arrival_process

_MASK64 = (1 << 64) - 1


def shard_seed(seed: int, shard_id: int) -> int:
    """Independent per-shard RNG stream seed: a SplitMix64 mix of
    ``(seed, shard_id)`` — the same construction Philox-style counter
    RNGs use to split one key into independent streams.  Derived, not
    ``seed + shard_id``: neighbouring seeds of the same generator family
    are *not* independent streams, and a run's determinism must not
    depend on how many workers happened to be used."""
    z = (int(seed) + (int(shard_id) + 1) * 0x9E3779B97F4A7C15) & _MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (z ^ (z >> 31)) & _MASK64


def split_blocks(n: int, k: int) -> List[Tuple[int, int]]:
    """Split ``range(n)`` into ``k`` contiguous ``[start, stop)`` blocks,
    sizes differing by at most one (larger blocks first).  Monotone in
    ``n`` per block index — so if global ``consumers >= devices``, every
    shard's consumer block covers its device block."""
    if k < 1:
        raise ValueError(f"need k >= 1, got {k}")
    base, rem = divmod(n, k)
    out, start = [], 0
    for i in range(k):
        size = base + (1 if i < rem else 0)
        out.append((start, start + size))
        start += size
    return out


def lookahead_s(cost, cuts: Sequence[Tuple[str, str]],
                nbytes: float = 0.0) -> float:
    """Conservative-window lookahead: the minimum routed transfer time
    across the inter-shard cut links — ``min`` over ``(src_tier,
    dst_tier)`` pairs of ``cost.route(src, dst).transfer_s(nbytes)``.
    With ``nbytes=0`` this is the pure routed link latency (the safe
    bound: real messages only take longer).  No cuts → ``inf`` (fully
    independent shards need a single window)."""
    if not cuts:
        return math.inf
    return min(cost.route(a, b).transfer_s(nbytes) for a, b in cuts)


# ---------------------------------------------------------------------------
# one shard
# ---------------------------------------------------------------------------


class ShardRunner:
    """One shard: a started windowed pipeline run plus its boundary-queue
    bookkeeping (export watermarks, injected-message ledger)."""

    def __init__(self, shard_id: int, pipe, executor: SimExecutor, handle,
                 metrics: MetricsRegistry, *,
                 export_hops: Optional[Dict[int, int]] = None,
                 streaming: bool = False, mgr: Optional[PilotManager] = None,
                 control_pilots: Optional[Dict[str, object]] = None):
        self.shard_id = shard_id
        self.pipe = pipe
        self.executor = executor
        self.handle = handle                   # started _SimRun
        self.metrics = metrics
        self.streaming = streaming
        self.mgr = mgr
        # tier -> Pilot map for applying *remote* re-advisory swap
        # commands (the control channel); None = this shard never
        # applies controls
        self.control_pilots = dict(control_pilots or {})
        self._ctl_wm = 0                       # decisions already exported
        # hop index -> destination shard id; messages appended to that
        # hop's topic are boundary traffic for the destination shard
        self.export_hops = dict(export_hops or {})
        self.deadline = handle.deadline
        # absolute end offsets already exported, per (hop, partition)
        self._export_wm: Dict[int, List[int]] = {
            hop: [p.base + len(p.log)
                  for p in handle.state.topics[hop].partitions]
            for hop in self.export_hops}
        # msg_id -> (injection clock time, ready_at): the causality
        # ledger the property tests audit
        self.injected: Dict[str, Tuple[float, float]] = {}

    @property
    def done(self) -> bool:
        return self.handle.done

    @property
    def clock_now(self) -> float:
        return self.executor.clock.now()

    def advance(self, t: float) -> None:
        self.handle.advance_to(t)

    def collect_exports(self) -> List[Tuple]:
        """Boundary messages appended since the last collection:
        ``(dest_shard, hop, partition, msg_id, key, raw, ready_at,
        produced_t)`` tuples, in partition-log order."""
        out: List[Tuple] = []
        trace = None if self.streaming else self.metrics.trace
        for hop, dest in self.export_hops.items():
            topic = self.handle.state.topics[hop]
            wm = self._export_wm[hop]
            for p, part in enumerate(topic.partitions):
                end = part.base + len(part.log)
                if end <= wm[p]:
                    continue
                for idx in range(wm[p] - part.base, len(part.log)):
                    m = part.log[idx]
                    produced_t = None
                    if trace is not None:
                        tr = trace(m.msg_id)
                        if tr is not None:
                            produced_t = tr.stamps.get("produced")
                    out.append((dest, hop, p, m.msg_id, m.key, m.raw,
                                part.ready_at[idx], produced_t))
                wm[p] = end
        return out

    def deliver(self, items: Sequence[Tuple]) -> None:
        """Inject boundary messages received at a window barrier:
        ``(hop, partition, msg_id, key, raw, ready_at, produced_t)``."""
        topics = self.handle.state.topics
        now = self.clock_now
        for hop, p, msg_id, key, raw, ready_at, produced_t in items:
            topics[hop].inject(raw, msg_id=msg_id, partition=p,
                               ready_at=ready_at, key=key,
                               produced_t=produced_t)
            self.injected[msg_id] = (now, ready_at)

    def collect_controls(self) -> List[dict]:
        """Re-advisory swap decisions made by this shard's ReAdvisor
        since the last collection — the control-channel counterpart of
        :meth:`collect_exports`.  Each entry carries the absolute virtual
        apply time; with ``window_s <= apply_delay_s`` the receiving
        shard's clock is guaranteed not to have passed it yet."""
        rv = getattr(self.executor, "readvisor", None)
        if rv is None:
            return []
        out = []
        for dec in rv.decisions[self._ctl_wm:]:
            out.append({"stage": dec.stage, "from_tier": dec.from_tier,
                        "to_tier": dec.to_tier,
                        "t_decided": dec.t_decided,
                        "t_apply": dec.t_decided + rv.apply_delay_s})
        self._ctl_wm = len(rv.decisions)
        return out

    def apply_controls(self, items: Sequence[dict]) -> None:
        """Schedule remote swap commands received at a window barrier:
        at ``t_apply`` the named stage re-binds to this shard's pilot for
        the target tier and its local consumer fleet (if any) migrates
        epoch-wise — the same code path the deciding shard runs."""
        h = self.handle
        for c in items:
            pilot = self.control_pilots[c["to_tier"]]

            def _swap(c=c, pilot=pilot):
                si = h.pipe.rebind_stage(c["stage"], pilot)
                h._migrate_stage(si)

            h.sched.at(float(c["t_apply"]), _swap)

    def finish_row(self) -> dict:
        """Close the run and summarize this shard's deterministic
        columns (plus its raw latency data for exact cross-shard
        merging)."""
        res = self.handle.finish()
        m = self.metrics
        topics = self.pipe._topics
        row = {
            "shard_id": self.shard_id,
            "processed": res.n_processed,
            "duplicates": int(m.counter("pipeline.duplicates_dropped")),
            "events": self.executor.sched.executed,
            "truncated_msgs": sum(t.truncated_msgs for t in topics),
            "wan_bytes": m.counter(f"topic.{topics[0].name}.bytes_in"),
            "first_produced": m.first_stamp("produced"),
            "last_processed": m.last_stamp("processed"),
        }
        if self.streaming:
            sk = m._sketch("produced", "processed")
            row["sketch"] = sk.state() if sk is not None else None
        else:
            row["latencies"] = m.latencies("produced", "processed")
        rv = getattr(self.executor, "readvisor", None)
        if rv is not None:
            row["swaps"] = [dict(s) for s in rv.swap_log]
        if self.mgr is not None:
            self.mgr.release_all()
        return row


def merge_rows(rows: Sequence[dict], *, streaming: bool) -> dict:
    """Aggregate per-shard rows into the single-run deterministic
    columns.  Counters sum; the makespan spans min-first-produced to
    max-last-processed; latency percentiles come from the merged
    multiset (exact mode — bit-identical to an unsharded run of the
    same streams) or the merged sketch (streaming mode — bucket counts
    add exactly)."""
    processed = sum(r["processed"] for r in rows)
    firsts = [r["first_produced"] for r in rows
              if r["first_produced"] is not None]
    lasts = [r["last_processed"] for r in rows
             if r["last_processed"] is not None]
    first = min(firsts) if firsts else 0.0
    last = max(lasts) if lasts else first
    if streaming:
        merged: Optional[LatencySketch] = None
        for r in rows:
            st = r.get("sketch")
            if st is None:
                continue
            sk = LatencySketch.from_state(st)
            if merged is None:
                merged = sk
            else:
                merged.merge(sk)
        p50 = merged.percentile(0.50) if merged is not None else 0.0
        p95 = merged.percentile(0.95) if merged is not None else 0.0
    else:
        lat: List[float] = []
        for r in rows:
            lat.extend(r["latencies"])
        lat.sort()
        n = len(lat)
        # the exact-mode rank formula the single-process bench uses
        p50 = lat[n // 2] if n else 0.0
        p95 = lat[min(n - 1, int(0.95 * n))] if n else 0.0
    merged = {
        "processed": processed,
        "duplicates": sum(r["duplicates"] for r in rows),
        "events": sum(r["events"] for r in rows),
        "truncated_msgs": sum(r["truncated_msgs"] for r in rows),
        "makespan_s": max(last - first, 1e-9),
        "lat_p50_s": p50,
        "lat_p95_s": p95,
        "wan_bytes": sum(r["wan_bytes"] for r in rows),
    }
    if any("swaps" in r for r in rows):
        # applied hot-swaps, in shard-id order (only the deciding shard
        # logs them, so this is also decision order)
        merged["swaps"] = [s for r in rows for s in r.get("swaps", ())]
    return merged


# ---------------------------------------------------------------------------
# coordinator: lock-step conservative windows, inline or multiprocessing
# ---------------------------------------------------------------------------


def _shard_worker(conn, build: Callable[[dict], ShardRunner],
                  cfg: dict) -> None:
    """Worker-process loop: build the shard, then serve the barrier
    protocol — ``('put', items)`` injects boundary messages, ``('ctl',
    items)`` schedules remote swap commands, ``('adv', t)`` advances the
    window and returns ``('adv', done, cpu_s, exports, controls)``,
    ``('fin',)`` closes the run and returns its row."""
    runner = build(cfg)
    conn.send(("ready", runner.deadline))
    while True:
        msg = conn.recv()
        op = msg[0]
        if op == "put":
            runner.deliver(msg[1])
        elif op == "ctl":
            runner.apply_controls(msg[1])
        elif op == "adv":
            c0 = time.process_time()
            runner.advance(msg[1])
            cpu = time.process_time() - c0
            conn.send(("adv", runner.done, cpu, runner.collect_exports(),
                       runner.collect_controls()))
        elif op == "fin":
            conn.send(("row", runner.finish_row()))
            conn.close()
            return
        else:                                  # pragma: no cover
            raise ValueError(f"unknown shard command {op!r}")


class ShardCoordinator:
    """Drive N shards in conservative time-window lock-step.

    ``builders`` is one ``(build_fn, cfg)`` per shard (shard ids are the
    list indices — export hop destinations refer to them).  ``window_s``
    must not exceed the partitioning's lookahead (``math.inf`` for
    fully-independent shards → a single window).  ``mode='mp'`` runs one
    OS process per shard over pipes; ``mode='inline'`` runs them
    sequentially in-process (tests introspect the runners afterwards via
    ``self.runners``)."""

    def __init__(self, builders: Sequence[Tuple[Callable, dict]], *,
                 window_s: float, mode: str = "mp"):
        if window_s <= 0.0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if mode not in ("mp", "inline"):
            raise ValueError(f"mode must be 'mp' or 'inline', got {mode!r}")
        self.builders = list(builders)
        self.window_s = window_s
        self.mode = mode
        self.runners: List[ShardRunner] = []   # inline mode only
        self.windows = 0
        self.cpu_s_total = 0.0
        # critical path across the barrier schedule: per window the
        # slowest shard gates the barrier, so the parallel-run CPU bound
        # is the sum over windows of the per-window max — what the wall
        # clock would be with one core per shard
        self.cpu_critical_s = 0.0

    # -- shared window loop ------------------------------------------------

    def _window_loop(self, n: int, horizon: float, deliver, advance_all,
                     control=None):
        pending: Dict[int, List[Tuple]] = {i: [] for i in range(n)}
        # re-advisory swap commands awaiting broadcast: (dest_sid, dict)
        pending_ctl: Dict[int, List[dict]] = {i: [] for i in range(n)}
        t = 0.0
        # +4: slack for barrier rounds that only flush boundary queues
        max_windows = (int(math.ceil(horizon / self.window_s)) + 4
                       if math.isfinite(self.window_s) else 8)
        while self.windows < max_windows:
            for sid, items in pending.items():
                if items:
                    deliver(sid, items)
                    pending[sid] = []
            if control is not None:
                for sid, items in pending_ctl.items():
                    if items:
                        control(sid, items)
                        pending_ctl[sid] = []
            t_next = min(t + self.window_s, horizon)
            done_flags, cpus, exports, controls = advance_all(t_next)
            self.windows += 1
            self.cpu_s_total += sum(cpus)
            self.cpu_critical_s += max(cpus) if cpus else 0.0
            for dest, hop, p, mid, key, raw, ready_at, produced_t in exports:
                pending[dest].append((hop, p, mid, key, raw, ready_at,
                                      produced_t))
            # controls broadcast to every *other* shard (the decider
            # already applied its own swap locally)
            if control is not None:
                for src, ctl in controls:
                    for dest in range(n):
                        if dest != src:
                            pending_ctl[dest].append(ctl)
            have_pending = any(pending.values()) or any(pending_ctl.values())
            if all(done_flags) and not have_pending:
                break
            if t_next >= horizon and not have_pending:
                break
            t = t_next

    # -- modes -------------------------------------------------------------

    def run(self) -> List[dict]:
        """Run all shards to completion; returns the per-shard rows (in
        shard-id order) for :func:`merge_rows`."""
        if self.mode == "inline":
            return self._run_inline()
        return self._run_mp()

    def _run_inline(self) -> List[dict]:
        self.runners = [build(cfg) for build, cfg in self.builders]
        horizon = max(r.deadline for r in self.runners)

        def deliver(sid, items):
            self.runners[sid].deliver(items)

        def control(sid, items):
            self.runners[sid].apply_controls(items)

        def advance_all(t_next):
            done, cpus, exports, controls = [], [], [], []
            for r in self.runners:
                c0 = time.process_time()
                r.advance(t_next)
                cpus.append(time.process_time() - c0)
                done.append(r.done)
                exports.extend(r.collect_exports())
                for ctl in r.collect_controls():
                    controls.append((r.shard_id, ctl))
            return done, cpus, exports, controls

        self._window_loop(len(self.runners), horizon, deliver, advance_all,
                          control)
        return [r.finish_row() for r in self.runners]

    def _run_mp(self) -> List[dict]:
        ctx = mp.get_context("fork")
        conns, procs = [], []
        for build, cfg in self.builders:
            parent, child = ctx.Pipe()
            proc = ctx.Process(target=_shard_worker, args=(child, build, cfg),
                               daemon=True)
            proc.start()
            child.close()
            conns.append(parent)
            procs.append(proc)
        try:
            deadlines = []
            for conn in conns:
                tag, deadline = conn.recv()
                if tag != "ready":             # pragma: no cover
                    raise RuntimeError(f"shard handshake got {tag!r}")
                deadlines.append(deadline)
            horizon = max(deadlines)

            def deliver(sid, items):
                conns[sid].send(("put", items))

            def control(sid, items):
                conns[sid].send(("ctl", items))

            def advance_all(t_next):
                for conn in conns:
                    conn.send(("adv", t_next))
                done, cpus, exports, controls = [], [], [], []
                for sid, conn in enumerate(conns):  # parallel workers
                    _, d, cpu, exp, ctl = conn.recv()
                    done.append(d)
                    cpus.append(cpu)
                    exports.extend(exp)
                    controls.extend((sid, c) for c in ctl)
                return done, cpus, exports, controls

            self._window_loop(len(conns), horizon, deliver, advance_all,
                              control)
            rows = []
            for conn in conns:
                conn.send(("fin",))
                tag, row = conn.recv()
                rows.append(row)
            return rows
        finally:
            for conn in conns:
                conn.close()
            for proc in procs:
                proc.join(timeout=60.0)
                if proc.is_alive():            # pragma: no cover
                    proc.terminate()


# ---------------------------------------------------------------------------
# partitioning 1: the scale benchmark's device-partition cut
# ---------------------------------------------------------------------------


def build_scale_shard(cfg: dict) -> ShardRunner:
    """One device-partition shard of the DES scale benchmark cell: a
    contiguous block of devices plus the matching block of consumers,
    as a complete :class:`EdgeToCloudPipeline`.

    Determinism regardless of shard count: the open-loop arrival times
    are drawn **once** from the global seed (the same
    ``arrival_process(...).times(messages, seed)`` cumsum every shard
    count sees) and each device takes its global interleave slice
    ``times[g::devices]`` — shard boundaries never touch the draw."""
    sid, k = cfg["shard_id"], cfg["shards"]
    devices, consumers = cfg["devices"], cfg["consumers"]
    lo, hi = split_blocks(devices, k)[sid]
    clo, chi = split_blocks(consumers, k)[sid]
    n_dev, n_con = hi - lo, chi - clo
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock, streaming=cfg["streaming"])
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge", n_workers=n_dev))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud", n_workers=n_con))
    payload = bytes(cfg["payload_bytes"])
    pipe = EdgeToCloudPipeline(
        pilot_cloud_processing=cloud, pilot_edge=edge,
        produce_function_handler=lambda ctx: payload,
        process_cloud_function_handler=lambda ctx, data=None: None,
        n_edge_devices=n_dev, n_partitions=n_dev,
        cloud_consumers=n_con, topic_name=f"des-scale-s{sid}",
        truncate_logs=cfg["truncate_logs"], metrics=metrics, clock=clock)
    times = arrival_process(cfg["arrival"], cfg["rate_hz"],
                            cfg.get("trace")).times(cfg["messages"],
                                                    cfg["seed"])
    plan = [times[g::devices] for g in range(lo, hi)]
    service_s = cfg["service_s"]
    ex = SimExecutor(
        clock,
        service_model=((lambda stage, ctx, data: service_s)
                       if service_s > 0.0 else None))
    handle = pipe.launch(ex, timeout_s=float(times[-1]) + 120.0,
                         collect_results=False, arrival_plan=plan)
    return ShardRunner(sid, pipe, ex, handle, metrics,
                       export_hops={}, streaming=cfg["streaming"], mgr=mgr)


def run_scale_sharded(*, arrival: str, messages: int, devices: int,
                      consumers: int, rate_hz: float, payload_bytes: int,
                      service_s: float, seed: int, shards: int,
                      streaming: bool = False, truncate_logs=None,
                      trace: Optional[str] = None,
                      mode: str = "mp") -> dict:
    """Run one scale-benchmark cell sharded ``shards`` ways; returns the
    merged row plus the parallel-run accounting columns.

    Requires ``consumers >= devices``: each partition then owns a
    dedicated consumer in *every* shard count, so per-partition
    timelines are independent and the merged deterministic columns are
    bit-identical to the single-process run.  With ``consumers <
    devices`` one consumer's service queue couples several partitions —
    that cross-partition coupling is exactly the "too chatty to shard"
    condition, so the split is refused rather than de-synchronized."""
    if consumers < devices:
        raise ValueError(
            f"sharding needs consumers >= devices ({consumers} < {devices}):"
            f" a consumer serving several partitions couples their "
            f"timelines across the shard cut (too chatty to shard)")
    if not 1 <= shards <= devices:
        raise ValueError(f"need 1 <= shards <= devices, got shards={shards}"
                         f" devices={devices}")
    cfgs = [dict(shard_id=sid, shards=shards, arrival=arrival,
                 messages=messages, devices=devices, consumers=consumers,
                 rate_hz=rate_hz, payload_bytes=payload_bytes,
                 service_s=service_s, seed=seed, streaming=streaming,
                 truncate_logs=truncate_logs, trace=trace)
            for sid in range(shards)]
    coord = ShardCoordinator([(build_scale_shard, c) for c in cfgs],
                             window_s=math.inf, mode=mode)
    t0 = time.perf_counter()
    rows = coord.run()
    wall = time.perf_counter() - t0
    merged = merge_rows(rows, streaming=streaming)
    events = merged["events"]
    if mode == "mp":
        rss_mb = (resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss
                  / 1024.0)
    else:
        rss_mb = (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                  / 1024.0)
    merged.update({
        "arrival": arrival, "messages": messages, "devices": devices,
        "consumers": consumers, "payload_bytes": payload_bytes,
        "seed": seed, "streaming_metrics": streaming,
        "shards": shards, "mode": mode,
        "windows": coord.windows,
        "wall_s": wall,
        "events_per_s": events / max(wall, 1e-9),
        "cpu_s_total": coord.cpu_s_total,
        "cpu_critical_s": coord.cpu_critical_s,
        # the parallel-run headline: events over the barrier-schedule
        # critical path — the wall rate on a host with >= 1 core per
        # shard (per window only the slowest shard gates the barrier)
        "agg_events_per_s": events / max(coord.cpu_critical_s, 1e-9),
        "rss_mb": rss_mb,
        "peak_rss_mb": rss_mb,
    })
    return merged


# ---------------------------------------------------------------------------
# partitioning 2: the tier cut (sources | WAN | consumers)
# ---------------------------------------------------------------------------


def build_tier_cut_shard(cfg: dict) -> ShardRunner:
    """One side of the edge→cloud tier cut.

    ``cfg['side'] == 'edge'``: the shard owns the source devices and the
    WAN shaper — its pipeline's consumer stage has ``n_tasks=0``, so
    produced messages (already carrying their shaped ``ready_at``) pile
    up in the hop-0 topic as boundary traffic exported to shard 1.  Its
    arrivals are seeded from ``shard_seed(seed, 0)``: a shard-local
    stream, independent of any other shard's draws.

    ``cfg['side'] == 'cloud'``: the shard owns the consumers — its
    source stage has ``n_tasks=0`` and every message arrives via
    :meth:`Topic.inject` at a window barrier.  The hop keeps a (virtual,
    never-charged) shaper object so the broker honors injected
    ``ready_at`` visibility times."""
    side = cfg["side"]
    devices, consumers = cfg["devices"], cfg["consumers"]
    payload = bytes(cfg["payload_bytes"])
    bw, rtt = cfg["bandwidth_bps"], cfg["rtt_s"]
    clock = SimClock()
    metrics = MetricsRegistry(clock=clock)
    mgr = PilotManager()
    edge = mgr.submit_pilot(ComputeResource(tier="edge",
                                            n_workers=max(devices, 1)))
    cloud = mgr.submit_pilot(ComputeResource(tier="cloud",
                                             n_workers=max(consumers, 1)))
    shaper = WanShaper(bandwidth_bps=bw, rtt_s=rtt, sleep=False)
    if side == "edge":
        pipe = ContinuumPipeline(
            stages=[StageSpec("produce", lambda ctx: payload,
                              pilot=edge, n_tasks=devices),
                    StageSpec("process_cloud", lambda ctx, data=None: None,
                              pilot=cloud, n_tasks=0)],
            n_partitions=devices, topic_name="tier-cut",
            shapers=[shaper], metrics=metrics, clock=clock,
            heartbeat_timeout_s=cfg["timeout_s"])
        times = arrival_process("poisson", cfg["rate_hz"]).times(
            cfg["messages"], shard_seed(cfg["seed"], 0))
        plan = [times[i::devices] for i in range(devices)]
        ex = SimExecutor(clock)
        handle = pipe.launch(ex, timeout_s=cfg["timeout_s"],
                             collect_results=False, arrival_plan=plan)
        export_hops = {0: 1}
    elif side == "cloud":
        pipe = ContinuumPipeline(
            stages=[StageSpec("produce", lambda ctx: payload,
                              pilot=edge, n_tasks=0),
                    StageSpec("process_cloud", lambda ctx, data=None: None,
                              pilot=cloud, n_tasks=consumers)],
            n_partitions=devices, topic_name="tier-cut-dst",
            shapers=[shaper], metrics=metrics, clock=clock,
            heartbeat_timeout_s=cfg["timeout_s"])
        ex = SimExecutor(clock)
        handle = pipe.launch(ex, n_messages=cfg["messages"],
                             timeout_s=cfg["timeout_s"],
                             collect_results=False)
        export_hops = {}
    else:
        raise ValueError(f"side must be 'edge' or 'cloud', got {side!r}")
    sid = 0 if side == "edge" else 1
    return ShardRunner(sid, pipe, ex, handle, metrics,
                       export_hops=export_hops, streaming=False, mgr=mgr)


def tier_cut_builders(cfg: dict) -> List[Tuple[Callable, dict]]:
    """The two-shard tier-cut builder list for a
    :class:`ShardCoordinator` (shard 0: sources+WAN, shard 1:
    consumers).  ``cfg`` needs messages/devices/consumers/rate_hz/
    payload_bytes/seed/bandwidth_bps/rtt_s/timeout_s."""
    return [(build_tier_cut_shard, dict(cfg, side="edge")),
            (build_tier_cut_shard, dict(cfg, side="cloud"))]


# ---------------------------------------------------------------------------
# partitioning 3: the drift tier cut (sources + WAN + ReAdvisor | consumers)
# ---------------------------------------------------------------------------


#: columns a sharded drift run must reproduce bit-identically to the
#: unsharded :func:`~repro.sim.scenarios.run_scenario` of the same
#: scenario (``events`` counts shard machinery and is excluded)
DRIFT_PARITY_COLS = ("processed", "duplicates", "makespan_s", "lat_p50_s",
                     "lat_p95_s", "wan_bytes", "swaps")


def build_drift_shard(cfg: dict) -> ShardRunner:
    """One side of the tier cut for a drift/re-advisory scenario.

    Both sides build the scenario's *full* pipeline via
    :func:`~repro.sim.scenarios.build_pipeline` — same pilots, payload,
    producer phase offsets, shapers and service model as the unsharded
    run — then zero out the stage the other shard owns (an explicit
    ``n_tasks=0``, which :meth:`stage_tasks` honors).

    ``side == 'edge'`` (shard 0) keeps the sources, the live WAN shaper,
    the scheduled drift events **and the ReAdvisor**: every produce-side
    counter the advisor reads (``msgs_in``/``wan_delay_s``/``bytes_in``)
    is stamped locally, so its decision timeline is bit-identical to the
    unsharded run's.  Its swap re-prices the local shaper; the decision
    ships to shard 1 over the control channel at the next barrier.

    ``side == 'cloud'`` (shard 1) keeps the consumers and the tier-aware
    service model; its executor gets no ReAdvisor and no drift plan —
    remote swap commands arrive via :meth:`ShardRunner.apply_controls`
    and re-bind the stage at the same virtual ``t_apply`` the deciding
    shard used (guaranteed still in this shard's future as long as
    ``window_s <= apply_delay_s``)."""
    import dataclasses

    from repro.sim.scenarios import build_pipeline

    sc, side = cfg["sc"], cfg["side"]
    pipe, ex, mgr = build_pipeline(sc)
    rv = ex.readvisor
    if side == "edge":
        pipe.stages[1] = dataclasses.replace(pipe.stages[1], n_tasks=0)
        handle = pipe.launch(ex, n_messages=sc.n_messages,
                             timeout_s=sc.t_max_s, collect_results=False)
        return ShardRunner(0, pipe, ex, handle, pipe.metrics,
                           export_hops={0: 1}, mgr=mgr)
    if side == "cloud":
        pipe.stages[0] = dataclasses.replace(pipe.stages[0], n_tasks=0)
        ex.readvisor = None     # decisions arrive via the control channel
        ex.drift_plan = ()      # the charged WAN shaper lives on shard 0
        handle = pipe.launch(ex, n_messages=sc.n_messages,
                             timeout_s=sc.t_max_s, collect_results=False)
        return ShardRunner(1, pipe, ex, handle, pipe.metrics,
                           export_hops={},
                           control_pilots=dict(rv.targets) if rv else {},
                           mgr=mgr)
    raise ValueError(f"side must be 'edge' or 'cloud', got {side!r}")


def drift_builders(sc) -> List[Tuple[Callable, dict]]:
    """The two-shard builder list for a drift/re-advisory scenario
    (shard 0: sources + WAN + ReAdvisor, shard 1: consumers)."""
    return [(build_drift_shard, {"sc": sc, "side": "edge"}),
            (build_drift_shard, {"sc": sc, "side": "cloud"})]


def _drift_window_s(sc) -> float:
    """Safe conservative window for the drift tier cut: half the minimum
    one-way link latency over every band the run can visit — the current
    WAN band, every drift target band, and the routed link to every
    re-advisory target tier.  The WanShaper charges ``rtt/2`` (plus
    serialization) per message, so any window at or below this bound
    keeps barrier delivery causal; re-advisory additionally requires
    ``window <= apply_delay_s`` so a decision shipped at the next
    barrier still lands in the receiving shard's future."""
    from repro.sim.scenarios import _resolve_drift, _wan_link

    cm = sc.cost_model.with_wan(sc.wan_band)
    rtts = [_wan_link(sc).latency_s]
    for d in _resolve_drift(sc):
        if d.kind == "band" and d.rtt_s is not None:
            rtts.append(d.rtt_s)
    if sc.readvise is not None:
        for tier in sc.readvise.targets:
            if tier != "cloud":
                rtts.append(cm.route("edge", tier).as_link().latency_s)
    window = min(r / 2.0 for r in rtts)
    if sc.readvise is not None:
        window = min(window, sc.readvise.apply_delay_s)
    return window


def run_drift_sharded(sc, *, shards: int = 2, mode: str = "inline") -> dict:
    """Run a drift/re-advisory scenario sharded across the tier cut;
    returns the :data:`DRIFT_PARITY_COLS` projection (plus shard
    accounting).  ``shards=1`` runs the plain unsharded
    :func:`~repro.sim.scenarios.run_scenario` projected onto the same
    columns — the parity baseline.

    Refused configurations (the "too chatty to shard" conditions of
    this cut): non-``cloud`` placements (the cut is the edge→cloud WAN
    hop), open-loop arrivals (the golden's closed-loop producers keep
    shard 0's timeline independent of consumer progress), failure
    injection and autoscaling (both act on consumers the edge shard
    can't see), and ``churn``/``outage`` drift kinds (they mutate the
    consumer fleet — run those unsharded)."""
    from repro.sim.scenarios import run_scenario

    if shards not in (1, 2):
        raise ValueError(f"drift sharding is the 2-way tier cut; "
                         f"got shards={shards}")
    if sc.placement != "cloud":
        raise ValueError(f"drift sharding cuts the edge→cloud WAN hop; "
                         f"placement {sc.placement!r} is not shardable")
    if sc.arrival is not None:
        raise ValueError("drift sharding needs closed-loop producers; "
                         "open-loop arrival scenarios run unsharded")
    if sc.failures or sc.autoscale is not None or sc.autoscale_stages:
        raise ValueError("failure injection / autoscaling act on the "
                         "consumer fleet across the cut — run unsharded")
    for d in sc.drift:
        if d.kind != "band":
            raise ValueError(f"drift kind {d.kind!r} mutates the consumer "
                             f"fleet across the cut — run unsharded")
    if shards == 1:
        res = run_scenario(sc)
        return {
            "processed": res.n_processed,
            "duplicates": res.n_duplicates,
            "makespan_s": res.makespan_s,
            "lat_p50_s": res.latency_p50_s,
            "lat_p95_s": res.latency_p95_s,
            "wan_bytes": res.wan_bytes,
            "swaps": [dict(s) for s in res.swaps],
            "shards": 1, "mode": "unsharded", "windows": 1,
        }
    coord = ShardCoordinator(drift_builders(sc),
                             window_s=_drift_window_s(sc), mode=mode)
    rows = coord.run()
    merged = merge_rows(rows, streaming=False)
    out = {k: merged[k] for k in DRIFT_PARITY_COLS if k != "swaps"}
    out["swaps"] = merged.get("swaps", [])
    out.update({"shards": 2, "mode": mode, "windows": coord.windows})
    return out
