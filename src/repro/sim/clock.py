"""The injected-clock API: one coherent notion of time for the whole stack.

Every core component (broker, task runtime, pilot liveness, autoscaler,
metrics, pipeline) reads time through a :class:`Clock` object instead of
calling ``time.monotonic()`` / ``time.sleep()`` directly.  Three
implementations:

* :class:`SystemClock` — wall clock; the default everywhere.  Behaviour is
  exactly the pre-refactor code.
* :class:`SimClock` (``auto_advance=True``) — *fast-forward* virtual time:
  ``sleep``/``wait`` advance the clock instantly instead of blocking.  A
  single-threaded discrete-event run (see :mod:`repro.sim.scheduler`)
  replays hours of simulated pipeline in milliseconds of wall time with
  bit-reproducible timestamps.
* :class:`SimClock` (``auto_advance=False``) — *manually driven* virtual
  time for multi-threaded tests: ``sleep`` blocks the calling thread until
  the test calls :meth:`SimClock.advance`.  Timing-dependent behaviour
  (heartbeat loss, straggler speculation, autoscaler cooldowns) is then
  triggered by advancing virtual time, not by real waiting.

Back-compat: the seed's half-finished hooks passed a bare ``now()``
callable as ``clock=``.  :func:`as_clock` coerces those (and ``None``)
into Clock objects so the old call sites keep working.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Union


class _NullLock:
    """A no-op drop-in for :class:`threading.Lock` used on single-owner
    paths: when an auto-advance :class:`SimClock` DES run owns every
    component outright, the components' internal locks are pure overhead
    (the profile shows them as the top non-algorithmic cost of the event
    loop).  The executor swaps this in for the run and restores the real
    locks afterwards, so threaded use is untouched."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        return True

    def release(self) -> None:
        pass

    def locked(self) -> bool:
        return False


NULL_LOCK = _NullLock()


class Clock:
    """Interface. ``virtual`` tells components whether time is free to
    advance (e.g. the broker honors WAN visibility times only when the
    clock can jump there at zero wall cost)."""

    virtual: bool = False

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        raise NotImplementedError

    def wait(self, cond: threading.Condition, timeout: float) -> bool:
        """Clock-aware ``Condition.wait`` (``cond`` must be held).  Returns
        True if (possibly) notified, False on timeout."""
        raise NotImplementedError


class SystemClock(Clock):
    """Wall clock — delegates to :mod:`time`."""

    virtual = False

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)

    def wait(self, cond: threading.Condition, timeout: float) -> bool:
        return cond.wait(timeout=max(timeout, 0.0))


SYSTEM_CLOCK = SystemClock()


class _CallableClock(SystemClock):
    """A bare ``now()`` callable (the seed's ``clock=`` kwarg) promoted to
    the Clock interface; sleeps stay real."""

    def __init__(self, fn: Callable[[], float]):
        self._fn = fn

    def now(self) -> float:
        return float(self._fn())


def as_clock(clock: Union[Clock, Callable[[], float], None]) -> Clock:
    """Coerce ``None`` / a Clock / a bare ``now()`` callable to a Clock.
    Duck-typed objects must implement the *full* interface (``now``,
    ``sleep``, ``wait``, ``virtual``); a partial object exposing only
    ``now`` is wrapped like a bare callable (real sleeps/waits)."""
    if clock is None:
        return SYSTEM_CLOCK
    if isinstance(clock, Clock) or all(
            hasattr(clock, a) for a in ("now", "sleep", "wait", "virtual")):
        return clock  # type: ignore[return-value]
    if hasattr(clock, "now"):
        return _CallableClock(clock.now)
    if callable(clock):
        return _CallableClock(clock)
    raise TypeError(f"cannot interpret {clock!r} as a clock")


class SimClock(Clock):
    """Virtual monotonic clock.

    ``auto_advance=True`` (default): ``sleep(dt)`` jumps time forward by
    ``dt`` and returns immediately; ``wait(cond, t)`` jumps by ``t`` and
    reports a timeout.  Single-threaded event-driven code runs at memory
    speed while all timestamps remain exact.

    ``auto_advance=False``: ``sleep(dt)`` blocks (on a real condition)
    until another thread moves time past the deadline via :meth:`advance` /
    :meth:`advance_to`, or the clock is :meth:`close`-d.  ``wait`` performs
    a short *real* wait (capped at ``max_real_wait``) so polling loops stay
    responsive while the test drives time.

    Thread-safe; ``advance`` wakes all virtual sleepers whose deadline has
    passed.
    """

    virtual = True

    def __init__(self, start: float = 0.0, *, auto_advance: bool = True,
                 max_real_wait: float = 0.05):
        self._now = float(start)
        self.auto_advance = auto_advance
        self.max_real_wait = max_real_wait
        self._cond = threading.Condition()
        self._closed = False
        self._n_sleepers = 0

    # -- reading / driving time ------------------------------------------

    def now(self) -> float:
        # auto-advance clocks are single-threaded by construction (the DES
        # owns them; ThreadedExecutor rejects them), so the hot read skips
        # the lock — a float attribute read is atomic under the GIL anyway
        if self.auto_advance:
            return self._now
        with self._cond:
            return self._now

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds; wakes sleepers."""
        if dt < 0:
            raise ValueError(f"cannot advance by {dt}")
        if self.auto_advance:
            self._now += float(dt)          # no sleepers to wake
            return self._now
        with self._cond:
            self._now += float(dt)
            self._cond.notify_all()
            return self._now

    def advance_to(self, t: float) -> float:
        """Move time to ``t`` (no-op if ``t`` is in the past)."""
        if self.auto_advance:
            if t > self._now:
                self._now = float(t)
            return self._now
        with self._cond:
            if t > self._now:
                self._now = float(t)
                self._cond.notify_all()
            return self._now

    def close(self) -> None:
        """Release every blocked sleeper (used at test teardown so hung
        virtual tasks don't outlive the test)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def sleepers(self) -> int:
        """Number of threads currently blocked in :meth:`sleep` (manual
        mode) — lets a time-driving test wait for quiescence."""
        with self._cond:
            return self._n_sleepers

    # -- Clock interface --------------------------------------------------

    def sleep(self, dt: float) -> None:
        if dt <= 0:
            return
        if self.auto_advance:
            self._now += dt
            return
        with self._cond:
            deadline = self._now + dt
            self._n_sleepers += 1
            try:
                while self._now < deadline and not self._closed:
                    self._cond.wait(timeout=self.max_real_wait)
            finally:
                self._n_sleepers -= 1

    def wait(self, cond: threading.Condition, timeout: float) -> bool:
        if self.auto_advance:
            # Nothing else can run while this (virtual) thread waits, so
            # the only way forward is to advance time and report a timeout;
            # the caller's loop re-checks its predicate at the new time.
            self.advance(max(timeout, 0.0))
            return False
        return cond.wait(timeout=min(max(timeout, 0.0), self.max_real_wait))
