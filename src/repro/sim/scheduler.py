"""Discrete-event scheduler over a :class:`~repro.sim.clock.SimClock`.

A classic DES loop: a heap of ``(time, seq, event)`` entries; ``run`` pops
the earliest event, jumps the virtual clock to its timestamp, and executes
it.  ``seq`` (insertion order) breaks time ties, so a run is a pure
function of the scenario + seed — the bit-reproducibility the emulator is
built on.

The hot path is engineered for million-event runs:

* heap entries are plain ``(t, seq, event)`` tuples, so every sift
  comparison happens in C instead of a Python ``__lt__``;
* cancelled events are counted (``__len__`` is O(1), not a heap scan) and
  *compacted* out of the heap once they outnumber the live events — a
  long run with heavy cancellation traffic (actor wakeup rewrites, poll
  timeouts raced by appends) keeps its heap proportional to the live
  event count instead of accumulating garbage for the whole run;
* an actor reuses its step :class:`_Event` slot across wakeups (one
  pre-bound callback per actor, no per-wakeup lambda closure or event
  allocation).

Events are plain callbacks: handlers schedule follow-up events, which keeps
the whole machine single-threaded and deterministic while reusing the
*real* broker / metrics / placement objects under virtual time.

On top of the callback loop sits a cooperative-actor layer
(:class:`Actor`, :meth:`EventScheduler.spawn`): a Python generator is
driven as a DES process. Each ``yield`` suspends the actor —

* ``yield <seconds>`` resumes it that much virtual time later,
* ``yield PARK`` parks it until an external ``resume``/``throw``,
* any other yielded value is handed to the spawner's ``interpret``
  callback (the execution strategy's effect vocabulary — e.g. the
  pipeline executors' ``Poll``/``Service`` effects).

This is how the *genuine* ``EdgeToCloudPipeline`` task loops run inside
the DES: the same generator bodies that thread executors drive with
blocking calls are spawned here as deterministic single-threaded actors.
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Any, Callable, List, Optional, Tuple

from repro.sim.clock import SimClock

# compaction trigger: dead (cancelled, still-heaped) events must exceed
# both this floor and the live event count before the heap is rebuilt —
# small runs never pay the rebuild, long cancellation-heavy runs stay
# proportional to their live set
_COMPACT_MIN = 64


class _Event:
    """Handle for one scheduled callback.  ``cancel()`` marks it dead in
    place (O(1)); the scheduler skips dead entries on pop and compacts
    them out wholesale when they pile up."""

    __slots__ = ("t", "seq", "fn", "cancelled", "_sched")

    def __init__(self, t: float, seq: int, fn: Callable[[], Any],
                 sched: "EventScheduler"):
        self.t = t
        self.seq = seq
        self.fn = fn
        self.cancelled = False
        self._sched = sched

    def cancel(self) -> None:
        if not self.cancelled:
            self.cancelled = True
            self._sched._on_cancel()


class EventScheduler:
    """Deterministic event loop bound to a virtual clock."""

    def __init__(self, clock: Optional[SimClock] = None):
        self.clock = clock if clock is not None else SimClock()
        if not self.clock.auto_advance:
            raise ValueError("EventScheduler needs an auto-advance SimClock")
        self._heap: List[Tuple[float, int, _Event]] = []
        self._seq = itertools.count()
        self._live = 0          # scheduled, not cancelled, not yet run
        self._dead = 0          # cancelled but still occupying a heap slot
        self.executed = 0
        self.compactions = 0    # heap rebuilds (observability / tests)

    # -- scheduling --------------------------------------------------------

    def at(self, t: float, fn: Callable[[], Any]) -> _Event:
        """Schedule ``fn`` at absolute virtual time ``t`` (clamped to now:
        the clock never runs backwards)."""
        t = max(t, self.clock.now())
        ev = _Event(t, next(self._seq), fn, self)
        heapq.heappush(self._heap, (t, ev.seq, ev))
        self._live += 1
        return ev

    def after(self, dt: float, fn: Callable[[], Any]) -> _Event:
        """Schedule ``fn`` ``dt`` seconds of virtual time from now."""
        return self.at(self.clock.now() + max(dt, 0.0), fn)

    def reschedule(self, ev: _Event, t: float) -> _Event:
        """Re-arm a *fired or cancelled-and-compacted* event handle at
        ``t`` with a fresh insertion seq (slot reuse: the actor layer
        recycles its step event instead of allocating one per wakeup).
        The handle must not currently sit in the heap."""
        t = max(t, self.clock.now())
        ev.t = t
        ev.seq = next(self._seq)
        ev.cancelled = False
        heapq.heappush(self._heap, (t, ev.seq, ev))
        self._live += 1
        return ev

    def __len__(self) -> int:
        return self._live

    # -- cancellation bookkeeping -----------------------------------------

    def _on_cancel(self) -> None:
        self._live -= 1
        self._dead += 1
        if self._dead > _COMPACT_MIN and self._dead > self._live:
            self._compact()

    def _compact(self) -> None:
        """Rebuild the heap without its cancelled entries.  (t, seq) keys
        are preserved, so execution order is unchanged.  In place (slice
        assignment): ``run`` holds a local reference to the heap list, so
        the list object's identity must survive compaction."""
        self._heap[:] = [e for e in self._heap if not e[2].cancelled]
        heapq.heapify(self._heap)
        self._dead = 0
        self.compactions += 1

    @property
    def next_time(self) -> Optional[float]:
        heap = self._heap
        while heap and heap[0][2].cancelled:
            heapq.heappop(heap)
            self._dead -= 1
        return heap[0][0] if heap else None

    # -- running -----------------------------------------------------------

    def run(self, until: float = math.inf,
            max_events: Optional[int] = None,
            stop: Optional[Callable[[], bool]] = None) -> int:
        """Execute events in (time, insertion) order until the queue
        drains, virtual time would pass ``until``, ``max_events`` (a
        runaway-scenario backstop) fire, or ``stop()`` returns True
        (checked before each event).  Returns events executed.

        When the run ends because the queue drained or every remaining
        event lies beyond ``until``, the clock is advanced to ``until``
        (for finite ``until``): the caller asked to simulate *through*
        that instant, so ``clock.now()`` reflects it even if no event
        happened to land there.  ``max_events``/``stop`` exits leave the
        clock at the last executed event."""
        # hot loop: every per-event attribute chain hoisted into locals
        # (heap, clock.advance_to, heapq.heappop) and the executed counter
        # accumulated locally, flushed once — at millions of events these
        # lookups are a measurable slice of the profile
        n = 0
        heap = self._heap
        advance_to = self.clock.advance_to
        pop = heapq.heappop
        exhausted = False
        try:
            while heap:
                if stop is not None and stop():
                    break
                entry = heap[0]
                ev = entry[2]
                if ev.cancelled:
                    pop(heap)
                    self._dead -= 1
                    continue
                t = entry[0]
                if t > until:
                    exhausted = True
                    break
                pop(heap)
                self._live -= 1
                # mark fired before fn() runs: a later cancel() on this
                # handle must be a no-op (not a counter decrement), and
                # fn() itself may reschedule() the handle, which clears
                # the flag for the fresh heap entry
                ev.cancelled = True
                advance_to(t)
                ev.fn()
                n += 1
                if max_events is not None and n >= max_events:
                    break
            else:
                exhausted = True
        finally:
            self.executed += n
        if exhausted and until != math.inf:
            # drained (or next event beyond the horizon): time still
            # passed up to `until` — composed scenarios read clock.now()
            # after run(until=...) and must not see a stale timestamp
            advance_to(until)
        return n

    def step(self) -> bool:
        """Execute exactly the next pending event. Returns False if none."""
        return self.run(max_events=1) == 1

    # -- actors ------------------------------------------------------------

    def spawn(self, gen, *, name: str = "actor",
              at: Optional[float] = None,
              interpret: Optional[Callable[["Actor", Any], None]] = None,
              on_exit: Optional[Callable[["Actor", Optional[BaseException],
                                          Any], None]] = None) -> "Actor":
        """Drive generator ``gen`` as a cooperative DES actor, starting at
        virtual time ``at`` (default: now)."""
        actor = Actor(self, gen, name=name, interpret=interpret,
                      on_exit=on_exit)
        actor._schedule_step(self.clock.now() if at is None else at)
        return actor


# sentinel: an actor yielding PARK (or None) suspends until an external
# resume()/throw()
PARK = object()


class ActorKilled(Exception):
    """Injected termination (crash/rebalance injection mid-run)."""


class Actor:
    """A generator driven by the scheduler as a DES process.

    The generator communicates by yielding: a number (sleep that many
    virtual seconds), :data:`PARK`/``None`` (suspend until ``resume``), or
    an arbitrary effect object handed to ``interpret`` (which must
    eventually ``resume``/``throw``/``kill`` the actor). ``on_exit`` fires
    exactly once with ``(actor, exception_or_None, return_value)``.

    Hot-path note: an actor schedules every step through one pre-bound
    callback and recycles its fired step event (``reschedule``) — zero
    per-wakeup closure/event allocation.
    """

    __slots__ = ("sched", "gen", "name", "interpret", "on_exit", "alive",
                 "parked", "_pending", "_spare", "_payload", "_exc",
                 "_step_cb")

    def __init__(self, sched: EventScheduler, gen, *, name: str = "actor",
                 interpret=None, on_exit=None):
        self.sched = sched
        self.gen = gen
        self.name = name
        self.interpret = interpret
        self.on_exit = on_exit
        self.alive = True
        self.parked = False
        self._pending: Optional[_Event] = None
        self._spare: Optional[_Event] = None    # fired event, reusable
        self._payload: Any = None
        self._exc: Optional[BaseException] = None
        self._step_cb = self._on_event          # bound once, reused

    # -- external control --------------------------------------------------

    def resume(self, payload: Any = None, delay: float = 0.0) -> None:
        """Wake a suspended actor with ``payload`` after ``delay`` virtual
        seconds.  Only a *parked* actor (or one idling with no pending
        wakeup — e.g. suspended on an interpreted effect) can be resumed:
        an actor mid-``yield <seconds>`` keeps its timed wakeup — a resume
        racing a timed sleep must not silently rewrite the wakeup time
        (use :meth:`throw`/:meth:`kill` to interrupt a sleep)."""
        if not self.alive:
            return
        if self._pending is not None and not self.parked:
            return
        self.parked = False
        self._schedule_step(self.sched.clock.now() + max(delay, 0.0),
                            payload=payload)

    def throw(self, exc: BaseException) -> None:
        """Deliver ``exc`` into the generator at its suspension point."""
        if not self.alive:
            return
        self.parked = False
        self._schedule_step(self.sched.clock.now(), exc=exc)

    def kill(self, exc: Optional[BaseException] = None) -> None:
        """Crash injection: raise :class:`ActorKilled` inside the actor."""
        self.throw(exc if exc is not None else ActorKilled(self.name))

    def drop(self) -> None:
        """Silent failure: stop driving the actor *without* running any
        cleanup or ``on_exit`` — the process just goes dark (the way a lost
        node does). Failure detection (heartbeat monitors) must notice."""
        self.alive = False
        self.parked = False
        self._cancel_pending()

    # -- machinery ---------------------------------------------------------

    def _cancel_pending(self) -> None:
        if self._pending is not None:
            self._pending.cancel()
            self._pending = None

    def _schedule_step(self, t: float, payload: Any = None,
                       exc: Optional[BaseException] = None) -> None:
        if not self.alive:
            return
        self._cancel_pending()
        self._payload = payload
        self._exc = exc
        spare = self._spare
        if spare is not None:
            self._spare = None
            spare.fn = self._step_cb
            self._pending = self.sched.reschedule(spare, t)
        else:
            self._pending = self.sched.at(t, self._step_cb)

    def _on_event(self) -> None:
        """The step event fired: recycle its slot and drive the
        generator one step."""
        ev = self._pending
        self._pending = None
        if ev is not None:
            self._spare = ev        # out of the heap — safe to reuse
        payload, exc = self._payload, self._exc
        self._payload = self._exc = None
        if not self.alive:
            return
        try:
            if exc is not None:
                eff = self.gen.throw(exc)
            else:
                eff = self.gen.send(payload)
        except StopIteration as s:
            self._finish(None, getattr(s, "value", None))
            return
        except BaseException as e:  # noqa: BLE001 — routed to on_exit
            self._finish(e, None)
            return
        self._dispatch(eff)

    def _step(self, payload: Any, exc: Optional[BaseException]) -> None:
        """Back-compat shim (tests drive actors directly): one generator
        step with an explicit payload/exception."""
        self._payload, self._exc = payload, exc
        self._on_event()

    def _dispatch(self, eff: Any) -> None:
        if eff is PARK or eff is None:
            self.parked = True
            return
        if isinstance(eff, (int, float)):
            self._schedule_step(self.sched.clock.now() + max(float(eff), 0.0))
            return
        if self.interpret is not None:
            self.interpret(self, eff)
            return
        self._finish(TypeError(f"actor {self.name!r} yielded {eff!r} "
                               f"with no interpreter"), None)

    def _finish(self, exc: Optional[BaseException], result: Any) -> None:
        self.alive = False
        self.parked = False
        self._cancel_pending()
        self.gen.close()
        if self.on_exit is not None:
            self.on_exit(self, exc, result)
