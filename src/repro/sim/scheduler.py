"""Discrete-event scheduler over a :class:`~repro.sim.clock.SimClock`.

A classic DES loop: a heap of ``(time, seq, fn)`` events; ``run`` pops the
earliest event, jumps the virtual clock to its timestamp, and executes it.
``seq`` (insertion order) breaks time ties, so a run is a pure function of
the scenario + seed — the bit-reproducibility the emulator is built on.

Events are plain callbacks (not coroutines): handlers schedule follow-up
events, which keeps the whole machine single-threaded and deterministic
while reusing the *real* broker / metrics / placement objects under
virtual time.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from repro.sim.clock import SimClock


@dataclass(order=True)
class _Event:
    t: float
    seq: int
    fn: Callable[[], Any] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)

    def cancel(self) -> None:
        self.cancelled = True


class EventScheduler:
    """Deterministic event loop bound to a virtual clock."""

    def __init__(self, clock: Optional[SimClock] = None):
        self.clock = clock if clock is not None else SimClock()
        if not self.clock.auto_advance:
            raise ValueError("EventScheduler needs an auto-advance SimClock")
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self.executed = 0

    # -- scheduling --------------------------------------------------------

    def at(self, t: float, fn: Callable[[], Any]) -> _Event:
        """Schedule ``fn`` at absolute virtual time ``t`` (clamped to now:
        the clock never runs backwards)."""
        ev = _Event(max(t, self.clock.now()), next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, dt: float, fn: Callable[[], Any]) -> _Event:
        """Schedule ``fn`` ``dt`` seconds of virtual time from now."""
        return self.at(self.clock.now() + max(dt, 0.0), fn)

    def __len__(self) -> int:
        return sum(1 for ev in self._heap if not ev.cancelled)

    @property
    def next_time(self) -> Optional[float]:
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].t if self._heap else None

    # -- running -----------------------------------------------------------

    def run(self, until: float = math.inf,
            max_events: Optional[int] = None) -> int:
        """Execute events in (time, insertion) order until the queue
        drains, virtual time would pass ``until``, or ``max_events``
        (a runaway-scenario backstop) fire.  Returns events executed."""
        n = 0
        while self._heap:
            ev = self._heap[0]
            if ev.cancelled:
                heapq.heappop(self._heap)
                continue
            if ev.t > until:
                break
            heapq.heappop(self._heap)
            self.clock.advance_to(ev.t)
            ev.fn()
            n += 1
            self.executed += 1
            if max_events is not None and n >= max_events:
                break
        return n

    def step(self) -> bool:
        """Execute exactly the next pending event. Returns False if none."""
        return self.run(max_events=1) == 1
