"""Discrete-event scheduler over a :class:`~repro.sim.clock.SimClock`.

A classic DES loop: a heap of ``(time, seq, fn)`` events; ``run`` pops the
earliest event, jumps the virtual clock to its timestamp, and executes it.
``seq`` (insertion order) breaks time ties, so a run is a pure function of
the scenario + seed — the bit-reproducibility the emulator is built on.

Events are plain callbacks: handlers schedule follow-up events, which keeps
the whole machine single-threaded and deterministic while reusing the
*real* broker / metrics / placement objects under virtual time.

On top of the callback loop sits a cooperative-actor layer
(:class:`Actor`, :meth:`EventScheduler.spawn`): a Python generator is
driven as a DES process. Each ``yield`` suspends the actor —

* ``yield <seconds>`` resumes it that much virtual time later,
* ``yield PARK`` parks it until an external ``resume``/``throw``,
* any other yielded value is handed to the spawner's ``interpret``
  callback (the execution strategy's effect vocabulary — e.g. the
  pipeline executors' ``Poll``/``Service`` effects).

This is how the *genuine* ``EdgeToCloudPipeline`` task loops run inside
the DES: the same generator bodies that thread executors drive with
blocking calls are spawned here as deterministic single-threaded actors.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from repro.sim.clock import SimClock


@dataclass(order=True)
class _Event:
    t: float
    seq: int
    fn: Callable[[], Any] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)

    def cancel(self) -> None:
        self.cancelled = True


class EventScheduler:
    """Deterministic event loop bound to a virtual clock."""

    def __init__(self, clock: Optional[SimClock] = None):
        self.clock = clock if clock is not None else SimClock()
        if not self.clock.auto_advance:
            raise ValueError("EventScheduler needs an auto-advance SimClock")
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self.executed = 0

    # -- scheduling --------------------------------------------------------

    def at(self, t: float, fn: Callable[[], Any]) -> _Event:
        """Schedule ``fn`` at absolute virtual time ``t`` (clamped to now:
        the clock never runs backwards)."""
        ev = _Event(max(t, self.clock.now()), next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, dt: float, fn: Callable[[], Any]) -> _Event:
        """Schedule ``fn`` ``dt`` seconds of virtual time from now."""
        return self.at(self.clock.now() + max(dt, 0.0), fn)

    def __len__(self) -> int:
        return sum(1 for ev in self._heap if not ev.cancelled)

    @property
    def next_time(self) -> Optional[float]:
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].t if self._heap else None

    # -- running -----------------------------------------------------------

    def run(self, until: float = math.inf,
            max_events: Optional[int] = None) -> int:
        """Execute events in (time, insertion) order until the queue
        drains, virtual time would pass ``until``, or ``max_events``
        (a runaway-scenario backstop) fire.  Returns events executed."""
        n = 0
        while self._heap:
            ev = self._heap[0]
            if ev.cancelled:
                heapq.heappop(self._heap)
                continue
            if ev.t > until:
                break
            heapq.heappop(self._heap)
            self.clock.advance_to(ev.t)
            ev.fn()
            n += 1
            self.executed += 1
            if max_events is not None and n >= max_events:
                break
        return n

    def step(self) -> bool:
        """Execute exactly the next pending event. Returns False if none."""
        return self.run(max_events=1) == 1

    # -- actors ------------------------------------------------------------

    def spawn(self, gen, *, name: str = "actor",
              at: Optional[float] = None,
              interpret: Optional[Callable[["Actor", Any], None]] = None,
              on_exit: Optional[Callable[["Actor", Optional[BaseException],
                                          Any], None]] = None) -> "Actor":
        """Drive generator ``gen`` as a cooperative DES actor, starting at
        virtual time ``at`` (default: now)."""
        actor = Actor(self, gen, name=name, interpret=interpret,
                      on_exit=on_exit)
        actor._schedule_step(self.clock.now() if at is None else at)
        return actor


# sentinel: an actor yielding PARK (or None) suspends until an external
# resume()/throw()
PARK = object()


class ActorKilled(Exception):
    """Injected termination (crash/rebalance injection mid-run)."""


class Actor:
    """A generator driven by the scheduler as a DES process.

    The generator communicates by yielding: a number (sleep that many
    virtual seconds), :data:`PARK`/``None`` (suspend until ``resume``), or
    an arbitrary effect object handed to ``interpret`` (which must
    eventually ``resume``/``throw``/``kill`` the actor). ``on_exit`` fires
    exactly once with ``(actor, exception_or_None, return_value)``.
    """

    def __init__(self, sched: EventScheduler, gen, *, name: str = "actor",
                 interpret=None, on_exit=None):
        self.sched = sched
        self.gen = gen
        self.name = name
        self.interpret = interpret
        self.on_exit = on_exit
        self.alive = True
        self.parked = False
        self._pending: Optional[_Event] = None

    # -- external control --------------------------------------------------

    def resume(self, payload: Any = None, delay: float = 0.0) -> None:
        """Wake the actor with ``payload`` after ``delay`` virtual seconds
        (cancels any pending wakeup)."""
        if not self.alive:
            return
        self.parked = False
        self._schedule_step(self.sched.clock.now() + max(delay, 0.0),
                            payload=payload)

    def throw(self, exc: BaseException) -> None:
        """Deliver ``exc`` into the generator at its suspension point."""
        if not self.alive:
            return
        self.parked = False
        self._schedule_step(self.sched.clock.now(), exc=exc)

    def kill(self, exc: Optional[BaseException] = None) -> None:
        """Crash injection: raise :class:`ActorKilled` inside the actor."""
        self.throw(exc if exc is not None else ActorKilled(self.name))

    def drop(self) -> None:
        """Silent failure: stop driving the actor *without* running any
        cleanup or ``on_exit`` — the process just goes dark (the way a lost
        node does). Failure detection (heartbeat monitors) must notice."""
        self.alive = False
        self.parked = False
        self._cancel_pending()

    # -- machinery ---------------------------------------------------------

    def _cancel_pending(self) -> None:
        if self._pending is not None:
            self._pending.cancel()
            self._pending = None

    def _schedule_step(self, t: float, payload: Any = None,
                       exc: Optional[BaseException] = None) -> None:
        if not self.alive:
            return
        self._cancel_pending()
        self._pending = self.sched.at(
            t, lambda: self._step(payload, exc))

    def _step(self, payload: Any, exc: Optional[BaseException]) -> None:
        self._pending = None
        if not self.alive:
            return
        try:
            if exc is not None:
                eff = self.gen.throw(exc)
            else:
                eff = self.gen.send(payload)
        except StopIteration as s:
            self._finish(None, getattr(s, "value", None))
            return
        except BaseException as e:  # noqa: BLE001 — routed to on_exit
            self._finish(e, None)
            return
        self._dispatch(eff)

    def _dispatch(self, eff: Any) -> None:
        if eff is PARK or eff is None:
            self.parked = True
            return
        if isinstance(eff, (int, float)):
            self._schedule_step(self.sched.clock.now() + max(float(eff), 0.0))
            return
        if self.interpret is not None:
            self.interpret(self, eff)
            return
        self._finish(TypeError(f"actor {self.name!r} yielded {eff!r} "
                               f"with no interpreter"), None)

    def _finish(self, exc: Optional[BaseException], result: Any) -> None:
        self.alive = False
        self.parked = False
        self._cancel_pending()
        self.gen.close()
        if self.on_exit is not None:
            self.on_exit(self, exc, result)
