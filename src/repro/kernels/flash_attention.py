"""Pallas TPU flash-attention kernel (GQA-aware, causal, sliding-window).

TPU-native design (not a CUDA port — see DESIGN.md §2):

* grid = (batch, q_heads, q_blocks, k_blocks); the innermost k-block axis is
  sequential ("arbitrary"), so VMEM scratch (m/l/acc) carries the online-
  softmax state across k-blocks — the TPU analogue of a CUDA thread-block
  loop, with the MXU doing the (block_q × d) @ (d × block_k) score matmul
  and the (block_q × block_k) @ (block_k × d) value matmul.
* GQA happens in the BlockSpec index_map: the kv block for q-head ``h`` is
  head ``h // (H // Hkv)`` — no repeated kv materialization in HBM.
* block_q = block_k = 128 keeps matmul dims MXU-aligned (128×128 systolic
  array) and the working set (q,k,v,acc ≈ 4·128·d·4B) well under VMEM.
* masks (causal / sliding window / k-padding) are f32 ``-inf`` adds built
  from 2-D ``broadcasted_iota`` (TPU has no 1-D iota).

Out-of-window k-blocks are masked, not skipped; the §Perf causal-block
scheduling note quantifies the waste (≤2× for causal) and the follow-up.

Validated in interpret mode against kernels/ref.py::flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, nk: int,
                  causal: bool, window, k_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < k_len                                   # k padding
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(s == NEG_INF, 0.0, p)
    corr = jnp.exp(jnp.where(m_prev == NEG_INF, 0.0, m_prev) - m_safe)
    corr = jnp.where(m_prev == NEG_INF, 0.0, corr)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q (B,Sq,H,D); k/v (B,Sk,Hkv,D) -> (B,Sq,H,D).

    ``interpret=True`` (default here) runs the kernel body on CPU for
    validation; on TPU pass ``interpret=False``.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv

    # (B,H,S,D) layout for clean blocking
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, block_q)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, block_k)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, block_k)
    sq_p, sk_p = qt.shape[2], kt.shape[2]
    nq, nk = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5), block_q=block_q,
        block_k=block_k, nk=nk, causal=causal, window=window, k_len=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, rep=rep:
                         (ib, ih // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, rep=rep:
                         (ib, ih // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :sq, :].transpose(0, 2, 1, 3)
