"""Pallas TPU k-means assignment kernel — the paper's k-means hot loop.

The paper streams (N × 32)-point messages through a 25-centroid k-means
(§III.2); assignment (distance + argmin) dominates its FLOPs. TPU-native
formulation: ‖x−c‖² = ‖x‖² − 2·x·cᵀ + ‖c‖², so the inner loop is a single
(block_n × F) @ (F × K) MXU matmul instead of a gather/scan — the MXU does
the distance expansion, the VPU does the row-argmin.

Tiling: points are tiled over N (block_n rows in VMEM); the centroid matrix
(K × F) is tiny (25×32 ≈ 3 KB padded to 128×128 lanes) and replicated into
VMEM for every block. F and K are zero/+inf-padded to the 128-lane width in
``ops.py`` — padded centroids get ‖c‖² = +big so argmin never selects them.

Validated in interpret mode against kernels/ref.py::kmeans_assign_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _kmeans_kernel(pts_ref, cent_ref, c2_ref, ids_ref, dmin_ref):
    x = pts_ref[...].astype(jnp.float32)                  # (bn, Fp)
    c = cent_ref[...].astype(jnp.float32)                 # (Kp, Fp)
    c2 = c2_ref[...].astype(jnp.float32)                  # (1, Kp)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)            # (bn, 1)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)             # (bn, Kp)
    ids = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dmin = jnp.sqrt(jnp.min(d2, axis=1))
    ids_ref[...] = ids[:, None]
    dmin_ref[...] = dmin[:, None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(points, centroids, *, block_n: int = 256,
                  interpret: bool = True):
    """points (N,F), centroids (K,F) -> (ids (N,) int32, dmin (N,) f32)."""
    n, f = points.shape
    k = centroids.shape[0]
    fp = max(128, -(-f // 128) * 128)
    kp = max(128, -(-k // 128) * 128)
    np_ = -(-n // block_n) * block_n

    pts = jnp.zeros((np_, fp), jnp.float32).at[:n, :f].set(
        points.astype(jnp.float32))
    cent = jnp.zeros((kp, fp), jnp.float32).at[:k, :f].set(
        centroids.astype(jnp.float32))
    c2 = jnp.full((1, kp), BIG, jnp.float32).at[0, :k].set(
        jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1))

    nb = np_ // block_n
    ids, dmin = pl.pallas_call(
        _kmeans_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, fp), lambda i: (i, 0)),
            pl.BlockSpec((kp, fp), lambda i: (0, 0)),
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, 1), jnp.int32),
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pts, cent, c2)
    return ids[:n, 0], dmin[:n, 0]
