"""Pallas TPU k-means kernels — the paper's k-means hot loop, fused.

The paper streams (N × 32)-point messages through a 25-centroid k-means
(§III.2); its per-message work is one assignment (outlier scoring) plus
one mini-batch centroid update.  TPU-native formulation: ‖x−c‖² = ‖x‖² −
2·x·cᵀ + ‖c‖², so the inner loop is a single (block_n × F) @ (F × K) MXU
matmul instead of a gather/scan — the MXU does the distance expansion,
the VPU the row-argmin.

Two entry points:

* :func:`kmeans_assign` — assignment only (ids + distances), one grid
  pass over N.
* :func:`kmeans_assign_update` — the **fused** assign+update kernel: the
  same grid pass additionally builds the block's one-hot membership
  in-register and accumulates per-centroid point sums (one more
  (K × block_n) @ (block_n × F) MXU matmul) and counts into accumulator
  outputs that live in VMEM across the sequential grid steps (constant
  index_map).  This eliminates the historical second pass in
  ``ml/kmeans.py::_update`` — materializing an (N × K) one-hot and
  re-running assignment — which used to dominate the per-message flops.

Precision variants (the placement axis ``cost/calibrate.py`` prices):

* ``fp32`` — everything float32.
* ``bf16`` — points/centroids stored and fed to the MXU as bfloat16
  (half the VMEM traffic), fp32 accumulation via
  ``preferred_element_type``.
* ``int8`` — symmetric per-feature scales shared by points and
  centroids (:mod:`repro.kernels.quant`), int8 storage (quarter traffic),
  in-kernel dequantization, fp32 distance + sum accumulation.

Tiling: points are tiled over N (block_n rows in VMEM); the centroid
matrix (K × F) is tiny (25×32 ≈ 3 KB padded to 128×128 lanes) and
replicated into VMEM for every block.  F and K are zero/+big-padded to
the 128-lane width — padded centroids get ‖c‖² = +big so argmin never
selects them, and the fused kernel masks padded *rows* out of the
accumulators with a ``broadcasted_iota`` validity test.  Padding is
skipped entirely when shapes are already lane-aligned and otherwise uses
a single ``jnp.pad`` (one HLO pad op that fuses under jit — the
historical ``zeros().at[].set()`` materialized an O(N·Fp) copy chain).

``block_n`` is autotunable: :func:`autotune_block_n` sweeps a small
deterministic candidate set on a capped probe shape and caches the
winner per (shape, precision, backend) — the DES ``--profile`` workflow
applied to the kernel grid.

Validated in interpret mode against kernels/ref.py (assignment,
fused-update and int8 oracles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import quant

BIG = 1e30
PRECISIONS = ("fp32", "bf16", "int8")

# autotune: candidate block sizes (all multiples of the fp32/bf16/int8
# sublane minimums) and the per-(shape, precision, backend) winner cache
AUTOTUNE_CANDIDATES = (128, 256, 512)
_autotune_cache: dict = {}


def _pad2(a, rows: int, cols: int, value=0):
    """Pad a 2-D array up to (rows, cols) — a no-op when already aligned,
    otherwise one fusable ``jnp.pad`` (never an at[].set() copy chain)."""
    n, f = a.shape
    if n == rows and f == cols:
        return a
    return jnp.pad(a, ((0, rows - n), (0, cols - f)),
                   constant_values=value)


def _make_kernel(n: int, block_n: int, quantized: bool, fused: bool):
    """Build the grid kernel body.  ``n`` (static) is the true row count
    — the fused accumulators mask padded tail rows with it."""

    def kernel(*refs):
        if quantized:
            pts_ref, cent_ref, scale_ref, c2_ref, *out = refs
        else:
            pts_ref, cent_ref, c2_ref, *out = refs
        if fused:
            ids_ref, dmin_ref, sums_ref, counts_ref = out
        else:
            ids_ref, dmin_ref = out

        if quantized:
            s = scale_ref[...]                        # (1, Fp) f32
            xm = pts_ref[...].astype(jnp.float32) * s
            cm = cent_ref[...].astype(jnp.float32) * s
        else:
            # storage dtype (f32 or bf16) straight into the MXU; the
            # matmul accumulates f32 via preferred_element_type
            xm = pts_ref[...]
            cm = cent_ref[...]
        x32 = xm.astype(jnp.float32)
        c2 = c2_ref[...]                              # (1, Kp) f32
        x2 = jnp.sum(x32 * x32, axis=1, keepdims=True)
        xc = jax.lax.dot_general(xm, cm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        d2 = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)     # (bn, Kp)
        ids = jnp.argmin(d2, axis=1).astype(jnp.int32)
        ids_ref[...] = ids[:, None]
        dmin_ref[...] = jnp.sqrt(jnp.min(d2, axis=1))[:, None]

        if not fused:
            return
        i = pl.program_id(0)
        kp = c2.shape[1]
        # in-register one-hot membership; padded tail rows (>= n) are
        # masked out so they never reach the accumulators
        rows = i * block_n + jax.lax.broadcasted_iota(
            jnp.int32, (block_n, kp), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_n, kp), 1)
        onehot = jnp.where((rows < n) & (ids[:, None] == cols),
                           1.0, 0.0).astype(jnp.float32)
        # (Kp, bn) @ (bn, Fp) on the MXU: this block's per-centroid sums
        bs = jax.lax.dot_general(onehot, x32, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        bc = jnp.sum(onehot, axis=0, keepdims=True)   # (1, Kp)

        # the accumulator outputs have a constant index_map, so their
        # blocks stay resident in VMEM across the sequential grid steps:
        # initialize on the first block, accumulate on the rest
        @pl.when(i == 0)
        def _init():
            sums_ref[...] = bs
            counts_ref[...] = bc

        @pl.when(i > 0)
        def _acc():
            sums_ref[...] += bs
            counts_ref[...] += bc

    return kernel


def _call(points, centroids, *, block_n: int, interpret: bool,
          precision: str, fused: bool):
    if precision not in PRECISIONS:
        raise ValueError(f"precision must be one of {PRECISIONS}, "
                         f"got {precision!r}")
    n, f = points.shape
    k = centroids.shape[0]
    fp = max(128, -(-f // 128) * 128)
    kp = max(128, -(-k // 128) * 128)
    np_ = -(-n // block_n) * block_n

    ptsf = points.astype(jnp.float32)
    centf = centroids.astype(jnp.float32)
    extra = []
    if precision == "int8":
        scales = quant.symmetric_scales(ptsf, centf)
        pts = _pad2(quant.quantize(ptsf, scales), np_, fp)
        qc = quant.quantize(centf, scales)
        cent = _pad2(qc, kp, fp)
        # c2 from the *rounded* centroid values the kernel dequantizes
        centv = quant.dequantize(qc, scales)
        extra = [jnp.pad(scales, (0, fp - f))[None, :]
                 if f != fp else scales[None, :]]
    elif precision == "bf16":
        pts = _pad2(ptsf, np_, fp).astype(jnp.bfloat16)
        cent = _pad2(centf, kp, fp).astype(jnp.bfloat16)
        centv = cent.astype(jnp.float32)[:k, :f]
    else:
        pts = _pad2(ptsf, np_, fp)
        cent = _pad2(centf, kp, fp)
        centv = centf
    c2v = jnp.sum(centv * centv, axis=1)[None, :]     # (1, k)
    c2 = (jnp.pad(c2v, ((0, 0), (0, kp - k)), constant_values=BIG)
          if k != kp else c2v)

    nb = np_ // block_n
    in_specs = [pl.BlockSpec((block_n, fp), lambda i: (i, 0)),
                pl.BlockSpec((kp, fp), lambda i: (0, 0))]
    if extra:
        in_specs.append(pl.BlockSpec((1, fp), lambda i: (0, 0)))
    in_specs.append(pl.BlockSpec((1, kp), lambda i: (0, 0)))
    out_specs = [pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
                 pl.BlockSpec((block_n, 1), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((np_, 1), jnp.int32),
                 jax.ShapeDtypeStruct((np_, 1), jnp.float32)]
    if fused:
        out_specs += [pl.BlockSpec((kp, fp), lambda i: (0, 0)),
                      pl.BlockSpec((1, kp), lambda i: (0, 0))]
        out_shape += [jax.ShapeDtypeStruct((kp, fp), jnp.float32),
                      jax.ShapeDtypeStruct((1, kp), jnp.float32)]

    res = pl.pallas_call(
        _make_kernel(n, block_n, bool(extra), fused),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(pts, cent, *extra, c2)
    if fused:
        ids, dmin, sums, counts = res
        return ids[:n, 0], dmin[:n, 0], sums[:k, :f], counts[0, :k]
    ids, dmin = res
    return ids[:n, 0], dmin[:n, 0]


@functools.partial(jax.jit,
                   static_argnames=("block_n", "interpret", "precision"))
def kmeans_assign(points, centroids, *, block_n: int = 256,
                  interpret: bool = True, precision: str = "fp32"):
    """points (N,F), centroids (K,F) -> (ids (N,) int32, dmin (N,) f32)."""
    return _call(points, centroids, block_n=block_n, interpret=interpret,
                 precision=precision, fused=False)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "interpret", "precision"))
def kmeans_assign_update(points, centroids, *, block_n: int = 256,
                         interpret: bool = True, precision: str = "fp32"):
    """The fused hot path: one grid pass returns
    ``(ids (N,), dmin (N,), sums (K,F) f32, counts (K,) f32)`` — the
    assignment *and* the per-centroid membership sums/counts a mini-batch
    k-means step needs, with no second pass over the points."""
    return _call(points, centroids, block_n=block_n, interpret=interpret,
                 precision=precision, fused=True)


def autotune_block_n(n: int, f: int, k: int, *, precision: str = "fp32",
                     interpret=None, candidates=AUTOTUNE_CANDIDATES,
                     probe_n: int = 4096, repeats: int = 2, timer=None):
    """Pick the fastest ``block_n`` for a (n, f, k) shape: a small
    deterministic sweep over ``candidates``, each timed ``repeats`` times
    on a ``min(n, probe_n)``-row probe after a warmup call, cached per
    (probe shape, precision, backend).  The sweep order and candidate set
    are fixed; only the wall-clock winner is host-dependent, which is why
    benchmark reports exclude the chosen ``block_n`` from their
    deterministic columns."""
    import time as _time

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pn = min(n, probe_n)
    key = (pn, f, k, precision, bool(interpret), jax.default_backend())
    hit = _autotune_cache.get(key)
    if hit is not None:
        return hit
    timer = timer or _time.perf_counter
    # deterministic probe data (values don't matter for timing)
    pts = jnp.linspace(-5.0, 5.0, pn * f, dtype=jnp.float32
                       ).reshape(pn, f)
    cent = jnp.linspace(-5.0, 5.0, k * f, dtype=jnp.float32
                        ).reshape(k, f)
    best, best_t = None, None
    for c in candidates:
        run = functools.partial(kmeans_assign_update, pts, cent,
                                block_n=c, interpret=interpret,
                                precision=precision)
        jax.block_until_ready(run())              # warm the compile cache
        t = []
        for _ in range(max(repeats, 1)):
            t0 = timer()
            jax.block_until_ready(run())
            t.append(timer() - t0)
        tm = min(t)
        if best_t is None or tm < best_t:
            best, best_t = c, tm
    _autotune_cache[key] = best
    return best
