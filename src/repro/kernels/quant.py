"""Symmetric per-feature int8 quantization for the k-means kernels.

The quantized k-means variants (``kmeans_int8`` in ``calibration.json``)
store points *and* centroids as int8 with one shared fp32 scale per
feature — the praxis-style weight-only scheme: storage and memory traffic
shrink 4×, the kernel dequantizes in-register, and every accumulation
(distance expansion, per-centroid sums) stays fp32.  A shared
per-*feature* scale is the correct axis for k-means: points and centroids
live in the same feature space, and per-feature scales do **not** factor
through the contraction axis of an int8×int8 matmul (Σ_f s_f² q_x q_c has
no common factor), so the MXU matmul runs on dequantized values while the
int8 arrays only pay the (4×-smaller) memory bill.

Shared by the Pallas int8 kernel (dequant in VMEM), the jnp simulation
path in :mod:`repro.ml.kmeans` and the :mod:`repro.kernels.ref` oracles —
one rounding definition, so parity tests are exact.
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_MAX = 127.0


def symmetric_scales(points, centroids):
    """Per-feature symmetric scales shared by points and centroids:
    ``s_f = max(max|x_f|, max|c_f|) / 127`` (never zero, so dequantize is
    always well-defined).  Returns an ``(F,)`` fp32 array."""
    amax = jnp.maximum(
        jnp.max(jnp.abs(points.astype(jnp.float32)), axis=0),
        jnp.max(jnp.abs(centroids.astype(jnp.float32)), axis=0))
    return jnp.maximum(amax, 1e-12) / INT8_MAX


def quantize(x, scales):
    """Round-to-nearest symmetric int8 quantization, ``(N, F) -> int8``."""
    q = jnp.round(x.astype(jnp.float32) / scales[None, :])
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def dequantize(q, scales):
    """``int8 -> fp32`` (the values the kernels actually compute on)."""
    return q.astype(jnp.float32) * scales[None, :]


def fake_quantize(x, scales):
    """Quantize → dequantize in one step: the fp32 values an int8 kernel
    sees.  The jnp simulation path and the parity oracles both use this,
    so 'int8 kernel vs int8 reference' comparisons are bit-meaningful."""
    return dequantize(quantize(x, scales), scales)
