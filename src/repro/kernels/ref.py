"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose``
targets for the per-kernel shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """O(S²) softmax attention. q (B,Sq,H,D); k/v (B,Sk,Hkv,D); GQA via
    kv-head broadcast. float32 softmax accumulation."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)           # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(jnp.float32),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)


def kmeans_assign_ref(points, centroids):
    """points (N,F), centroids (K,F) -> (ids (N,), min-dist (N,)).
    Distances via the MXU-friendly expansion ||x||²−2x·cᵀ+||c||²."""
    x2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)
    xc = points.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    d2 = jnp.maximum(x2 - 2.0 * xc + c2[None, :], 0.0)
    ids = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dmin = jnp.sqrt(jnp.take_along_axis(d2, ids[:, None].astype(jnp.int64)
                                        if False else ids[:, None], 1)[:, 0])
    return ids, dmin


def kmeans_assign_update_ref(points, centroids):
    """Two-pass oracle for the fused assign+update kernel: assignment via
    :func:`kmeans_assign_ref`, then an explicit (K,N) one-hot matmul for
    the per-centroid sums/counts.  Returns (ids, dmin, sums (K,F) f32,
    counts (K,) f32)."""
    ids, dmin = kmeans_assign_ref(points, centroids)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(ids, k, dtype=jnp.float32)       # (N, K)
    sums = onehot.T @ points.astype(jnp.float32)             # (K, F)
    counts = jnp.sum(onehot, axis=0)                         # (K,)
    return ids, dmin, sums, counts


def kmeans_assign_update_int8_ref(points, centroids):
    """int8 oracle: fake-quantize points/centroids with the shared
    per-feature scales, then run the exact fp32 two-pass oracle on the
    rounded values — precisely what the int8 kernel computes (sums are
    dequantized-point sums)."""
    from repro.kernels import quant

    xf = points.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    scales = quant.symmetric_scales(xf, cf)
    return kmeans_assign_update_ref(quant.fake_quantize(xf, scales),
                                    quant.fake_quantize(cf, scales))


def ssd_ref(xh, dt, A, B_, C_, D):
    """Sequential (exact) SSD recurrence — the slow oracle.

    xh (B,S,nh,hd); dt (B,S,nh) post-softplus; A (nh,) negative;
    B_/C_ (B,S,g,ds); D (nh,). Returns y (B,S,nh,hd), final_state
    (B,nh,hd,ds).
    """
    b, s, nh, hd = xh.shape
    g, ds = B_.shape[2], B_.shape[3]
    rep = nh // g
    BH = jnp.repeat(B_, rep, axis=2).astype(jnp.float32)   # (B,S,nh,ds)
    CH = jnp.repeat(C_, rep, axis=2).astype(jnp.float32)
    xf = xh.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, t):
        x_t, dt_t, b_t, c_t = t
        dA = jnp.exp(dt_t * A[None, :])                    # (B,nh)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, b_t)
        state = dA[:, :, None, None] * state + upd
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    init = jnp.zeros((b, nh, hd, ds), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          BH.transpose(1, 0, 2, 3), CH.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, init, xs)
    y = ys.transpose(1, 0, 2, 3)
    y = y + xf * D[None, None, :, None]
    return y.astype(xh.dtype), final
