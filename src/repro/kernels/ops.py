"""Jit'd public wrappers for the Pallas kernels.

Call sites use these (``from repro.kernels import ops as kops``); each
forwards to the kernel with ``interpret=True`` on CPU hosts and
``interpret=False`` on TPU, chosen at trace time from the default backend.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.kmeans import kmeans_assign as _kmeans_assign
from repro.kernels.kmeans import kmeans_assign_update as _kmeans_fused
from repro.kernels.ssd import ssd_chunk_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=_interpret())


def kmeans_assign(points, centroids, *, block_n: int = 256,
                  precision: str = "fp32"):
    return _kmeans_assign(points, centroids, block_n=block_n,
                          precision=precision, interpret=_interpret())


def kmeans_assign_update(points, centroids, *, block_n: int = 256,
                         precision: str = "fp32"):
    """Fused assign+update: (ids, dmin, sums (K,F), counts (K,))."""
    return _kmeans_fused(points, centroids, block_n=block_n,
                         precision=precision, interpret=_interpret())


def ssd_chunk_scan(xh, dt, A, B_, C_, D, *, chunk: int = 256):
    return _ssd(xh, dt, A, B_, C_, D, chunk=chunk, interpret=_interpret())
