"""Pallas TPU Mamba2-SSD chunk kernel.

The SSD prefill decomposes into (Mamba2 Alg. 1):

  1. **intra-chunk** (quadratic in chunk length): y += (L ∘ (C·Bᵀ)) · X —
     two (chunk × chunk) MXU matmuls per (batch, head, chunk); this is the
     compute hot-spot and lives in the kernel,
  2. **chunk states**: S_c = Bᵀ·(decay·dt·X) — one (ds × chunk)@(chunk × hd)
     MXU matmul, also in the kernel,
  3. **inter-chunk recurrence** — sequential over ~S/chunk steps; stays in
     ``lax.scan`` outside (a sequential dependence has no MXU win).

Grid = (batch, heads, chunks); heads map to their B/C group via the
BlockSpec index_map (n_groups ≤ heads, like GQA). The cumulative decay
``cum`` is computed with a lower-triangular ones matmul (MXU) rather than a
1-D scan (TPU-friendly), and is emitted so the host-side inter-chunk pass
can reuse it.

Validated in interpret mode against kernels/ref.py::ssd_ref (exact
sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xh_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,
                y_ref, st_ref, cum_ref, *, chunk: int):
    x = xh_ref[0, 0].astype(jnp.float32)                   # (q, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)                  # (1, q) row
    dt = dt.reshape(chunk)
    B = b_ref[0, 0].astype(jnp.float32)                    # (q, ds)
    C = c_ref[0, 0].astype(jnp.float32)                    # (q, ds)
    A = a_ref[0, 0]                                        # scalar
    D = d_ref[0, 0]

    dA = dt * A                                            # (q,) <= 0
    # cumulative sum via lower-triangular ones matmul (MXU, no 1-D scan)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = (ii >= jj).astype(jnp.float32)
    cum = jax.lax.dot_general(tril, dA[:, None], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j)·dt_j for i >= j
    L = jnp.exp(cum[:, None] - cum[None, :]) * dt[None, :]
    L = jnp.where(ii >= jj, L, 0.0)
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (q,q)
    y = jax.lax.dot_general(G * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (q,hd)
    y = y + x * D

    # chunk state: S = Bᵀ · (decay_to_end · dt · X)  -> (ds, hd)
    total = cum[chunk - 1]
    w = jnp.exp(total - cum) * dt                          # (q,)
    st = jax.lax.dot_general(B, x * w[:, None], (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    y_ref[0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = st
    cum_ref[0, 0] = cum[None, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(xh, dt, A, B_, C_, D, *, chunk: int = 256,
                   interpret: bool = True):
    """Full SSD pass: Pallas intra-chunk kernel + host inter-chunk scan.

    xh (B,S,nh,hd); dt (B,S,nh) post-softplus; A (nh,) negative;
    B_/C_ (B,S,g,ds); D (nh,). Returns (y (B,S,nh,hd), final_state
    (B,nh,hd,ds)) matching ref.ssd_ref.
    """
    b, s, nh, hd = xh.shape
    g, ds = B_.shape[2], B_.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = nh // g

    xt = xh.transpose(0, 2, 1, 3)                          # (B,nh,S,hd)
    dtt = dt.transpose(0, 2, 1)[:, :, None, :]             # (B,nh,1,S)
    Bt = B_.transpose(0, 2, 1, 3)                          # (B,g,S,ds)
    Ct = C_.transpose(0, 2, 1, 3)
    A2 = A.reshape(nh, 1).astype(jnp.float32)
    D2 = D.reshape(nh, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st, cum = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda ib, ih, ic: (ib, ih, 0, ic)),
            pl.BlockSpec((1, 1, chunk, ds),
                         lambda ib, ih, ic, rep=rep: (ib, ih // rep, ic, 0)),
            pl.BlockSpec((1, 1, chunk, ds),
                         lambda ib, ih, ic, rep=rep: (ib, ih // rep, ic, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ih, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ih, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, 1, ds, hd),
                         lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda ib, ih, ic: (ib, ih, ic, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, s, hd), xh.dtype),
            jax.ShapeDtypeStruct((b, nh, nc, ds, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, nc, chunk), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, Bt, Ct, A2, D2)

    # ---- inter-chunk recurrence (sequential, host-side jnp) ----
    total = cum[:, :, :, chunk - 1]                        # (B,nh,nc)

    def step(prev, xs):
        st_c, tot_c = xs                                   # (B,nh,ds,hd)
        new = jnp.exp(tot_c)[..., None, None] * prev + st_c
        return new, prev

    init = jnp.zeros((b, nh, ds, hd), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init, (st.transpose(2, 0, 1, 3, 4), total.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 2, 0, 3, 4)     # (B,nh,nc,ds,hd)

    CH = jnp.repeat(Ct, rep, axis=1).reshape(b, nh, nc, chunk, ds)
    y_inter = jnp.einsum("bhcin,bhcnp->bhcip",
                         CH * jnp.exp(cum)[..., None].astype(jnp.float32),
                         prev_states)
    y = y + y_inter.reshape(b, nh, s, hd).astype(y.dtype)
    return (y.transpose(0, 2, 1, 3),
            final.transpose(0, 1, 3, 2))                   # (B,nh,hd,ds)
