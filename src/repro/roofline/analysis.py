"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are parsed out of the optimized HLO text: we sum the result-buffer
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaling ops that live inside while-loop bodies by the
loop trip count (scan over layers / microbatches).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (per assignment).
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# `%name = TYPE[d0,d1]{layout} op-name(` — possibly tuple types
_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9]+\[[^\]=]*\]?[^=]*?)\s+"
    r"(?P<op>" + "|".join(_COLL_OPS) + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Sum collective result-buffer bytes, weighting while-body ops by trip
    count when XLA recorded one (known_trip_count / known_induction_variable)."""
    # Split into computations; track which are while bodies w/ trip counts.
    trip_counts = {}
    for m in re.finditer(
            r'while\(.*?\).*?body=%?([\w.\-]+).*?'
            r'known_trip_count.*?"n"\s*:\s*"?(\d+)"?',
            hlo_text, re.S):
        body, n = m.group(1), int(m.group(2))
        trip_counts[body] = max(trip_counts.get(body, 1), n)
    # fallback: trip_count attr inline
    for m in re.finditer(
            r'body=%?([\w.\-]+)[^\n]*trip_count=(\d+)', hlo_text):
        trip_counts[m.group(1)] = max(
            trip_counts.get(m.group(1), 1), int(m.group(2)))

    stats = {op: 0.0 for op in _COLL_OPS}
    counts = {op: 0 for op in _COLL_OPS}
    current_comp = None
    weight = 1
    for line in hlo_text.splitlines():
        header = re.match(r"\s*(?:%?)([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if line and not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            if m and "(" in line:
                current_comp = m.group(1)
                weight = trip_counts.get(current_comp, 1)
        m = _OP_RE.search(line)
        if m and "-done(" not in line:
            op = m.group("op")
            stats[op] += _shape_bytes(m.group("type")) * weight
            counts[op] += weight
    stats["total_bytes"] = sum(stats[o] for o in _COLL_OPS)
    stats["counts"] = counts
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # module total (per-device x chips)
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    per_device_hbm: Optional[float] = None
    dot_flops: float = 0.0         # matmul-only flops (remat-waste view)
    coll_counts: Optional[dict] = None

    @property
    def t_compute(self):
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self):
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self):
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self):
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self):
        """compute-term share of the max term — 1.0 means perfectly
        compute-bound (the roofline)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t else 0.0

    def row(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "dot_flops": self.dot_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_hbm": self.per_device_hbm,
            "coll_counts": self.coll_counts,
        }


def analyze_compiled(compiled, *, chips: int):
    """Trip-count-aware per-module costs from the compiled artifact.

    Returns dict with module-total flops/bytes/collective bytes (per-device
    parsed costs x chips) — see hlo_cost.HloCostModel for methodology.
    """
    from repro.roofline.hlo_cost import HloCostModel
    m = HloCostModel(compiled.as_text())
    coll = m.collective_bytes()
    return {
        "flops": m.flops() * chips,
        "dot_flops": m.dot_flops_only() * chips,
        "bytes": m.bytes_accessed() * chips,
        "collective_bytes": coll["total_bytes"] * chips,
        "coll_counts": coll["counts"],
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = active params."""
    n = cfg.active_param_count
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def parse_memory_analysis(compiled) -> Optional[float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    for attr in ("temp_size_in_bytes",):
        pass
    try:
        total = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes + ma.generated_code_size_in_bytes)
        return float(total)
    except Exception:
        return None
