from repro.roofline import analysis
