"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 96 layers therefore under-reports FLOPs/bytes by ~96×
(verified experimentally; see EXPERIMENTS.md §Roofline methodology). Since
all our models scan over layers (and the train step scans over
microbatches), we parse the optimized HLO ourselves:

1. split the module into computations and build the call graph
   (``while`` bodies/conditions, ``fusion``/``call``/``conditional``
   callees),
2. weight each computation by the product of caller weights ×
   ``known_trip_count`` of its calling ``while`` ops,
3. FLOPs: 2·M·N·K per ``dot`` (shapes resolved through a module-wide
   symbol table) + 1/element for elementwise arithmetic ops, × weight,
4. bytes: Σ (operand + result bytes) per op at the scheduled level
   (fusion interfaces, not fusion internals — matching HBM traffic),
   × weight,
5. collective bytes: Σ operand bytes of all-gather / all-reduce /
   reduce-scatter / all-to-all / collective-permute, × weight.

All counts are per-device (the module is the SPMD-partitioned program);
callers multiply by chip count where the total is wanted.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_NAME = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE = re.compile(r"^([a-z][\w\-]*)\(")
_SHAPE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_CALLEE = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r"known_trip_count\D*?(\d+)")
_OPERANDS = re.compile(r"%([\w.\-]+)")

# elementwise-ish ops counted at 1 flop / output element
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "logistic", "power",
    "negate", "abs", "floor", "ceil", "round-nearest-even", "sign",
    "cosine", "sine", "expm1", "log1p", "atan2", "remainder",
}
# ops that move no HBM bytes themselves (while/call/conditional pass
# loop-carried buffers by alias; their bodies are counted separately)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call",
}
# slicing ops touch only the slice, not the (aliased) full buffer
_SLICE_OPS = {"dynamic-slice", "dynamic-update-slice", "slice"}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over possibly-tuple type strings."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op] = dataclasses.field(default_factory=list)
    is_fusion_body: bool = False
    is_scalar_body: bool = False     # reduce/sort/scatter to_apply


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, _Computation] = {}
        self.entry: Optional[str] = None
        self.symbols: Dict[str, str] = {}          # op name -> type string
        self._parse(hlo_text)
        self.weights = self._compute_weights()

    # -- parsing -----------------------------------------------------------

    @staticmethod
    def _split_op_line(raw: str):
        """'%name = TYPE opcode(...)' -> (name, type_str, opcode) or None.
        Handles tuple types '(f32[..], s32[])' with balanced parens."""
        m = _OP_NAME.match(raw)
        if not m:
            return None
        name = m.group(1)
        rest = raw[m.end():]
        if rest.startswith("("):                       # tuple type
            depth = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            type_str, rest = rest[:i + 1], rest[i + 1:]
        else:
            sp = rest.find(" ")
            if sp < 0:
                return None
            type_str, rest = rest[:sp], rest[sp:]
        rest = rest.lstrip()
        mo = _OPCODE.match(rest)
        if not mo:
            return None
        return name, type_str, mo.group(1)

    def _parse(self, txt: str) -> None:
        current: Optional[_Computation] = None
        for raw in txt.splitlines():
            if raw and not raw[0].isspace():
                m = _COMP_HEADER.match(raw)
                if m and "{" in raw:
                    current = _Computation(m.group(1))
                    self.computations[current.name] = current
                    if raw.startswith("ENTRY"):
                        self.entry = current.name
                    continue
            if current is None:
                continue
            parsed = self._split_op_line(raw)
            if parsed:
                name, type_str, opcode = parsed
                self.symbols[name] = type_str
                current.ops.append(_Op(name, type_str, opcode, raw))

        # classify fusion/scalar bodies
        for comp in self.computations.values():
            for op in comp.ops:
                line = op.line
                for callee in _CALLEE.findall(line):
                    if callee not in self.computations:
                        continue
                    if op.opcode == "fusion":
                        self.computations[callee].is_fusion_body = True
                    elif op.opcode in ("reduce", "reduce-window", "scatter",
                                       "sort", "select-and-scatter",
                                       "all-reduce", "reduce-scatter",
                                       "map"):
                        self.computations[callee].is_scalar_body = True

    # -- call-graph weights ----------------------------------------------------

    def _compute_weights(self) -> Dict[str, float]:
        edges: Dict[str, List[Tuple[str, float]]] = {
            c: [] for c in self.computations}
        for comp in self.computations.values():
            for op in comp.ops:
                line = op.line
                mult = 1.0
                if op.opcode == "while":
                    t = _TRIP.search(line)
                    mult = float(t.group(1)) if t else 1.0
                for callee in _CALLEE.findall(line):
                    if callee in self.computations:
                        edges[comp.name].append((callee, mult))
                mb = _BRANCHES.search(line)
                if mb:
                    for br in _OPERANDS.findall(mb.group(1)):
                        if br in self.computations:
                            edges[comp.name].append((br, 1.0))

        weights = {c: 0.0 for c in self.computations}
        if self.entry is None:
            return weights
        weights[self.entry] = 1.0
        # propagate in topological order via repeated relaxation (call
        # graphs are small; no recursion in HLO)
        for _ in range(len(self.computations)):
            changed = False
            acc = {c: 0.0 for c in self.computations}
            acc[self.entry] = 1.0
            for caller, outs in edges.items():
                for callee, mult in outs:
                    acc[callee] += weights[caller] * mult
            for c in acc:
                if abs(acc[c] - weights[c]) > 1e-9:
                    changed = True
            weights = acc
            if not changed:
                break
        return weights

    # -- costs -------------------------------------------------------------

    def _dot_flops(self, op: _Op) -> float:
        _, line = op.name, op.line
        out_elems, _ = _shape_elems_bytes(op.type_str)
        # contracting dims from the lhs operand's shape
        args = line.split("(", 1)[1]
        operands = _OPERANDS.findall(args.split(")", 1)[0])
        k = 1
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        if operands and mc:
            lhs_type = self.symbols.get(operands[0], "")
            shapes = _SHAPE.findall(lhs_type)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for idx in mc.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        k *= dims[int(idx)]
        return 2.0 * out_elems * k

    def _fusion_root_opcode(self, op: _Op) -> Optional[str]:
        m = _CALLEE.search(op.line)
        if not m:
            return None
        body = self.computations.get(m.group(1))
        if not body:
            return None
        for o in body.ops:
            if "ROOT" in o.line:
                return o.opcode
        return body.ops[-1].opcode if body.ops else None

    def _op_operands(self, op: _Op) -> List[str]:
        args = op.line.split("(", 1)[1]
        return _OPERANDS.findall(args.split(")", 1)[0])

    def _fusion_bytes(self, op: _Op) -> float:
        """Fusion traffic with slice-awareness: an operand whose only use
        inside the body is a ``dynamic-slice`` contributes the slice size,
        not the full (possibly loop-stacked) buffer; a ``dynamic-update-
        slice`` root writes the update, not the whole aliased buffer."""
        m = _CALLEE.search(op.line)
        body = self.computations.get(m.group(1)) if m else None
        _, out_bytes = _shape_elems_bytes(op.type_str)
        operands = self._op_operands(op)
        if body is None:
            return out_bytes + sum(
                _shape_elems_bytes(self.symbols.get(o, ""))[1]
                for o in operands)
        # body parameter names by index + their consumers
        param_name: Dict[int, str] = {}
        for o in body.ops:
            if o.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", o.line)
                if pm:
                    param_name[int(pm.group(1))] = o.name
        consumers: Dict[str, List[_Op]] = {}
        for o in body.ops:
            if o.opcode == "parameter":
                continue
            for ref in self._op_operands(o):
                consumers.setdefault(ref, []).append(o)
        # dynamic-update-slices anywhere in the body: their target buffers
        # are aliased in-place — traffic is the update slice, not the full
        # (loop-stacked) buffer. The XLA *CPU* backend wraps bf16 DUS in
        # full-buffer f32 converts (convert → DUS → convert); a TPU would
        # alias in place, so we resolve targets/roots through "transparent"
        # unary ops (convert/bitcast/copy/reshape) when detecting aliasing.
        transparent = {"convert", "bitcast", "copy", "reshape"}
        by_name = {o.name: o for o in body.ops}

        def resolve(name: str) -> str:
            seen = set()
            while name in by_name and name not in seen:
                seen.add(name)
                o = by_name[name]
                if o.opcode in transparent:
                    ops_o = self._op_operands(o)
                    if ops_o:
                        name = ops_o[0]
                        continue
                break
            return name

        dus_targets = set()
        dus_names = set()
        dus_update_bytes = 0.0
        max_target = 0.0
        for o in body.ops:
            if o.opcode != "dynamic-update-slice":
                continue
            dus_names.add(o.name)
            ops_d = self._op_operands(o)
            if ops_d:
                dus_targets.add(resolve(ops_d[0]))
                max_target = max(max_target, _shape_elems_bytes(
                    self.symbols.get(ops_d[0], ""))[1])
            upd = [_shape_elems_bytes(self.symbols.get(x, ""))[1]
                   for x in ops_d[1:]]
            big = [s for s in upd if s > 16]
            dus_update_bytes += min(big) if big else 0.0

        root_src = None
        for o in body.ops:
            if "ROOT" in o.line:
                root_src = resolve(o.name)

        total = 0.0
        if dus_names and (out_bytes >= 0.9 * max_target
                          or root_src in dus_names):
            total += dus_update_bytes        # write = the update slice(s)
        else:
            total += out_bytes
        def effective_consumers(name: str):
            """Consumers, looking through transparent unary ops."""
            out, queue, seen = [], [name], set()
            while queue:
                n = queue.pop()
                if n in seen:
                    continue
                seen.add(n)
                for c in consumers.get(n, []):
                    if c.opcode in transparent:
                        queue.append(c.name)
                    else:
                        out.append(c)
            return out

        for i, operand in enumerate(operands):
            full = _shape_elems_bytes(self.symbols.get(operand, ""))[1]
            pname = param_name.get(i)
            if pname is not None and pname in dus_targets:
                continue                      # aliased in-place target
            cons = effective_consumers(pname) if pname else []
            if cons and all(c.opcode == "dynamic-slice" for c in cons):
                total += sum(_shape_elems_bytes(c.type_str)[1]
                             for c in cons)
            else:
                total += full
        return total

    def _op_bytes(self, op: _Op) -> float:
        if op.opcode in _FREE_OPS:
            return 0.0
        _, out_bytes = _shape_elems_bytes(op.type_str)
        if op.opcode == "fusion":
            return self._fusion_bytes(op)
        operand_bytes = [
            _shape_elems_bytes(self.symbols.get(o, ""))[1]
            for o in self._op_operands(op)]
        if op.opcode in _SLICE_OPS:
            # aliased slicing: traffic = 2 x the slice, not the full buffer
            candidates = [b for b in [out_bytes] + operand_bytes if b > 16]
            return 2.0 * min(candidates) if candidates else 0.0
        return float(out_bytes) + float(sum(operand_bytes))

    def flops(self) -> float:
        total = 0.0
        for comp in self.computations.values():
            w = self.weights.get(comp.name, 0.0)
            if w == 0.0:
                continue
            for op in comp.ops:
                if op.opcode == "dot":
                    total += w * self._dot_flops(op)
                elif op.opcode == "convolution":
                    # not used by our models; approximate via output elems
                    out_elems, _ = _shape_elems_bytes(op.type_str)
                    total += w * 2.0 * out_elems
                elif op.opcode in _EW_OPS:
                    out_elems, _ = _shape_elems_bytes(op.type_str)
                    total += w * out_elems
        return total

    def dot_flops_only(self) -> float:
        total = 0.0
        for comp in self.computations.values():
            w = self.weights.get(comp.name, 0.0)
            for op in comp.ops:
                if w and op.opcode == "dot":
                    total += w * self._dot_flops(op)
        return total

    def bytes_accessed(self) -> float:
        total = 0.0
        for comp in self.computations.values():
            if comp.is_fusion_body or comp.is_scalar_body:
                continue                      # fused internals stay on-chip
            w = self.weights.get(comp.name, 0.0)
            if w == 0.0:
                continue
            for op in comp.ops:
                total += w * self._op_bytes(op)
        return total

    @staticmethod
    def _crosses_boundary(line: str, boundary: int) -> bool:
        """True if any replica/partition group mixes devices from both
        sides of ``boundary`` (e.g. 256 = the pod/DCN edge)."""
        m = re.search(r"(?:replica_groups|partition_groups)="
                      r"(\{\{[^=]*?\}\}|\[[^\]]*\]<=\[[^\]]*\]"
                      r"(?:T\([0-9,]+\))?)", line)
        if not m:
            return False
        spec = m.group(1)
        if spec.startswith("{{"):
            for grp in re.findall(r"\{([0-9,]+)\}", spec):
                ids = [int(x) for x in grp.split(",") if x]
                if (any(i < boundary for i in ids)
                        and any(i >= boundary for i in ids)):
                    return True
            return False
        # iota form [G,S]<=[dims](T(perm)): decode exactly
        mi = re.match(r"\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                      r"(?:T\(([0-9,]+)\))?", spec)
        if not mi:
            return True                      # unknown form: conservative
        import numpy as _np
        g, s = int(mi.group(1)), int(mi.group(2))
        dims = [int(x) for x in mi.group(3).split(",")]
        arr = _np.arange(int(_np.prod(dims))).reshape(dims)
        if mi.group(4):
            arr = arr.transpose([int(x) for x in mi.group(4).split(",")])
        groups = arr.reshape(g, s)
        lo = (groups < boundary).any(axis=1)
        hi = (groups >= boundary).any(axis=1)
        return bool((lo & hi).any())

    def collective_bytes(self, boundary: Optional[int] = None
                         ) -> Dict[str, float]:
        stats = {op: 0.0 for op in _COLL_OPS}
        counts = {op: 0 for op in _COLL_OPS}
        cross = 0.0
        for comp in self.computations.values():
            w = self.weights.get(comp.name, 0.0)
            if w == 0.0:
                continue
            for op in comp.ops:
                opc = op.opcode
                base = None
                for c in _COLL_OPS:
                    if opc == c or opc == c + "-start":
                        base = c
                        break
                if base is None:
                    continue
                # operand bytes (assignment methodology)
                args = op.line.split("(", 1)[1]
                nbytes = 0.0
                for operand in _OPERANDS.findall(args.split(")", 1)[0]):
                    t = self.symbols.get(operand)
                    if t:
                        nbytes += _shape_elems_bytes(t)[1]
                stats[base] += w * nbytes
                counts[base] += int(w)
                if boundary and self._crosses_boundary(op.line, boundary):
                    cross += w * nbytes
        stats["total_bytes"] = sum(stats[c] for c in _COLL_OPS)
        stats["counts"] = counts
        if boundary:
            stats["cross_boundary_bytes"] = cross
        return stats
