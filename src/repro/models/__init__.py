from repro.models import layers, transformer
from repro.models.transformer import (ShardRules, decode_step, forward,
                                      init_cache, init_params, loss_fn,
                                      param_pspecs, param_shapes,
                                      cache_pspecs)
