"""Layer primitives for the unified decoder model zoo.

Pure-JAX implementations of every mixer/FFN family needed by the assigned
architectures:

* GQA attention (dense / chunked-flash / sliding-window / decode)
* MLA — multi-head latent attention (prefill expansion + absorbed decode)
* Mamba2 SSD — chunked state-space duality scan (prefill) + stateful decode
* Hymba hybrid block — parallel attention + SSM heads
* FFN: SwiGLU / squared-ReLU / GELU
* MoE: top-k router with scatter-based capacity dispatch (+ arctic's parallel
  dense residual)

All functions take params as plain dict pytrees; initializers live next to the
forward functions so the structure is defined exactly once. Softmax/norm math
runs in float32 regardless of the compute dtype.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig

# ---------------------------------------------------------------------------
# small utilities
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions (...,S) int -> cos/sin (...,S,head_dim//2) float32."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions, head_dim: int, theta: float, sections):
    """M-RoPE (Qwen2-VL): positions (3,B,S) for (t,h,w) sections.

    ``sections`` gives per-axis counts of rotary half-dims,
    sum(sections) == head_dim // 2.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs    # (3,B,S,hd/2)
    parts_cos, parts_sin = [], []
    off = 0
    for i, n in enumerate(sections):
        parts_cos.append(jnp.cos(ang[i, ..., off:off + n]))
        parts_sin.append(jnp.sin(ang[i, ..., off:off + n]))
        off += n
    return jnp.concatenate(parts_cos, -1), jnp.concatenate(parts_sin, -1)


def apply_rope(x, cos, sin):
    """x (B,S,H,D); cos/sin (B,S,D/2) or (S,D/2) — rotate-half convention."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :].astype(jnp.float32)
    sin = sin[:, :, None, :].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def attention_dense(q, k, v, *, causal=True, window=None, q_offset=0):
    """Reference O(S^2)-memory attention. q (B,Sq,H,D), k/v (B,Sk,Hkv,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = kpos[None, :] <= qpos[:, None] if causal else jnp.ones(
        (sq, sk), bool)
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out


def attention_chunked(q, k, v, *, causal=True, window=None,
                      chunk_q=1024, chunk_k=1024):
    """Flash-style chunked attention in pure jnp (online softmax).

    Memory is O(chunk_q * chunk_k) per (batch, head) instead of O(S^2); this
    is the XLA stand-in for the Pallas flash kernel and is used for the long
    prefill shapes. Upper-triangular chunk pairs are masked (not skipped) —
    see EXPERIMENTS.md §Perf for the scheduling optimization that removes the
    waste.
    """
    b, s, h, d = q.shape
    dv = v.shape[-1]                      # MLA: v head dim != qk head dim
    sk = k.shape[1]
    assert s % chunk_q == 0 and sk % chunk_k == 0, (s, sk, chunk_q, chunk_k)
    nq, nk = s // chunk_q, sk // chunk_k
    k = _repeat_kv(k, h // k.shape[2])
    v = _repeat_kv(v, h // v.shape[2])

    qc = q.reshape(b, nq, chunk_q, h, d)
    kc = k.reshape(b, nk, chunk_k, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, chunk_k, h, dv).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qpos = jnp.arange(s).reshape(nq, 1, chunk_q, 1)          # (nq,1,cq,1)

    def body(carry, xs):
        m, l, acc = carry                                    # running stats
        kb, vb, j = xs
        kpos = (j * chunk_k + jnp.arange(chunk_k)).reshape(1, 1, 1, chunk_k)
        sc = jnp.einsum("bnqhd,bkhd->bnhqk", qc, kb,
                        preferred_element_type=jnp.float32) * scale
        mask = kpos <= qpos if causal else (kpos >= 0)       # (nq,1,cq,ck)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        # (nq,1,cq,ck) -> (1,nq,1,cq,ck), broadcasts against (b,nq,h,cq,ck)
        sc = jnp.where(mask[None], sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(sc - m_safe[..., None])
        p = jnp.where(jnp.isneginf(sc), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isneginf(m), 0.0, m) - m_safe)
        corr = jnp.where(jnp.isneginf(m), 0.0, corr)
        l_new = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bnhqk,bkhd->bnhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, nq, h, chunk_q), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, nq, h, chunk_q), jnp.float32)
    a0 = jnp.zeros((b, nq, h, chunk_q, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.transpose(0, 1, 3, 2, 4).reshape(b, s, h, dv).astype(q.dtype)


def attention_decode(q, k_cache, v_cache, valid_len):
    """Single-token decode. q (B,1,H,D); caches (B,Smax,Hkv,D); valid_len =
    number of valid cache entries (the new token is already written).

    GQA is computed *grouped* — q reshaped to (B,1,Hkv,rep,D) against the
    raw (B,S,Hkv,D) cache — instead of materializing ``repeat_kv``. The
    broadcast reshape defeated GSPMD sharding propagation (Hkv=8 cannot
    re-tile to 16 model shards), forcing a full KV-cache all-gather per
    layer; the grouped einsum keeps the cache model-sharded along S and
    turns the collective into tiny (B,H,1)-stat all-reduces.

    Ring-buffer caches (sliding-window archs) are handled by the caller: once
    the buffer wraps, *every* slot is valid and in-window, so a plain
    ``kpos < valid_len`` mask is exact for both layouts."""
    b, _, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    qg = q.reshape(b, 1, hkv, rep, d)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    kpos = jnp.arange(smax)
    mask = kpos < valid_len
    scores = jnp.where(mask[None, None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ArchConfig, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = 0.02
    out_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    return {
        "wq": _init(ks[0], (d, h * hd), scale, dtype),
        "wk": _init(ks[1], (d, hkv * hd), scale, dtype),
        "wv": _init(ks[2], (d, hkv * hd), scale, dtype),
        "wo": _init(ks[3], (h * hd, d), out_scale, dtype),
    }


def gqa_forward(p, x, cos, sin, cfg: ArchConfig, *, impl="dense",
                window=None, chunk=1024):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if impl == "dense":
        o = attention_dense(q, k, v, causal=True, window=window)
    elif impl == "chunked":
        o = attention_chunked(q, k, v, causal=True, window=window,
                              chunk_q=min(chunk, s), chunk_k=min(chunk, s))
    elif impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        raise ValueError(impl)
    return o.reshape(b, s, h * hd) @ p["wo"], (k, v)


def gqa_decode(p, x, cache_k, cache_v, write_idx, valid_len, cos, sin,
               cfg: ArchConfig):
    """x (B,1,D). Writes the new kv at ``write_idx`` (== position, or
    position % window for ring buffers); attends over ``valid_len`` entries.
    Returns (out, new_k, new_v)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache_k = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                       (0, write_idx, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                       (0, write_idx, 0, 0))
    o = attention_decode(q, cache_k, cache_v, valid_len)
    return o.reshape(b, 1, h * hd) @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 family)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype):
    m, d, h = cfg.mla, cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 5)
    out_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    return {
        "wq_a": _init(ks[0], (d, m.q_lora_rank), 0.02, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": _init(ks[1], (m.q_lora_rank, h * qk), 0.02, dtype),
        "wkv_a": _init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), 0.02,
                       dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": _init(ks[3], (m.kv_lora_rank,
                               h * (m.qk_nope_dim + m.v_head_dim)), 0.02,
                       dtype),
        "wo": _init(ks[4], (h * m.v_head_dim, d), out_scale, dtype),
    }


def _mla_qkv(p, x, cos, sin, cfg):
    """Shared projection path; returns q_nope,q_rope,c_kv(normed),k_rope."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    kv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p, x, cos, sin, cfg: ArchConfig, *, impl="dense",
                chunk=1024):
    """Prefill/train path: expand the latent back to per-head k/v."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cos, sin, cfg)
    kvx = (c_kv @ p["wkv_b"]).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvx, [m.qk_nope_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (b, s, h, m.qk_rope_dim))], -1)
    if impl == "chunked":
        o = attention_chunked(q, k, v, causal=True,
                              chunk_q=min(chunk, s), chunk_k=min(chunk, s))
    else:
        o = attention_dense(q, k, v, causal=True)
    return o.reshape(b, s, h * m.v_head_dim) @ p["wo"], (c_kv, k_rope)


def mla_decode(p, x, cache_ckv, cache_krope, length, cos, sin,
               cfg: ArchConfig):
    """Absorbed-matmul MLA decode: attention runs in the latent space, so the
    cache stays compressed — (B,S,kv_lora) + (B,S,rope) only."""
    m, h = cfg.mla, cfg.n_heads
    b = x.shape[0]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cos, sin, cfg)
    cache_ckv = lax.dynamic_update_slice(
        cache_ckv, c_kv.astype(cache_ckv.dtype), (0, length, 0))
    cache_krope = lax.dynamic_update_slice(
        cache_krope, k_rope.astype(cache_krope.dtype), (0, length, 0))
    w_kv = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk, w_uv = w_kv[..., :m.qk_nope_dim], w_kv[..., m.qk_nope_dim:]
    # absorb: q_lat[b,h,r] = sum_n q_nope[b,h,n] w_uk[r,h,n]
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))
    sc = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cache_ckv,
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bqhn,bsn->bhqs", q_rope, cache_krope,
                       preferred_element_type=jnp.float32)) * scale
    smax = cache_ckv.shape[1]
    mask = jnp.arange(smax) < (length + 1)
    sc = jnp.where(mask[None, None, None, :], sc, -jnp.inf)
    pattn = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pattn.astype(cache_ckv.dtype),
                       cache_ckv)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv)
    out = o.reshape(b, 1, h * m.v_head_dim) @ p["wo"]
    return out, cache_ckv, cache_krope


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------


def ffn_init(key, cfg: ArchConfig, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    p = {"w_up": _init(ks[0], (d, f), 0.02, dtype),
         "w_down": _init(ks[1], (f, d), out_scale, dtype)}
    if cfg.ffn_kind == "swiglu":
        p["w_gate"] = _init(ks[2], (d, f), 0.02, dtype)
    return p


def ffn_forward(p, x, kind: str):
    if kind == "swiglu":
        return (silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "relu2":
        h = jax.nn.relu(x @ p["w_up"])
        return (h * h) @ p["w_down"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# MoE — top-k router + scatter-based capacity dispatch
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ArchConfig, dtype):
    m, d = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 5)
    out_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    p = {
        "router": _init(ks[0], (d, m.n_experts), 0.02, jnp.float32),
        "w_gate": _init(ks[1], (m.n_experts, d, m.d_expert), 0.02, dtype),
        "w_up": _init(ks[2], (m.n_experts, d, m.d_expert), 0.02, dtype),
        "w_down": _init(ks[3], (m.n_experts, m.d_expert, d), out_scale,
                        dtype),
    }
    if m.dense_residual:
        p["dense"] = ffn_init(ks[4], cfg, dtype)
    return p


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)          # round up to multiple of 8


def _dispatch_positions(flat_ids, n_experts: int):
    """Position of each (token, slot) within its expert's arrival order.

    Sort-free (cumsum over a one-hot): the argsort formulation lowered to
    multi-megabyte variadic sorts in HLO (§Perf measured them at ~3 TB of
    traffic for qwen3 train); cumsum is linear, deterministic, and keeps
    the same (token, slot)-order priority semantics.

    flat_ids (..., N) int -> pos (..., N) int32.
    """
    oh = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.float32)
    csum = jnp.cumsum(oh, axis=-2)                      # inclusive
    pos = jnp.take_along_axis(csum, flat_ids[..., None].astype(jnp.int32),
                              axis=-1)[..., 0] - 1.0    # exclusive
    return pos.astype(jnp.int32)


def moe_forward(p, x, cfg: ArchConfig, *, shard_experts=None,
                groups: int = 1):
    """x (B,S,D) -> (y (B,S,D), aux_losses dict).

    Scatter/gather capacity dispatch: tokens are routed to a fixed-capacity
    (E, C, D) buffer with plain scatters (no one-hot dispatch einsum), so the
    HLO FLOP count stays proportional to *useful* expert FLOPs. Overflowing
    tokens are dropped (their combine weight contribution is zero), matching
    GShard/Switch semantics.

    ``groups > 1`` enables GShard-style *local dispatch groups*: tokens are
    pre-split into ``groups`` row blocks (aligned with the data-parallel
    sharding of the batch) and each group scatters into its own capacity
    slice. Without groups, the scatter's contributions from different data
    shards must be summed — XLA emits a full (E·C, D) all-reduce per scatter
    per layer per microbatch, which §Perf measured at 98.9% of all
    collective bytes for qwen3-moe. Group-local dispatch removes that sum
    entirely (each buffer row is written by exactly one shard); the
    trade-off is GShard's: capacity is enforced per group, so imbalance
    across groups can drop marginally more tokens.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    # group-local dispatch only when each group fills its capacity floor:
    # with few tokens/group (decode), the per-expert minimum capacity (8)
    # makes the grouped buffer `groups`x oversized — measured 2x WORSE for
    # arctic decode. Training shapes (tg ~ 65k) stay grouped.
    if (groups > 1 and t % groups == 0
            and m.capacity_factor * (t // groups) * m.top_k
            / m.n_experts >= 8):
        return _moe_forward_grouped(p, x, cfg, shard_experts, groups)
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = lax.top_k(probs, m.top_k)                    # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = moe_capacity(cfg, t)
    flat_ids = ids.reshape(-1)                               # (T*k,)
    pos = _dispatch_positions(flat_ids, m.n_experts).reshape(t, m.top_k)
    keep = pos < cap
    slot = jnp.where(keep, ids * cap + pos, m.n_experts * cap)  # drop slot

    buf = jnp.zeros((m.n_experts * cap + 1, d), x.dtype)
    for j in range(m.top_k):                                 # k small, unroll
        buf = buf.at[slot[:, j]].set(xf, mode="drop")
    eb = buf[:-1].reshape(m.n_experts, cap, d)
    if shard_experts is not None:
        eb = shard_experts(eb)
    h = jnp.einsum("ecd,edf->ecf", eb, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", silu(h) * u, p["w_down"])
    if shard_experts is not None:
        out = shard_experts(out)
    out_flat = jnp.concatenate(
        [out.reshape(m.n_experts * cap, d),
         jnp.zeros((1, d), out.dtype)], 0)

    y = jnp.zeros((t, d), jnp.float32)
    for j in range(m.top_k):
        yj = out_flat[slot[:, j]]
        y = y + gate[:, j:j + 1] * yj.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(b, s, d)

    # aux losses: switch load-balance + router z-loss
    me = probs.mean(0)                                        # (E,)
    one_hot_top1 = jax.nn.one_hot(ids[:, 0], m.n_experts, dtype=jnp.float32)
    ce = one_hot_top1.mean(0)
    aux = {
        "lb_loss": m.router_aux_coef * m.n_experts * jnp.sum(me * ce),
        "z_loss": m.router_z_coef * jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - keep.mean(),
    }
    if m.dense_residual:
        y = y + ffn_forward(p["dense"], x, cfg.ffn_kind)
    return y, aux


def _moe_forward_grouped(p, x, cfg: ArchConfig, shard_experts, groups: int):
    """Group-local capacity dispatch (see moe_forward docstring)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    g = groups
    tg = t // g
    xf = x.reshape(g, tg, d)
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = lax.top_k(probs, m.top_k)                    # (g,tg,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = moe_capacity(cfg, tg)
    flat_ids = ids.reshape(g, tg * m.top_k)
    pos = _dispatch_positions(flat_ids, m.n_experts).reshape(g, tg,
                                                             m.top_k)
    keep = pos < cap
    slot = jnp.where(keep, ids * cap + pos, m.n_experts * cap)

    buf = jnp.zeros((g, m.n_experts * cap + 1, d), x.dtype)
    for j in range(m.top_k):
        buf = jax.vmap(lambda bf, sl, xr: bf.at[sl].set(xr, mode="drop"))(
            buf, slot[:, :, j], xf)
    eb = buf[:, :-1].reshape(g, m.n_experts, cap, d)
    if shard_experts is not None:
        eb = shard_experts(eb)
    h = jnp.einsum("gecd,edf->gecf", eb, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", eb, p["w_up"])
    out = jnp.einsum("gecf,efd->gecd", silu(h) * u, p["w_down"])
    if shard_experts is not None:
        out = shard_experts(out)
    out_flat = jnp.concatenate(
        [out.reshape(g, m.n_experts * cap, d),
         jnp.zeros((g, 1, d), out.dtype)], 1)

    y = jnp.zeros((g, tg, d), jnp.float32)
    for j in range(m.top_k):
        yj = jax.vmap(lambda of, sl: of[sl])(out_flat, slot[:, :, j])
        y = y + gate[:, :, j:j + 1] * yj.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(b, s, d)

    me = probs.mean((0, 1))
    one_hot_top1 = jax.nn.one_hot(ids[..., 0], m.n_experts,
                                  dtype=jnp.float32)
    ce = one_hot_top1.mean((0, 1))
    aux = {
        "lb_loss": m.router_aux_coef * m.n_experts * jnp.sum(me * ce),
        "z_loss": m.router_z_coef * jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - keep.mean(),
    }
    if m.dense_residual:
        y = y + ffn_forward(p["dense"], x, cfg.ffn_kind)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba2 — SSD (state-space duality), chunked
# ---------------------------------------------------------------------------


def ssm_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_dim


def ssm_init(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    out_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32)
                 * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))                  # inv softplus
    return {
        "in_proj": _init(ks[0], (d, 2 * d_in + 2 * s.n_groups * s.d_state
                                 + nh), 0.02, dtype),
        "conv_w": _init(ks[1], (s.d_conv, conv_dim), 0.02, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": _init(ks[3], (d_in, d), out_scale, dtype),
    }


def _ssm_split(p, x, cfg: ArchConfig):
    """in_proj + causal conv; returns (z, xh, B, C, dt_raw)."""
    s = cfg.ssm
    d_in, nh, conv_dim = ssm_dims(cfg)
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(proj, [d_in, d_in + conv_dim], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(xbc, conv_w, conv_b):
    """xbc (B,S,C); depthwise causal conv along S."""
    k = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(k))
    return silu(out + conv_b)


def ssd_chunked(xh, dt, A, B_, C_, D, chunk: int, *, return_state=False):
    """Chunked SSD scan (Mamba2 alg. 1), pure jnp.

    xh (B,S,nh,hd); dt (B,S,nh) [post-softplus]; A (nh,) negative;
    B_/C_ (B,S,g,d_state); D (nh,). Returns y (B,S,nh,hd), and with
    ``return_state`` also the final recurrent state (B,nh,hd,ds).
    """
    b, s, nh, hd = xh.shape
    g, ds = B_.shape[2], B_.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = nh // g

    xc = xh.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B_.reshape(b, nc, chunk, g, ds)
    Cc = C_.reshape(b, nc, chunk, g, ds)
    BH = jnp.repeat(Bc, rep, axis=3)                        # (b,nc,q,nh,ds)
    CH = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]                       # (b,nc,q,nh) <=0
    cum = jnp.cumsum(dA, axis=2)                            # within-chunk
    total = cum[:, :, -1, :]                                # (b,nc,nh)

    # ---- intra-chunk (quadratic within chunk) ----
    # L[i,j] = exp(cum_i - cum_j) * dt_j  for i >= j
    li = cum[:, :, :, None, :]                              # (b,nc,q,1,nh)
    lj = cum[:, :, None, :, :]                              # (b,nc,1,q,nh)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    L = jnp.exp(li - lj) * dtc[:, :, None, :, :]
    L = jnp.where(mask[None, None, :, :, None], L, 0.0)     # (b,nc,i,j,nh)
    G = jnp.einsum("bcihn,bcjhn->bcijh", CH, BH,
                   preferred_element_type=jnp.float32)      # (b,nc,i,j,nh)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", G * L,
                         xc.astype(jnp.float32))

    # ---- chunk states ----
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)      # (b,nc,j,nh)
    st = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", BH,
                    (decay_to_end * dtc).astype(jnp.float32),
                    xc.astype(jnp.float32))                 # (b,nc,nh,hd,ds)

    # ---- inter-chunk recurrence ----
    def step(state, xs):
        st_c, tot_c = xs                                    # (b,nh,hd,ds)
        prev = state
        new = jnp.exp(tot_c)[:, :, None, None] * prev + st_c
        return new, prev                                    # emit state *before* chunk

    init = jnp.zeros((b, nh, hd, ds), jnp.float32)
    final_state, prev_states = lax.scan(step, init,
                                        (st.transpose(1, 0, 2, 3, 4),
                                         total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (b,nc,nh,hd,ds)

    # ---- inter-chunk output ----
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", CH * jnp.exp(cum)[..., None],
                         prev_states)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    y = (y + xh.astype(jnp.float32) * D[None, None, :, None]).astype(
        xh.dtype)
    if return_state:
        return y, final_state
    return y


def ssm_forward(p, x, cfg: ArchConfig, *, return_state=False, impl="jnp"):
    """Full-sequence Mamba2 mixer. x (B,S,D) -> y, or with ``return_state``
    -> (y, (final ssm_state (B,nh,hd,ds), conv_state (B,d_conv-1,conv_dim)))."""
    s = cfg.ssm
    d_in, nh, conv_dim = ssm_dims(cfg)
    b, sl, _ = x.shape
    z, xbc_raw, dt_raw = _ssm_split(p, x, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xh, B_, C_ = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], -1)
    xh = xh.reshape(b, sl, nh, s.head_dim)
    B_ = B_.reshape(b, sl, s.n_groups, s.d_state)
    C_ = C_.reshape(b, sl, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, final = kops.ssd_chunk_scan(xh, dt, A, B_, C_, p["D"],
                                       chunk=min(s.chunk, sl))
    else:
        y, final = ssd_chunked(xh, dt, A, B_, C_, p["D"], min(s.chunk, sl),
                               return_state=True)
    y = y.reshape(b, sl, d_in)
    y = rms_norm(y * silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        k = s.d_conv - 1
        conv_state = xbc_raw[:, -k:, :] if sl >= k else jnp.pad(
            xbc_raw, ((0, 0), (k - sl, 0), (0, 0)))
        return out, (final, conv_state.astype(x.dtype))
    return out


def ssm_decode(p, x, ssm_state, conv_state, cfg: ArchConfig):
    """Stateful single-token decode.

    x (B,1,D); ssm_state (B,nh,hd,ds) float32; conv_state (B,d_conv-1,conv_dim).
    Returns (y, new_ssm_state, new_conv_state).
    """
    s = cfg.ssm
    d_in, nh, conv_dim = ssm_dims(cfg)
    b = x.shape[0]
    z, xbc, dt_raw = _ssm_split(p, x, cfg)
    xbc = xbc[:, 0]                                          # (B,conv_dim)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], 1)
    out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc_t = silu(out)
    new_conv = window[:, 1:]
    xh, B_, C_ = jnp.split(xbc_t, [d_in, d_in + s.n_groups * s.d_state], -1)
    xh = xh.reshape(b, nh, s.head_dim)
    B_ = jnp.repeat(B_.reshape(b, s.n_groups, s.d_state), nh // s.n_groups, 1)
    C_ = jnp.repeat(C_.reshape(b, s.n_groups, s.d_state), nh // s.n_groups, 1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                     # (B,nh)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, xh.astype(jnp.float32),
                     B_.astype(jnp.float32))
    new_state = dA[:, :, None, None] * ssm_state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C_.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(y * silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_state, new_conv


# ---------------------------------------------------------------------------
# Hymba hybrid block pieces (parallel attn + SSM heads)
# ---------------------------------------------------------------------------


def hybrid_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    d_in, _, _ = ssm_dims(cfg)
    return {
        "attn": gqa_init(k1, cfg, dtype),
        "ssm": ssm_init(k2, cfg, dtype),
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "ssm_norm_out": jnp.ones((cfg.d_model,), dtype),
    }


def hybrid_forward(p, x, cos, sin, cfg: ArchConfig, *, impl="dense",
                   chunk=1024):
    a, kv = gqa_forward(p["attn"], x, cos, sin, cfg, impl=impl,
                        window=cfg.sliding_window, chunk=chunk)
    m = ssm_forward(p["ssm"], x, cfg)
    y = 0.5 * (rms_norm(a, p["attn_norm"], cfg.norm_eps)
               + rms_norm(m, p["ssm_norm_out"], cfg.norm_eps))
    return y, kv


def hybrid_decode(p, x, cache, write_idx, valid_len, cos, sin,
                  cfg: ArchConfig):
    a, ck, cv = gqa_decode(p["attn"], x, cache["k"], cache["v"], write_idx,
                           valid_len, cos, sin, cfg)
    m, st, conv = ssm_decode(p["ssm"], x, cache["ssm"], cache["conv"], cfg)
    y = 0.5 * (rms_norm(a, p["attn_norm"], cfg.norm_eps)
               + rms_norm(m, p["ssm_norm_out"], cfg.norm_eps))
    return y, {"k": ck, "v": cv, "ssm": st, "conv": conv}
