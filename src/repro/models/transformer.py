"""Unified decoder model covering all ten assigned architectures.

One parameterized decoder:

* layer stack via ``lax.scan`` over stacked per-layer params (keeps the HLO —
  and therefore compile time of the 340B/480B configs — small and makes the
  remat policy uniform),
* family-specific mixers picked by ``cfg.attn_kind`` (gqa / mla / hybrid /
  none→SSD),
* FFN / MoE picked by ``cfg.moe`` / ``cfg.ffn_kind``,
* modality stubs: musicgen consumes (B,S,4) codebook ids, qwen2-vl consumes
  precomputed patch embeddings + (3,B,S) M-RoPE position ids.

Params are plain dict pytrees; ``param_pspecs`` mirrors the structure with
``PartitionSpec`` leaves (TP on ``model``, optional ZeRO-3/FSDP dim on
``data``), so the same model runs on 1 CPU device (smoke tests) or a
512-chip multi-pod mesh (dry-run) without code changes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardRules:
    """Maps logical dims to mesh axes. ``None`` fields replicate."""
    batch: tuple = ("data",)          # ("pod","data") on the multi-pod mesh
    model: Optional[str] = "model"
    fsdp: Optional[str] = None        # ZeRO-3 axis for params (usually "data")
    seq: Optional[str] = None         # sequence-parallel axis for activations
    moe_groups: int = 1               # local dispatch groups (= batch shards)
    model_size: int = 1               # mesh size of the model axis

    def act(self, x, *spec):
        """Sharding constraint helper; no-op when rules are disabled."""
        return jax.lax.with_sharding_constraint(x, P(*spec))


NO_RULES = None


def _c(rules, x, *spec):
    if rules is None:
        return x
    return rules.act(x, *spec)


def _expert_constraint(rules):
    """MoE buffer constraint: (E,C,D) -> model on E; grouped (G,E,C,D) ->
    batch axes on G, model on E (group-local dispatch)."""
    def f(e):
        if e.ndim == 4:
            return _c(rules, e, rules.batch, rules.model, None, None)
        return _c(rules, e, rules.model, None, None)
    return f


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.attn_kind == "gqa":
        p["attn"] = L.gqa_init(ks[0], cfg, dtype)
    elif cfg.attn_kind == "mla":
        p["attn"] = L.mla_init(ks[0], cfg, dtype)
    elif cfg.attn_kind == "hybrid":
        p["mixer"] = L.hybrid_init(ks[0], cfg, dtype)
    elif cfg.attn_kind == "none":
        p["ssm"] = L.ssm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(cfg.attn_kind)
    if cfg.moe is not None:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = L.moe_init(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = L.ffn_init(ks[2], cfg, dtype)
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    params = {}
    v, d, kb = cfg.padded_vocab_size, cfg.d_model, cfg.n_codebooks
    if cfg.input_mode == "tokens":
        shape = (v, d) if kb == 1 else (kb, v, d)
        emb = L._init(k_emb, shape, 0.02, dtype)
        if v != cfg.vocab_size:        # zero the pad rows (never indexed)
            emb = emb.at[..., cfg.vocab_size:, :].set(0.0)
        params["embed"] = emb
    params["ln_f"] = jnp.ones((d,), dtype)
    if not cfg.tie_embeddings:
        shape = (d, v) if kb == 1 else (kb, d, v)
        head = L._init(k_head, shape, 0.02, dtype)
        if v != cfg.vocab_size:        # zero pad cols -> pad logits == 0
            head = head.at[..., cfg.vocab_size:].set(0.0)
        params["head"] = head
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    params["blocks"] = jax.vmap(
        lambda k: _block_init(k, cfg, dtype))(layer_keys)
    return params


def param_shapes(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree — lets the dry-run lower without allocating."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.key(0))


# ---------------------------------------------------------------------------
# partition specs (mirror init structure exactly)
# ---------------------------------------------------------------------------


def _block_pspecs(cfg: ArchConfig, r: ShardRules):
    m, f = r.model, r.fsdp
    rep1 = P(None, None)                       # stacked (L, d) norms
    p = {"ln1": rep1}
    if cfg.attn_kind in ("gqa", "hybrid"):
        # TP shards whole heads. If kv heads don't divide the model axis
        # (every assigned GQA arch: hkv <= 8 < 16), a column-sharded wk/wv
        # splits *within* head_dim and every score matmul needs a partial-
        # sum all-reduce (or the cache a full all-gather at decode).
        # Megatron-style: replicate the (tiny) kv projections instead and
        # keep q/o head-sharded — kv compute is redundant but local. Only
        # worth it without a backward pass (§Perf): dgrad of a replicated
        # wk/wv costs an activation-sized model-axis all-reduce.
        kv_rep = (r.model_size > 1
                  and cfg.n_kv_heads % max(r.model_size, 1) != 0)
        mkv = None if kv_rep else m
        attn = {"wq": P(None, f, m), "wk": P(None, f, mkv),
                "wv": P(None, f, mkv), "wo": P(None, m, f)}
    if cfg.attn_kind == "gqa":
        p["attn"] = attn
    elif cfg.attn_kind == "mla":
        p["attn"] = {
            "wq_a": P(None, f, None), "q_norm": rep1,
            "wq_b": P(None, None, m),
            "wkv_a": P(None, f, None), "kv_norm": rep1,
            "wkv_b": P(None, None, m),
            "wo": P(None, m, f),
        }
    if cfg.attn_kind in ("none", "hybrid"):
        # SSM projections pack z/x/B/C/dt into one output dim — that packed
        # dim is not TP-shardable as-is (6482/3352 ∤ 16), so SSM weights
        # replicate over 'model' and shard only on the FSDP axis. Splitting
        # the projection per-segment to enable head-sharded SSM TP is the
        # §Perf follow-up recorded in EXPERIMENTS.md.
        ssm = {"in_proj": P(None, f, None),
               "conv_w": P(None, None, None), "conv_b": P(None, None),
               "A_log": P(None, None), "D": P(None, None),
               "dt_bias": P(None, None),
               "norm": P(None, None), "out_proj": P(None, None, f)}
        if cfg.attn_kind == "none":
            p["ssm"] = ssm
        else:
            p["mixer"] = {"attn": attn, "ssm": ssm,
                          "attn_norm": rep1, "ssm_norm_out": rep1}
    if cfg.moe is not None:
        p["ln2"] = rep1
        moe = {"router": P(None, None, None),
               "w_gate": P(None, m, f, None),
               "w_up": P(None, m, f, None),
               "w_down": P(None, m, None, f)}
        if cfg.moe.dense_residual:
            moe["dense"] = {"w_up": P(None, f, m), "w_down": P(None, m, f),
                            **({"w_gate": P(None, f, m)}
                               if cfg.ffn_kind == "swiglu" else {})}
        p["moe"] = moe
    elif cfg.d_ff:
        p["ln2"] = rep1
        ffn = {"w_up": P(None, f, m), "w_down": P(None, m, f)}
        if cfg.ffn_kind == "swiglu":
            ffn["w_gate"] = P(None, f, m)
        p["ffn"] = ffn
    return p


def param_pspecs(cfg: ArchConfig, rules: ShardRules):
    m, f = rules.model, rules.fsdp
    specs = {"ln_f": P(None), "blocks": _block_pspecs(cfg, rules)}
    if cfg.input_mode == "tokens":
        specs["embed"] = (P(m, f) if cfg.n_codebooks == 1
                          else P(None, m, f))
    if not cfg.tie_embeddings:
        specs["head"] = (P(f, m) if cfg.n_codebooks == 1
                         else P(None, f, m))
    return specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ArchConfig, inputs):
    if cfg.input_mode == "embeddings":
        return inputs["embeds"]
    tok = inputs["tokens"]
    if cfg.n_codebooks == 1:
        return params["embed"][tok]
    # musicgen: (B,S,K) codebook ids, summed embeddings
    parts = [params["embed"][k][tok[..., k]]
             for k in range(cfg.n_codebooks)]
    return sum(parts)


def _logits(params, cfg: ArchConfig, x, rules):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
        return _c(rules, logits,
                  (rules.batch if rules else None), None,
                  (rules.model if rules else None))
    if cfg.n_codebooks == 1:
        logits = x @ params["head"]
        return _c(rules, logits,
                  (rules.batch if rules else None), None,
                  (rules.model if rules else None))
    return jnp.einsum("bsd,kdv->bskv", x, params["head"])


def _positions_cos_sin(cfg: ArchConfig, inputs, seq_len, head_dim):
    if cfg.pos_kind == "none":
        return None, None
    if cfg.pos_kind == "mrope":
        return L.mrope_cos_sin(inputs["positions"], head_dim,
                               cfg.rope_theta, cfg.mrope_sections)
    pos = jnp.arange(seq_len)
    return L.rope_cos_sin(pos, head_dim, cfg.rope_theta)


def _rope_dim(cfg: ArchConfig) -> int:
    return (cfg.mla.qk_rope_dim if cfg.attn_kind == "mla"
            else cfg.head_dim)


def block_forward(lp, x, cos, sin, cfg: ArchConfig, *, impl, chunk, rules):
    """One decoder block. Returns (x, aux_dict)."""
    aux = {}
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "gqa":
        a, _ = L.gqa_forward(lp["attn"], h, cos, sin, cfg, impl=impl,
                             window=cfg.sliding_window, chunk=chunk)
        x = x + a
    elif cfg.attn_kind == "mla":
        a, _ = L.mla_forward(lp["attn"], h, cos, sin, cfg, impl=impl,
                             chunk=chunk)
        x = x + a
    elif cfg.attn_kind == "hybrid":
        a, _ = L.hybrid_forward(lp["mixer"], h, cos, sin, cfg, impl=impl,
                                chunk=chunk)
        x = x + a
    else:                                           # pure SSM (mamba2)
        x = x + L.ssm_forward(lp["ssm"], h, cfg)
        return x, aux
    x = _c(rules, x, (rules.batch if rules else None), rules.seq if rules
           else None, None)
    if cfg.moe is not None:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = L.moe_forward(
            lp["moe"], h2, cfg,
            shard_experts=(_expert_constraint(rules) if rules else None),
            groups=(rules.moe_groups if rules else 1))
        x = x + y
    elif cfg.d_ff:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn_forward(lp["ffn"], h2, cfg.ffn_kind)
    x = _c(rules, x, (rules.batch if rules else None), rules.seq if rules
           else None, None)
    return x, aux


def forward(params, cfg: ArchConfig, inputs, *, impl="dense", chunk=1024,
            rules: Optional[ShardRules] = None, remat: Optional[bool] = None):
    """Full-sequence forward. Returns (logits, aux)."""
    remat = cfg.remat if remat is None else remat
    x = _embed_inputs(params, cfg, inputs)
    x = _c(rules, x, (rules.batch if rules else None),
           rules.seq if rules else None, None)
    seq_len = x.shape[1]
    cos, sin = _positions_cos_sin(cfg, inputs, seq_len, _rope_dim(cfg))

    def body(carry, lp):
        h, aux_acc = carry
        h, aux = block_forward(lp, h, cos, sin, cfg, impl=impl, chunk=chunk,
                               rules=rules)
        for k, v in aux.items():
            aux_acc[k] = aux_acc.get(k, 0.0) + v
        return (h, aux_acc), None

    aux0 = ({"lb_loss": 0.0, "z_loss": 0.0, "dropped_frac": 0.0}
            if cfg.moe is not None else {})
    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable) if remat \
        else body
    (x, aux), _ = lax.scan(body_fn, (x, aux0), params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _logits(params, cfg, x, rules)
    if cfg.moe is not None:
        aux = {k: v / cfg.n_layers if k == "dropped_frac" else v
               for k, v in aux.items()}
    return logits, aux


def loss_fn(params, cfg: ArchConfig, inputs, *, impl="dense", chunk=1024,
            rules=None, remat=None):
    """Next-token cross entropy (+ MoE aux losses). Returns (loss, metrics)."""
    logits, aux = forward(params, cfg, inputs, impl=impl, chunk=chunk,
                          rules=rules, remat=remat)
    labels = inputs["labels"]
    vp = cfg.padded_vocab_size
    if vp != cfg.vocab_size:
        # mask the vocab-padding columns out of the softmax (no gradient
        # flows into the zero-init pad rows of the head)
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32)
                           ).astype(logits.dtype)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    if cfg.n_codebooks == 1:
        oh = jax.nn.one_hot(labels, vp, dtype=logits.dtype)
        gold = jnp.einsum("...v,...v->...", logits, oh).astype(jnp.float32)
    else:
        oh = jax.nn.one_hot(labels, vp, dtype=logits.dtype)
        gold = jnp.einsum("bskv,bskv->bsk", logits, oh).astype(jnp.float32)
    ce = (lse - gold).mean()
    loss = ce
    metrics = {"ce": ce}
    if cfg.moe is not None:
        loss = loss + aux["lb_loss"] + aux["z_loss"]
        metrics.update({k: jnp.asarray(v) for k, v in aux.items()})
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (single-token serve step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Per-layer cache pytree, stacked on a leading layer axis.

    Sliding-window archs get a ring buffer of ``window`` entries; MLA caches
    the compressed latent; SSM archs carry O(1) state.
    """
    Lc = cfg.n_layers
    c = {}
    if cfg.attn_kind in ("gqa", "hybrid"):
        size = max_len
        if cfg.sliding_window is not None:
            size = min(max_len, cfg.sliding_window)
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        c["k"] = jnp.zeros((Lc, batch, size, hkv, hd), dtype)
        c["v"] = jnp.zeros((Lc, batch, size, hkv, hd), dtype)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        c["ckv"] = jnp.zeros((Lc, batch, max_len, m.kv_lora_rank), dtype)
        c["krope"] = jnp.zeros((Lc, batch, max_len, m.qk_rope_dim), dtype)
    if cfg.attn_kind in ("none", "hybrid"):
        s = cfg.ssm
        d_in, nh, conv_dim = L.ssm_dims(cfg)
        c["ssm"] = jnp.zeros((Lc, batch, nh, s.head_dim, s.d_state),
                             jnp.float32)
        c["conv"] = jnp.zeros((Lc, batch, s.d_conv - 1, conv_dim), dtype)
    return c


def cache_pspecs(cfg: ArchConfig, rules: ShardRules):
    """Decode caches: batch on the batch axes, long (sequence) dim on model —
    context-parallel decode keeps the 32k/500k caches within per-chip HBM."""
    b = rules.batch
    m = rules.model
    c = {}
    if cfg.attn_kind in ("gqa", "hybrid"):
        c["k"] = P(None, b, m, None, None)
        c["v"] = P(None, b, m, None, None)
    if cfg.attn_kind == "mla":
        c["ckv"] = P(None, b, m, None)
        c["krope"] = P(None, b, m, None)
    if cfg.attn_kind in ("none", "hybrid"):
        # nh (24/50) is not divisible by the model axis — SSM decode state
        # is batch-sharded only (it is O(1) per sequence anyway)
        c["ssm"] = P(None, b, None, None, None)
        c["conv"] = P(None, b, None, None)
    return c


def block_decode(lp, x, cache, length, cos, sin, cfg: ArchConfig,
                 rules: Optional[ShardRules] = None):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)

    def _ring(cache_k):
        """(write_idx, valid_len) for full or ring-buffer caches."""
        size = cache_k.shape[1]                     # (B, size, hkv, hd)
        if cfg.sliding_window is not None:
            return length % size, jnp.minimum(length + 1, size)
        return length, length + 1

    if cfg.attn_kind == "gqa":
        widx, valid = _ring(cache["k"])
        a, ck, cv = L.gqa_decode(lp["attn"], h, cache["k"], cache["v"],
                                 widx, valid, cos, sin, cfg)
        x = x + a
        cache = {"k": ck, "v": cv}
    elif cfg.attn_kind == "mla":
        a, ckv, kr = L.mla_decode(lp["attn"], h, cache["ckv"],
                                  cache["krope"], length, cos, sin, cfg)
        x = x + a
        cache = {"ckv": ckv, "krope": kr}
    elif cfg.attn_kind == "hybrid":
        widx, valid = _ring(cache["k"])
        sub = {"k": cache["k"], "v": cache["v"], "ssm": cache["ssm"],
               "conv": cache["conv"]}
        a, sub = L.hybrid_decode(lp["mixer"], h, sub, widx, valid, cos, sin,
                                 cfg)
        x = x + a
        cache = sub
    else:
        y, st, conv = L.ssm_decode(lp["ssm"], h, cache["ssm"], cache["conv"],
                                   cfg)
        x = x + y
        return x, {"ssm": st, "conv": conv}
    if cfg.moe is not None:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = L.moe_forward(
            lp["moe"], h2, cfg,
            shard_experts=(_expert_constraint(rules) if rules else None),
            groups=(rules.moe_groups if rules else 1))
        x = x + y
    elif cfg.d_ff:
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn_forward(lp["ffn"], h2, cfg.ffn_kind)
    return x, cache


def decode_step(params, cfg: ArchConfig, cache, inputs, *,
                rules: Optional[ShardRules] = None):
    """One serve step: new token at position ``inputs['length']``.

    inputs: tokens (B,1) or (B,1,K) / embeds (B,1,D); positions (3,B,1) for
    mrope; length scalar int32. Returns (logits, new_cache).
    """
    x = _embed_inputs(params, cfg, inputs)
    length = inputs["length"]
    if cfg.pos_kind == "mrope":
        cos, sin = L.mrope_cos_sin(inputs["positions"], _rope_dim(cfg),
                                   cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos_kind == "rope":
        cos, sin = L.rope_cos_sin(length[None], _rope_dim(cfg),
                                  cfg.rope_theta)
        cos, sin = cos[None], sin[None]             # (1,1,hd/2)
    else:
        cos = sin = None

    def body(h, xs):
        lp, cache_l = xs
        h, new_cache = block_decode(lp, h, cache_l, length, cos, sin, cfg,
                                    rules=rules)
        return h, new_cache

    x, new_cache = lax.scan(body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _logits(params, cfg, x, rules)
    return logits, new_cache
