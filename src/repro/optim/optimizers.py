"""Optimizers in pure JAX (no optax offline).

* AdamW — standard, fp32 or bf16 moments (``moment_dtype``).
* Adafactor — factored second moment, no first moment: the memory-fit choice
  for the ≥100B archs (340B params × Adam-fp32 moments would blow the 16 GB
  v5e HBM budget; factored moments are O(rows+cols)).

Each optimizer is an (init, update) pair over arbitrary pytrees, mirroring
the optax convention so swapping in optax later is a one-liner.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable       # params -> opt_state
    update: callable     # (grads, opt_state, params, step) -> (updates, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32)
        lr = lr_fn(step)
        bc1 = 1 - b1 ** (stepf + 1)
        bc2 = 1 - b2 ** (stepf + 1)

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
            nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
            u = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), mu32.astype(moment_dtype), \
                nu32.astype(moment_dtype)

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


def adafactor(lr_fn, eps=1e-30, clip_threshold=1.0, decay_pow=0.8,
              weight_decay=0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), beta1=0."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(per_leaf, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32)
        lr = lr_fn(step)
        beta2 = 1.0 - (stepf + 1) ** (-decay_pow)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p.shape):
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
                         )[..., None] * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv_ = beta2 * v["v"] + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(nv_ + eps)
                nv = {"v": nv_}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), nv

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        vflat = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(gflat, vflat, flat)]
        updates = jax.tree.unflatten(treedef, [o[0] for o in out])
        nv = jax.tree.unflatten(treedef, [o[1] for o in out])
        return updates, {"v": nv}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
