"""Gradient compression for the cross-pod (DCN) data-parallel reduction.

The ICI all-reduce inside a pod is cheap (~50 GB/s/link); the pod axis rides
on DCN where bandwidth is the scarce resource. We compress the pod-axis
gradient all-reduce to int8 with per-tensor scale + error feedback:

    q = round(g / s),  s = max|g| / 127        (per leaf)
    psum(q) over 'pod'  →  dequantize  →  average

Error feedback (Karimireddy et al. 2019) keeps the quantization residual in
the optimizer state and re-injects it next step, preserving convergence.

``compressed_psum`` must run under ``shard_map`` manual over the 'pod' axis
(the train step uses shard_map(auto={'data','model'}) when
``grad_compression='int8_pod'``). The DCN traffic drops 4x vs fp32 / 2x vs
bf16 per direction; §Perf records the measured collective-bytes delta.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def int8_compress(g):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, axis_name: str, error: jnp.ndarray | None = None):
    """int8 all-reduce-mean over ``axis_name`` with error feedback.

    Wire format stays int8 end-to-end: a naive ``psum(int32)`` would put
    4 B/elem on the DCN (2x WORSE than bf16 — §Perf measured exactly that
    on the first attempt). Instead:

        all_to_all(int8 chunks)  →  local dequant + sum  →  requantize
        →  all_gather(int8)

    = 2N int8 bytes on the wire vs ~4N for a bf16 ring all-reduce: 2x DCN
    reduction, 4x vs fp32. Error feedback keeps the local quantization
    residual; the reduced-chunk requantization error is O(1/127) of the
    already-averaged gradient.

    Returns (g_avg_f32, new_error). Call under shard_map manual over
    ``axis_name``.
    """
    g32 = g.astype(jnp.float32)
    if error is not None:
        g32 = g32 + error.astype(jnp.float32)
    p = compat.axis_size(axis_name)
    shape = g32.shape
    n = g32.size
    pad = (-n) % p
    flat = jnp.pad(g32.reshape(-1), (0, pad))

    scale_local = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale_local, axis_name)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    deq = q[:n].astype(jnp.float32).reshape(shape) * scale
    new_error = g32 - deq

    if p == 1:
        return q[:n].astype(jnp.float32).reshape(shape) * scale, new_error

    # scatter int8 chunks: row i goes to peer i
    chunks = q.reshape(p, -1)
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    # recv (p, chunk): peer contributions for MY chunk — dequant + sum
    local_sum = jnp.sum(recv.astype(jnp.float32), axis=0) * scale / p
    # requantize the reduced chunk and gather
    scale2_local = jnp.maximum(jnp.max(jnp.abs(local_sum)), 1e-12) / 127.0
    scale2 = jax.lax.pmax(scale2_local, axis_name)
    q2 = jnp.clip(jnp.round(local_sum / scale2), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis_name, axis=0)     # (p, chunk)
    out = gathered.reshape(-1)[:n].astype(jnp.float32) * scale2
    return out.reshape(shape), new_error


def tree_compressed_psum(grads, axis_name: str, errors=None):
    """Apply compressed_psum leaf-wise over a gradient pytree."""
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.bfloat16),
                              grads)
    out = jax.tree.map(
        lambda g, e: compressed_psum(g, axis_name, e), grads, errors)
    g_avg = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1].astype(jnp.bfloat16), out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return g_avg, new_err
