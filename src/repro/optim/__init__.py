from repro.optim.optimizers import (adafactor, adamw, make_optimizer,
                                    clip_by_global_norm, cosine_schedule)
from repro.optim.compression import (int8_compress, int8_decompress,
                                     compressed_psum)
