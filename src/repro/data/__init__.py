from repro.data.pipeline import (SyntheticLMDataset, TokenBatcher,
                                 make_batch_iterator)

__all__ = ["SyntheticLMDataset", "TokenBatcher", "make_batch_iterator"]
