"""LM token pipeline: synthetic corpus → packed sequences → sharded batches.

The training substrate for the assigned LM architectures. No external data
offline, so the corpus is synthetic but *structured* (a Zipf-distributed
Markov chain — non-trivial next-token statistics so a ~100M-param model's
loss visibly falls during the end-to-end example run).

* :class:`SyntheticLMDataset` — deterministic, seekable stream of "documents"
  (variable length), Zipf unigram frequencies + first-order Markov structure.
* packing — documents are concatenated with EOS separators and cut into
  fixed ``seq_len+1`` windows (inputs = [:-1], labels = [1:]), never padding.
* :class:`TokenBatcher` — yields {tokens, labels} numpy batches; with a mesh,
  ``make_batch_iterator`` device_puts them with the batch PartitionSpec, so
  the same iterator feeds 1-CPU smoke tests and the 512-chip dry-run mesh.
* multi-host ready: each data-parallel rank seeds its own stream
  (``shard_id``/``num_shards``) — no coordination needed, matching how the
  pilot abstraction gives each pod its own data pilot.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int = 32_000
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    doc_len_mean: int = 512
    zipf_a: float = 1.2
    n_codebooks: int = 1          # musicgen-style multi-codebook streams

    def __post_init__(self):
        self._rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.shard_id) & 0x7FFFFFFF)
        v = self.vocab_size
        # Zipf unigram distribution over the vocab (token 0 reserved = EOS)
        ranks = np.arange(1, v, dtype=np.float64)
        p = 1.0 / ranks ** self.zipf_a
        self._unigram = p / p.sum()
        # cheap first-order structure: each token deterministically biases
        # the next draw towards a "successor band" of the vocab
        self._band = 64

    def _sample_doc(self) -> np.ndarray:
        n = max(8, int(self._rng.exponential(self.doc_len_mean)))
        toks = np.empty((n,), np.int32)
        t = 1 + self._rng.choice(self.vocab_size - 1, p=self._unigram)
        for i in range(n):
            toks[i] = t
            if self._rng.random() < 0.7:       # stay in successor band
                lo = (t * 7919) % (self.vocab_size - self._band - 1) + 1
                t = lo + int(self._rng.integers(self._band))
            else:                               # re-draw from unigram
                t = 1 + self._rng.choice(self.vocab_size - 1,
                                         p=self._unigram)
        return toks

    def token_stream(self) -> Iterator[int]:
        while True:
            yield from self._sample_doc()
            yield 0                              # EOS separator


class TokenBatcher:
    """Packs the stream into (batch, seq_len) {tokens, labels} batches."""

    def __init__(self, dataset: SyntheticLMDataset, batch: int,
                 seq_len: int):
        self.ds = dataset
        self.batch = batch
        self.seq_len = seq_len
        self._stream = dataset.token_stream()

    def _window(self) -> np.ndarray:
        n = self.seq_len + 1
        return np.fromiter(self._stream, np.int32, count=n)

    def __iter__(self):
        return self

    def __next__(self):
        rows = np.stack([self._window() for _ in range(self.batch)])
        tokens, labels = rows[:, :-1], rows[:, 1:]
        if self.ds.n_codebooks > 1:
            k = self.ds.n_codebooks
            tokens = np.stack([(tokens + i) % self.ds.vocab_size
                               for i in range(k)], axis=-1)
            labels = np.stack([(labels + i) % self.ds.vocab_size
                               for i in range(k)], axis=-1)
        return {"tokens": tokens, "labels": labels}


def make_batch_iterator(cfg, batch: int, seq_len: int, *, seed: int = 0,
                        mesh=None, pspec_tree=None,
                        shard_id: int = 0, num_shards: int = 1):
    """Arch-aware iterator: emits the right input structure per config
    (tokens / codebook tokens / embedding stubs), optionally device_put
    with NamedShardings."""
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seed=seed,
                            shard_id=shard_id, num_shards=num_shards,
                            n_codebooks=cfg.n_codebooks)
    batcher = TokenBatcher(ds, batch, seq_len)
    rng = np.random.default_rng(seed + 17)

    def gen():
        for b in batcher:
            if cfg.input_mode == "embeddings":
                # vlm stub frontend: patch embeddings + M-RoPE positions
                b = {
                    "embeds": rng.standard_normal(
                        (batch, seq_len, cfg.d_model)).astype(np.float32),
                    "positions": np.tile(
                        np.arange(seq_len, dtype=np.int32)[None, None],
                        (3, batch, 1)),
                    "labels": b["labels"],
                }
            if mesh is not None and pspec_tree is not None:
                b = {
                    k: jax.device_put(
                        v, jax.sharding.NamedSharding(mesh, pspec_tree[k]))
                    for k, v in b.items()}
            yield b

    return gen()
