"""Configuration system for the repro framework.

Every assigned architecture is expressed as an :class:`ArchConfig`. Configs are
pure data (frozen dataclasses) so they can be hashed into jit caches and
serialized into checkpoints. ``reduced()`` derives the CPU-smoke-test variant
of any config; the full configs are only ever lowered (never allocated) by the
dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    dense_residual: bool = False  # arctic: parallel dense FFN path
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD state-space mixer config."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256              # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 => d_model // n_heads
    ffn_kind: str = "swiglu"       # swiglu | relu2 | gelu | none
    attn_kind: str = "gqa"         # gqa | mla | none | hybrid
    pos_kind: str = "rope"         # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple = (16, 24, 24)   # qwen2-vl (t, h, w) per-head-dim halves
    sliding_window: Optional[int] = None   # hymba local attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    n_codebooks: int = 1           # musicgen: 4 parallel EnCodec codebooks
    input_mode: str = "tokens"     # tokens | embeddings (vlm stub frontend)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # training knobs
    optimizer: str = "adamw"       # adamw | adafactor (huge archs)
    remat: bool = True
    # which shapes this arch supports (subset of SHAPES keys)
    skip_shapes: tuple = ()
    notes: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to 128 so the vocab-sharded embedding/head divide
        evenly on any mesh axis up to 128 (standard production practice:
        pad rows are zero-init and masked out of the loss)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab_size * d * self.n_codebooks          # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size * self.n_codebooks     # lm head
        n += d                                              # final norm
        n += L * self._block_params()
        return n

    @property
    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        inactive = (m.n_experts - m.top_k) * per_expert * self.n_layers
        return self.param_count - inactive

    def _block_params(self) -> int:
        d = self.d_model
        n = 2 * d  # two rms norms
        # --- attention ---
        if self.attn_kind == "gqa" or self.attn_kind == "hybrid":
            hd = self.head_dim
            n += d * self.n_heads * hd            # wq
            n += 2 * d * self.n_kv_heads * hd     # wk, wv
            n += self.n_heads * hd * d            # wo
        elif self.attn_kind == "mla":
            m = self.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            n += d * m.q_lora_rank + m.q_lora_rank               # wq_a + norm
            n += m.q_lora_rank * self.n_heads * qk               # wq_b
            n += d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d                 # wo
        # --- ssm (mamba2 / hybrid) ---
        if self.ssm is not None and self.attn_kind in ("none", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            n += d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
            n += conv_dim * s.d_conv + conv_dim                    # conv + bias
            n += 3 * nh                                            # A_log, D, dt_bias
            n += d_in                                              # gated norm
            n += d_in * d                                          # out_proj
        # --- ffn / moe ---
        mults = {"swiglu": 3, "relu2": 2, "gelu": 2, "none": 0}
        if self.moe is not None:
            n += d * self.moe.n_experts                            # router
            n += self.moe.n_experts * 3 * d * self.moe.d_expert    # swiglu experts
            if self.moe.dense_residual:
                n += mults[self.ffn_kind] * d * self.d_ff
        elif self.d_ff:
            n += mults[self.ffn_kind] * d * self.d_ff
        return n

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_expert=32)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
            kw["d_head"] = 0
        if self.sliding_window is not None:
            kw["sliding_window"] = 16
        if self.pos_kind == "mrope":
            kw["mrope_sections"] = (2, 3, 3)    # sums to head_dim//2 == 8
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # late import registers everything
        from repro import configs as _c  # noqa: F401
        import importlib
        importlib.import_module("repro.configs.all")
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    from repro.configs import all as _all  # noqa: F401
    return sorted(_REGISTRY)


def cells(arch: ArchConfig):
    """All (arch, shape) dry-run cells for this arch, with skip annotations."""
    out = []
    for s in SHAPES.values():
        skipped = s.name in arch.skip_shapes
        out.append((s, skipped))
    return out
