"""Config for nemotron-4-340b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import NEMOTRON_4_340B

CONFIG = NEMOTRON_4_340B
