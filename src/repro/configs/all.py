"""All assigned architecture configs (exact published dims) + paper workloads.

Each arch also lives in its own module (``repro.configs.<id>``) per the
required layout; those modules import from here so there is a single source
of truth.
"""
from repro.configs.base import (ArchConfig, MLAConfig, MoEConfig, SSMConfig,
                                register)

# Pure-full-attention archs skip the 524k decode cell (sub-quadratic required).
_FULL_ATTN_SKIP = ("long_500k",)

NEMOTRON_4_340B = register(ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73728, vocab_size=256000,
    ffn_kind="relu2", attn_kind="gqa", pos_kind="rope",
    optimizer="adafactor", skip_shapes=_FULL_ATTN_SKIP,
    notes="GQA kv=8, squared-ReLU FFN [arXiv:2402.16819]",
))

INTERNLM2_1_8B = register(ArchConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=92544,
    ffn_kind="swiglu", attn_kind="gqa", pos_kind="rope", rope_theta=1e6,
    skip_shapes=_FULL_ATTN_SKIP,
    notes="GQA [arXiv:2403.17297]",
))

MINICPM3_4B = register(ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    ffn_kind="swiglu", attn_kind="mla", pos_kind="rope",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
    skip_shapes=_FULL_ATTN_SKIP,
    notes="Multi-head Latent Attention [hf:openbmb/MiniCPM3-4B]",
))

MISTRAL_NEMO_12B = register(ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=131072,
    ffn_kind="swiglu", attn_kind="gqa", pos_kind="rope", rope_theta=1e6,
    skip_shapes=_FULL_ATTN_SKIP,
    notes="128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]",
))

MUSICGEN_MEDIUM = register(ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    ffn_kind="gelu", attn_kind="gqa", pos_kind="rope",
    n_codebooks=4,
    skip_shapes=_FULL_ATTN_SKIP,
    notes=("decoder-only over 4 EnCodec codebooks; frontend stubbed to "
           "codebook token ids [arXiv:2306.05284]"),
))

QWEN2_VL_2B = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab_size=151936,
    ffn_kind="swiglu", attn_kind="gqa", pos_kind="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24), input_mode="embeddings",
    skip_shapes=_FULL_ATTN_SKIP,
    notes=("M-RoPE, dynamic resolution; vision frontend stubbed to "
           "precomputed patch embeddings [arXiv:2409.12191]"),
))

MAMBA2_130M = register(ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ffn_kind="none", attn_kind="none", pos_kind="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
    notes="SSD (state-space duality) [arXiv:2405.21060]; long_500k runs",
))

HYMBA_1_5B = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab_size=32001,
    ffn_kind="swiglu", attn_kind="hybrid", pos_kind="rope",
    sliding_window=2048,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    notes=("parallel attn+mamba heads [arXiv:2411.13676]; SWA + SSM => "
           "sub-quadratic, long_500k runs"),
))

ARCTIC_480B = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864, vocab_size=32000,
    ffn_kind="swiglu", attn_kind="gqa", pos_kind="rope",
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864, dense_residual=True),
    optimizer="adafactor", skip_shapes=_FULL_ATTN_SKIP,
    notes="128 experts top-2 + parallel dense residual [hf:Snowflake/snowflake-arctic-base]",
))

QWEN3_MOE_235B = register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=0, vocab_size=151936,
    ffn_kind="none", attn_kind="gqa", pos_kind="rope", rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536, dense_residual=False),
    optimizer="adafactor", skip_shapes=_FULL_ATTN_SKIP,
    notes="128 experts top-8 [hf:Qwen/Qwen3-30B-A3B scaled family]",
))

ALL_ARCHS = [
    "nemotron-4-340b", "internlm2-1.8b", "minicpm3-4b", "mistral-nemo-12b",
    "musicgen-medium", "qwen2-vl-2b", "mamba2-130m", "hymba-1.5b",
    "arctic-480b", "qwen3-moe-235b-a22b",
]
