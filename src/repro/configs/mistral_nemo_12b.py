"""Config for mistral-nemo-12b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import MISTRAL_NEMO_12B

CONFIG = MISTRAL_NEMO_12B
