"""Config for minicpm3-4b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import MINICPM3_4B

CONFIG = MINICPM3_4B
