"""Config for arctic-480b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import ARCTIC_480B

CONFIG = ARCTIC_480B
