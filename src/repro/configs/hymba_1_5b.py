"""Config for hymba-1.5b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import HYMBA_1_5B

CONFIG = HYMBA_1_5B
