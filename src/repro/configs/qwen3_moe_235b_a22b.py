"""Config for qwen3-moe-235b-a22b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import QWEN3_MOE_235B

CONFIG = QWEN3_MOE_235B
