"""Architecture config registry."""
from repro.configs.base import (ArchConfig, MLAConfig, MoEConfig, SSMConfig,
                                ShapeConfig, SHAPES, get_arch, list_archs, cells)
from repro.configs.all import ALL_ARCHS  # noqa: F401 (registers everything)
