"""Config for qwen2-vl-2b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import QWEN2_VL_2B

CONFIG = QWEN2_VL_2B
