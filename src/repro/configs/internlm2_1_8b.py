"""Config for internlm2-1.8b (see repro.configs.all for the single source of truth)."""
from repro.configs.all import INTERNLM2_1_8B

CONFIG = INTERNLM2_1_8B
