"""Config for musicgen-medium (see repro.configs.all for the single source of truth)."""
from repro.configs.all import MUSICGEN_MEDIUM

CONFIG = MUSICGEN_MEDIUM
