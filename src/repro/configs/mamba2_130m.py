"""Config for mamba2-130m (see repro.configs.all for the single source of truth)."""
from repro.configs.all import MAMBA2_130M

CONFIG = MAMBA2_130M
