"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero device allocation — the dry-run lowers
against these. Modality frontends are stubs per the assignment: qwen2-vl gets
precomputed patch embeddings + M-RoPE position ids; musicgen gets EnCodec
codebook ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                      dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeddings":
        return {"embeds": SDS((b, s, cfg.d_model), dtype),
                "positions": SDS((3, b, s), jnp.int32),
                "labels": SDS((b, s), jnp.int32)}
    if cfg.n_codebooks > 1:
        return {"tokens": SDS((b, s, cfg.n_codebooks), jnp.int32),
                "labels": SDS((b, s, cfg.n_codebooks), jnp.int32)}
    return {"tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32)}


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                        dtype=jnp.bfloat16):
    spec = train_input_specs(cfg, shape, dtype)
    spec.pop("labels")
    return spec


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                       dtype=jnp.bfloat16):
    """serve_step inputs: one new token + a KV/SSM cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, b, s, dtype))
    if cfg.input_mode == "embeddings":
        inp = {"embeds": SDS((b, 1, cfg.d_model), dtype),
               "positions": SDS((3, b, 1), jnp.int32)}
    elif cfg.n_codebooks > 1:
        inp = {"tokens": SDS((b, 1, cfg.n_codebooks), jnp.int32)}
    else:
        inp = {"tokens": SDS((b, 1), jnp.int32)}
    inp["length"] = SDS((), jnp.int32)
    return inp, cache


def input_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """Dispatch by shape kind. Returns (inputs,) or (inputs, cache)."""
    if shape.kind == "train":
        return (train_input_specs(cfg, shape, dtype),)
    if shape.kind == "prefill":
        return (prefill_input_specs(cfg, shape, dtype),)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape, dtype)
    raise ValueError(shape.kind)


def input_pspecs(cfg: ArchConfig, rules):
    """PartitionSpecs matching train/prefill input structure."""
    from jax.sharding import PartitionSpec as P
    b = rules.batch
    if cfg.input_mode == "embeddings":
        return {"embeds": P(b, None, None), "positions": P(None, b, None),
                "labels": P(b, None)}
    if cfg.n_codebooks > 1:
        return {"tokens": P(b, None, None), "labels": P(b, None, None)}
    return {"tokens": P(b, None), "labels": P(b, None)}
