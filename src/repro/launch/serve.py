"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --reduced --requests 16 --prompt-len 32 --new-tokens 16

Instantiates a (reduced or full) model, spins up the slot-based
:class:`BatchServer`, pushes a stream of synthetic requests through it and
reports latency/throughput — the serving-side end-to-end example.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serve import BatchServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.input_mode == "embeddings":
        print("vlm serving uses the embedding frontend stub; "
              "pick a token arch")
        return 1
    params = T.init_params(jax.random.key(args.seed), cfg, jnp.float32)
    print(f"serving {cfg.name}: {cfg.param_count/1e6:.1f}M params, "
          f"{args.slots} slots")

    server = BatchServer(params, cfg, n_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        server.submit(Request(request_id=f"req-{i}", prompt=prompt,
                              max_new_tokens=args.new_tokens))
    done = server.run(max_requests=args.requests, idle_timeout_s=1.0)
    wall = time.monotonic() - t0

    lat_first = [r.t_first_token - r.t_submit for r in done
                 if r.t_first_token]
    lat_total = [r.t_done - r.t_submit for r in done if r.t_done]
    n_tok = sum(len(r.result_tokens) for r in done)
    print(f"completed {len(done)}/{args.requests} requests, "
          f"{n_tok} tokens in {wall:.2f}s "
          f"({n_tok / max(wall, 1e-9):,.1f} tok/s)")
    if lat_first:
        print(f"first-token latency: mean {np.mean(lat_first)*1e3:.1f} ms, "
              f"p95 {np.percentile(lat_first, 95)*1e3:.1f} ms")
        print(f"request latency:     mean {np.mean(lat_total)*1e3:.1f} ms, "
              f"p95 {np.percentile(lat_total, 95)*1e3:.1f} ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
