"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax

from repro.models.transformer import ShardRules


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    The 'pod' axis is the DCN tier — the edge↔cloud boundary of the
    Pilot-Edge continuum mapping; 'data' and 'model' ride the ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh, *, fsdp: bool = False, seq: bool = False,
               moe_groups: bool = True) -> ShardRules:
    """ShardRules matched to a mesh's axis names."""
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    groups = 1
    if moe_groups:
        for a in batch:
            groups *= mesh.shape[a]
    # model_size stays 1: it gates kv-projection replication in the param
    # pspecs, which §Perf measured as a net loss on every cell (training:
    # bwd all-reduce of dk/dv; decode: resharded cache writes). The
    # mechanism remains available by constructing ShardRules directly.
    return ShardRules(batch=batch,
                      model="model",
                      fsdp=("data" if fsdp else None),
                      seq=("model" if seq else None),
                      moe_groups=groups,
                      model_size=1)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many devices exist (tests)."""
    return jax.make_mesh(shape, axes)
