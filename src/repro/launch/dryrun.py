import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run driver.

For every (architecture × input shape) cell, lower + compile the appropriate
step function on the production mesh (single-pod 16x16 = 256 chips, and
multi-pod 2x16x16 = 512 chips), print memory/cost analysis, and emit the
roofline terms as JSON for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import SHAPES, get_arch, list_archs
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch import specs as S
from repro.models import transformer as T
from repro.roofline import analysis as R
from repro.train import step as TS


def arch_train_config(cfg: ArchConfig, overrides=None) -> TS.TrainConfig:
    """Per-arch defaults: microbatching + attention impl scale with size."""
    n = cfg.param_count
    micro = 8 if n > 100e9 else (4 if n > 10e9 else 1)
    kw = dict(
        microbatches=micro,
        accum_dtype="bfloat16" if n > 100e9 else "float32",
        attn_impl="dense",
        attn_chunk=1024,
    )
    if overrides:
        kw.update(overrides)
    return TS.TrainConfig(**kw)


def wants_fsdp(cfg: ArchConfig) -> bool:
    return cfg.param_count > 10e9


def _shard(mesh, spec_tree, shape_tree):
    return jax.tree.map(
        lambda _, s: NamedSharding(mesh, s), shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_axes_for(shape: ShapeConfig, mesh):
    """Drop batch axes that don't divide the global batch (e.g. long_500k
    with batch=1 stays unsharded)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    keep = []
    b = shape.global_batch
    for a in axes:
        n = mesh.shape[a]
        if b % n == 0:
            keep.append(a)
            b //= n
    return tuple(keep)


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               overrides=None, verbose=True, compression=False,
               seq_shard=False, fsdp: str = "auto", pipeline=False):
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    use_fsdp = {"auto": wants_fsdp(cfg), "on": True, "off": False}[fsdp]
    rules = make_rules(mesh, fsdp=use_fsdp, seq=seq_shard)
    batch_axes = _batch_axes_for(shape, mesh)
    # kv/q replication (model_size-aware pspecs) pays off only without a
    # backward pass: in training, the gradient of a replicated wk/wv needs
    # an activation-sized model-axis all-reduce that outweighs the saved
    # score partial-sums (§Perf, measured on qwen3). Serving has no bwd.
    eff_model_size = 1   # kv-replication refuted for decode too (see §Perf)
    rules = T.ShardRules(batch=batch_axes,
                         model=rules.model, fsdp=rules.fsdp, seq=rules.seq,
                         moe_groups=_prod(mesh, batch_axes),
                         model_size=eff_model_size)
    dtype = jnp.bfloat16
    t0 = time.time()

    with compat.set_mesh(mesh):
        return _lower_cell_inner(cfg, shape, arch_name, shape_name, mesh,
                                 chips, rules, dtype, t0, overrides,
                                 verbose, compression, pipeline)


def _lower_cell_inner(cfg, shape, arch_name, shape_name, mesh, chips, rules,
                      dtype, t0, overrides, verbose, compression,
                      pipeline=False):
    if pipeline:
        assert shape.kind == "train" and "pod" in mesh.axis_names, \
            "--pipeline needs a train shape on the multi-pod mesh"
        lowered = _lower_pipeline(cfg, shape, mesh, rules, dtype, overrides)
    elif shape.kind == "train":
        tc = arch_train_config(cfg, overrides)
        if shape.global_batch % (max(1, _prod(mesh, rules.batch))
                                 * tc.microbatches):
            tc = TS.TrainConfig(**{**tc.__dict__, "microbatches": 1})
        pshapes, sshapes = _train_shapes(cfg, tc, dtype)
        pspec, sspec = TS.train_state_pspecs(cfg, tc, rules, pshapes)
        bspec = S.input_pspecs(cfg, rules)
        (inputs,) = S.input_specs(cfg, shape, dtype)
        if compression:
            tc = TS.TrainConfig(**{**tc.__dict__,
                                   "grad_compression": "int8_pod"})
            pshapes, sshapes = _train_shapes(cfg, tc, dtype)
            pspec, sspec = TS.train_state_pspecs(cfg, tc, rules, pshapes)
            step = TS.make_compressed_train_step(cfg, tc, rules, mesh)
        else:
            step = TS.make_train_step(cfg, tc, rules)
        fn = jax.jit(
            step,
            in_shardings=(_shard(mesh, pspec, pshapes),
                          _shard(mesh, sspec, sshapes),
                          _shard(mesh, bspec, inputs)),
            out_shardings=(_shard(mesh, pspec, pshapes),
                           _shard(mesh, sspec, sshapes), None))
        lowered = fn.lower(pshapes, sshapes, inputs)
    elif shape.kind == "prefill":
        (inputs,) = S.input_specs(cfg, shape, dtype)
        pshapes = T.param_shapes(cfg, dtype)
        pspec = T.param_pspecs(cfg, rules)
        bspec = S.input_pspecs(cfg, rules)
        bspec.pop("labels", None)
        impl = "chunked" if shape.seq_len > 8192 else "dense"

        def prefill(params, batch):
            logits, _ = T.forward(params, cfg, batch, impl=impl,
                                  chunk=1024, rules=rules, remat=False)
            return logits

        fn = jax.jit(prefill,
                     in_shardings=(_shard(mesh, pspec, pshapes),
                                   _shard(mesh, bspec, inputs)),
                     out_shardings=None)
        lowered = fn.lower(pshapes, inputs)
    else:  # decode
        inputs, cache = S.input_specs(cfg, shape, dtype)
        pshapes = T.param_shapes(cfg, dtype)
        pspec = T.param_pspecs(cfg, rules)
        cspec = T.cache_pspecs(cfg, rules)
        ispec = {k: P(*((rules.batch,) + (None,) * (v.ndim - 1)))
                 if k not in ("length", "positions")
                 else (P() if k == "length" else P(None, rules.batch, None))
                 for k, v in inputs.items()}

        def serve_step(params, cache, batch):
            return T.decode_step(params, cfg, cache, batch, rules=rules)

        fn = jax.jit(serve_step,
                     in_shardings=(_shard(mesh, pspec, pshapes),
                                   _shard(mesh, cspec, cache),
                                   _shard(mesh, ispec, inputs)),
                     out_shardings=(None, _shard(mesh, cspec, cache)))
        lowered = fn.lower(pshapes, cache, inputs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = R.analyze_compiled(compiled, chips=chips)
    mem = R.parse_memory_analysis(compiled)
    roof = R.Roofline(
        arch=arch_name, shape=shape_name,
        mesh="x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        chips=chips, hlo_flops=cost["flops"], hlo_bytes=cost["bytes"],
        collective_bytes=cost["collective_bytes"],
        model_flops=R.model_flops(cfg, shape),
        per_device_hbm=(mem / chips if mem else None),
        dot_flops=cost["dot_flops"], coll_counts=cost["coll_counts"])
    if verbose:
        print(f"== {arch_name} x {shape_name} on {roof.mesh} "
              f"({chips} chips) ==")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: {compiled.memory_analysis()}")
        print(f"   hlo_flops={cost['flops']:.3e} "
              f"(dot {cost['dot_flops']:.3e}) bytes={cost['bytes']:.3e}")
        print(f"   collective_bytes={cost['collective_bytes']:.3e} "
              f"counts={cost['coll_counts']}")
        r = roof.row()
        print(f"   t_compute={r['t_compute_s']:.4f}s "
              f"t_memory={r['t_memory_s']:.4f}s "
              f"t_collective={r['t_collective_s']:.4f}s "
              f"-> bottleneck={r['bottleneck']}")
        print(f"   useful_flop_ratio={r['useful_flop_ratio']:.3f} "
              f"roofline_fraction={r['roofline_fraction']:.3f}")
    return roof


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _lower_pipeline(cfg, shape, mesh, rules, dtype, overrides):
    """GPipe over the 'pod' axis: blocks stage-sharded, TP inside stages."""
    from repro.train.pipeline import (PipelineConfig, init_pp_state,
                                      make_pp_train_step)
    tc = arch_train_config(cfg, overrides)
    pc = PipelineConfig(n_stages=mesh.shape["pod"],
                        microbatches=max(tc.microbatches, 4))
    # inner (per-stage) rules: data/model only
    inner = T.ShardRules(batch=tuple(a for a in rules.batch if a != "pod"),
                         model=rules.model, fsdp=rules.fsdp,
                         moe_groups=1)
    pshapes, sshapes = jax.eval_shape(
        lambda k: init_pp_state(k, cfg, tc, pc, dtype), jax.random.key(0))
    # shardings: blocks (S, L/S, ...) -> pod on dim0 + usual TP/FSDP inside
    base_pspec = T.param_pspecs(cfg, inner)

    def _shift(spec):
        return P(*(("pod",) + tuple(spec)))

    pspec = dict(base_pspec)
    pspec["blocks"] = jax.tree.map(_shift, base_pspec["blocks"])
    opt_like = sshapes["opt"]

    def _opt_spec(tree, under_blocks=False):
        if isinstance(tree, dict):
            return {k: _opt_spec(v, under_blocks or k == "blocks")
                    for k, v in tree.items()}
        return P("pod") if under_blocks else P()
    sspec = {"opt": _opt_spec(opt_like), "step": P()}
    (inputs,) = S.input_specs(cfg, shape, dtype)
    bspec = S.input_pspecs(cfg, inner)
    step = make_pp_train_step(cfg, tc, pc, inner, mesh)
    fn = jax.jit(step,
                 in_shardings=(_shard(mesh, pspec, pshapes),
                               _shard(mesh, sspec, sshapes),
                               _shard(mesh, bspec, inputs)),
                 out_shardings=(_shard(mesh, pspec, pshapes),
                                _shard(mesh, sspec, sshapes), None))
    return fn.lower(pshapes, sshapes, inputs)


def _train_shapes(cfg, tc, dtype):
    return TS.train_state_shapes(cfg, tc, dtype)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compression", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--pipeline", action="store_true",
                    help="GPipe over the pod axis (multi-pod train only)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--attn-impl", default=None)
    args = ap.parse_args(argv)

    overrides = {}
    if args.micro:
        overrides["microbatches"] = args.micro
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl

    cells = []
    if args.all:
        for a in list_archs():
            cfg = get_arch(a)
            for sname in SHAPES:
                if sname in cfg.skip_shapes:
                    print(f"-- skip {a} x {sname} "
                          f"(sub-quadratic requirement; see DESIGN.md)")
                    continue
                cells.append((a, sname))
    else:
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    rows, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            try:
                roof = lower_cell(arch, shape, multi_pod=mp,
                                  overrides=overrides or None,
                                  compression=args.compression,
                                  seq_shard=args.seq_shard,
                                  fsdp=args.fsdp,
                                  pipeline=args.pipeline)
                rows.append(roof.row())
            except Exception as e:  # noqa: BLE001 — report all failures
                failures.append((arch, shape, mp, repr(e)[:500]))
                print(f"!! FAIL {arch} x {shape} multi_pod={mp}: "
                      f"{repr(e)[:300]}", file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
    print(f"\n{len(rows)} cells OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
