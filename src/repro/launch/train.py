"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires every substrate together: config → data pipeline → mesh/shardings →
train step → checkpoint manager → metrics. On the CPU container use
``--reduced`` (tiny same-family config); on a TPU pod the same driver takes
the full config and the production mesh.

Fault tolerance: resumes from the latest checkpoint in --ckpt-dir if one
exists (restore reshards onto whatever mesh is alive — see ckpt/).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_arch
from repro.data import make_batch_iterator
from repro.models import transformer as T
from repro.train import step as TS


def train_loop(cfg, tc: TS.TrainConfig, *, steps: int, batch: int,
               seq_len: int, ckpt_dir=None, ckpt_every: int = 100,
               mesh=None, rules=None, seed: int = 0, log_every: int = 10,
               dtype=jnp.float32, log=print):
    """Returns (params, state, history)."""
    params, state = TS.init_train_state(jax.random.key(seed), cfg, tc,
                                        dtype)
    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        got = mgr.restore_latest({"params": params, "state": state})
        if got[0] is not None:
            start_step = got[0]
            params, state = got[1]["params"], got[1]["state"]
            log(f"resumed from step {start_step}")

    pspec_tree = None
    if mesh is not None and rules is not None:
        pspec_tree = TS.batch_pspec(cfg, rules)
    it = make_batch_iterator(cfg, batch, seq_len, seed=seed, mesh=mesh,
                             pspec_tree=pspec_tree)
    # deterministic resume: replay the stream to the restored step so a
    # resumed run sees exactly the batches a straight run would have seen
    for _ in range(start_step):
        next(it)
    step_fn = jax.jit(TS.make_train_step(cfg, tc, rules))

    history = []
    t0 = time.time()
    for i in range(start_step, steps):
        batch_data = next(it)
        params, state, metrics = step_fn(params, state, batch_data)
        if (i + 1) % log_every == 0 or i == start_step:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tok_s = (i + 1 - start_step) * batch * seq_len / max(dt, 1e-9)
            history.append({"step": i + 1, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "tok_per_s": tok_s})
            log(f"step {i+1:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"{tok_s:,.0f} tok/s")
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "state": state})
    if mgr:
        mgr.save(steps, {"params": params, "state": state})
        mgr.wait()
    return params, state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TS.TrainConfig(lr=args.lr, microbatches=args.micro,
                        total_steps=args.steps,
                        warmup=max(10, args.steps // 20))
    print(f"training {cfg.name}: {cfg.param_count/1e6:.1f}M params "
          f"({cfg.active_param_count/1e6:.1f}M active), "
          f"batch={args.batch} seq={args.seq}")
    _, _, history = train_loop(
        cfg, tc, steps=args.steps, batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, seed=args.seed)
    if history:
        first, last = history[0], history[-1]
        print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} over "
              f"{last['step'] - first['step']} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
