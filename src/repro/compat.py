"""JAX API compatibility layer.

The codebase targets the modern JAX surface (``jax.shard_map`` with
``check_vma``/``axis_names``, ``jax.set_mesh``); CPU containers in CI pin
older releases where those names live under ``jax.experimental`` or don't
exist.  Route every use through this module so version drift is absorbed
in exactly one place.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` when available, else the ``jax.experimental``
    spelling.  ``check_vma`` maps onto the old ``check_rep``; the old API
    treats every mesh axis as manual, so ``axis_names`` is meaningful only
    on new JAX (all our meshes are single-axis, where the two agree)."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma) if check_vma is not None
                      else True)


def axis_size(axis_name):
    """``jax.lax.axis_size`` when available; otherwise the classic
    ``psum(1, axis)`` spelling (constant-folded by XLA)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.set_mesh`` when available; older releases use the Mesh
    object's own context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
