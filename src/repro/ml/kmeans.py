"""Streaming (mini-batch) k-means in JAX — the paper's lightest workload
(25 clusters, §III.2).

The paper's pattern: "the model is updated based on the incoming data; model
updates are managed via the parameter service". We implement exactly that:

* ``assign(points)`` — nearest-centroid ids + distances (inference /
  outlier score). The assignment hot loop has a Pallas TPU kernel
  (kernels/kmeans.py) selected with ``impl='pallas'``; the jnp paths are
  numerically identical (kernels/ref.py *is* this math).
* ``update(points)`` / ``assign_update(points)`` — one mini-batch k-means
  step (Sculley 2010): per-seen-count learning rates, so repeated messages
  converge like the paper's streaming updates.  The step is *fused* with
  assignment: one pass over the points yields ids, distances and the
  per-centroid sums/counts the update needs.
* ``outlier_scores(points)`` — distance to the assigned centroid;
  thresholded at ``mean + 3·std`` of running distances.

Implementation axis (``impl``):

* ``"fused"`` (default) — single pass: distance expansion + scatter-add
  (``segment_sum``) membership statistics.  This is the lowering
  ``cost/calibrate.py`` rooflines, and the HLO-visible proxy for the
  fused Pallas kernel (custom-calls are free to the HLO cost model).
* ``"pallas"`` — the fused Pallas TPU kernel
  (:func:`repro.kernels.ops.kmeans_assign_update`).
* ``"jnp"`` — the historical two-pass path (assign, then an (N,K) one-hot
  matmul).  Kept as the parity/benchmark baseline.

Precision axis (``precision``): ``fp32`` | ``bf16`` | ``int8``.  The jnp
paths *simulate* the reduced-precision kernels bit-faithfully — bf16
rounds points/centroids to bfloat16, int8 fake-quantizes both with the
shared per-feature scales from :mod:`repro.kernels.quant` — so
``KMeans(impl='fused', precision='int8')`` and the int8 Pallas kernel
agree on assignments, and :func:`assignment_agreement` can score a
precision variant against the fp32 reference without TPU hardware.

State is a plain pytree ``{"centroids", "counts"}`` so it round-trips the
ParameterService and checkpoints unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

IMPLS = ("fused", "pallas", "jnp")
PRECISIONS = ("fp32", "bf16", "int8")


def _precision_view(centroids, points, precision: str):
    """The fp32 values a reduced-precision kernel actually computes on."""
    if precision == "fp32":
        return centroids, points
    if precision == "bf16":
        return (centroids.astype(jnp.bfloat16).astype(jnp.float32),
                points.astype(jnp.bfloat16).astype(jnp.float32))
    if precision == "int8":
        from repro.kernels import quant
        scales = quant.symmetric_scales(points, centroids)
        return (quant.fake_quantize(centroids, scales),
                quant.fake_quantize(points, scales))
    raise ValueError(f"precision must be one of {PRECISIONS}, "
                     f"got {precision!r}")


def _expansion_assign(centroids, points):
    # ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2 (MXU-matmul form)
    x2 = jnp.sum(points * points, axis=1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=1)
    d2 = x2 - 2.0 * points @ centroids.T + c2[None, :]
    d2 = jnp.maximum(d2, 0.0)
    ids = jnp.argmin(d2, axis=1)
    dmin = jnp.sqrt(jnp.take_along_axis(d2, ids[:, None], axis=1)[:, 0])
    return ids, dmin


@partial(jax.jit, static_argnames=("impl", "precision"))
def _assign(centroids, points, impl: str = "fused",
            precision: str = "fp32"):
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.kmeans_assign(points, centroids, precision=precision)
    centroids, points = _precision_view(centroids, points, precision)
    return _expansion_assign(centroids, points)


@partial(jax.jit, static_argnames=("impl", "precision"))
def _assign_update(centroids, counts, points, impl: str = "fused",
                   precision: str = "fp32"):
    """Fused mini-batch k-means step: one pass over ``points`` returns
    ``(new_centroids, new_counts, ids, dmin)``."""
    k = centroids.shape[0]
    if impl == "pallas":
        from repro.kernels import ops as kops
        ids, dmin, sums, batch_counts = kops.kmeans_assign_update(
            points, centroids, precision=precision)
    else:
        # sums accumulate the *precision view* of the points, not the raw
        # fp32 values: a quantized kernel only ever holds quantized data,
        # so the bit-faithful sim must update centroids from the same
        # dequantized values the kernel sums in VMEM
        cv, pv = _precision_view(centroids, points, precision)
        ids, dmin = _expansion_assign(cv, pv)
        if impl == "jnp":
            # historical two-pass baseline: assign, then an (N,K) one-hot
            # materialization and a (K,N)@(N,F) matmul
            onehot = jax.nn.one_hot(ids, k, dtype=jnp.float32)
            batch_counts = onehot.sum(0)                      # (K,)
            sums = onehot.T @ pv                              # (K,F)
        else:
            # fused jnp: same distance pass, scatter-add membership stats
            # — the one-pass formulation the Pallas kernel implements on
            # TPU, and the HLO-visible lowering calibrate.py rooflines
            sums = jax.ops.segment_sum(pv, ids, num_segments=k)
            batch_counts = jax.ops.segment_sum(
                jnp.ones((points.shape[0],), jnp.float32), ids,
                num_segments=k)
    new_counts = counts + batch_counts
    lr = jnp.where(batch_counts > 0, batch_counts /
                   jnp.maximum(new_counts, 1.0), 0.0)[:, None]
    means = sums / jnp.maximum(batch_counts, 1.0)[:, None]
    new_centroids = centroids * (1.0 - lr) + means * lr
    return new_centroids, new_counts, ids, dmin


def _update(centroids, counts, points, impl: str = "fused",
            precision: str = "fp32"):
    """Mini-batch k-means step (per-count learning rate).  Threads
    ``impl``/``precision`` through to the fused step — historically this
    re-ran ``_assign`` with the *default* impl, silently bypassing the
    Pallas kernel for ``KMeans(impl='pallas')`` updates."""
    new_centroids, new_counts, _, _ = _assign_update(
        centroids, counts, points, impl=impl, precision=precision)
    return new_centroids, new_counts


@dataclass
class KMeans:
    n_clusters: int = 25
    n_features: int = 32
    seed: int = 0
    impl: str = "fused"             # fused | pallas | jnp
    precision: str = "fp32"         # fp32 | bf16 | int8

    def init(self, sample: Optional[np.ndarray] = None):
        if sample is not None and len(sample) >= self.n_clusters:
            idx = np.random.default_rng(self.seed).choice(
                len(sample), self.n_clusters, replace=False)
            cent = jnp.asarray(sample[idx], jnp.float32)
        else:
            cent = jax.random.normal(
                jax.random.key(self.seed),
                (self.n_clusters, self.n_features)) * 5.0
        return {"centroids": cent,
                "counts": jnp.zeros((self.n_clusters,), jnp.float32)}

    def assign(self, state, points) -> Tuple[jnp.ndarray, jnp.ndarray]:
        pts = jnp.asarray(points, jnp.float32)
        return _assign(state["centroids"], pts, impl=self.impl,
                       precision=self.precision)

    def update(self, state, points):
        pts = jnp.asarray(points, jnp.float32)
        cent, counts = _update(state["centroids"], state["counts"], pts,
                               impl=self.impl, precision=self.precision)
        return {"centroids": cent, "counts": counts}

    def assign_update(self, state, points):
        """One fused pass: (new_state, ids, dmin) — the streaming hot
        path ``make_processor`` runs per message."""
        pts = jnp.asarray(points, jnp.float32)
        cent, counts, ids, dmin = _assign_update(
            state["centroids"], state["counts"], pts,
            impl=self.impl, precision=self.precision)
        return {"centroids": cent, "counts": counts}, ids, dmin

    def outlier_scores(self, state, points) -> jnp.ndarray:
        _, d = self.assign(state, points)
        return d

    def inertia(self, state, points) -> float:
        _, d = self.assign(state, points)
        return float(jnp.sum(d * d))

    def make_processor(self, param_service=None, model_name: str = "kmeans",
                       train: bool = True):
        """FaaS ``process_cloud`` handler: score + (optionally) update +
        publish to the parameter service — the paper's model-update loop.
        Training messages take the *fused* path: one assign+update pass
        yields the outlier scores and the centroid step together."""
        holder = {"state": None, "version": 0}

        def process_cloud(context, data=None):
            pts = np.asarray(data, np.float64)
            if holder["state"] is None:
                if param_service is not None and model_name in \
                        param_service.names():
                    v, tree = param_service.fetch(model_name)
                    holder["state"] = jax.tree.map(jnp.asarray, tree)
                    holder["version"] = v
                else:
                    holder["state"] = self.init(pts)
            elif param_service is not None:
                newer = param_service.fetch_if_newer(
                    model_name, holder["version"])
                if newer is not None:
                    holder["version"] = newer[0]
                    holder["state"] = jax.tree.map(jnp.asarray, newer[1])
            if train:
                holder["state"], _, scores = self.assign_update(
                    holder["state"], pts)
                if param_service is not None:
                    holder["version"] = param_service.publish(
                        model_name, holder["state"])
            else:
                scores = self.outlier_scores(holder["state"], pts)
            s = np.asarray(scores)
            thresh = s.mean() + 3.0 * s.std()
            return {"n_outliers": int((s > thresh).sum()),
                    "mean_score": float(s.mean())}

        return process_cloud


def assignment_agreement(precision: str, *, n_points: int = 2_500,
                         n_features: int = 32, n_clusters: int = 25,
                         seed: int = 0, n_warmup: int = 10) -> float:
    """Fraction of points a reduced-precision variant assigns to the same
    centroid as the fp32 reference, on a fixed MiniAppGenerator probe —
    the accuracy column the placement advisor stamps on precision cells.

    Measured after ``n_warmup`` streaming updates so the centroids are
    near-converged (the steady state a long-running pipeline prices);
    fresh-seeded centroids would put arbitrarily many points on Voronoi
    boundaries and understate every variant.  Deterministic (fixed probe,
    jnp simulation paths) and cached."""
    key = (precision, n_points, n_features, n_clusters, seed, n_warmup)
    hit = _AGREEMENT_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.ml.datagen import MiniAppGenerator
    gen = MiniAppGenerator(n_points=n_points, n_features=n_features,
                           n_clusters=n_clusters, seed=seed)
    pts = gen.sample()
    model = KMeans(n_clusters=n_clusters, n_features=n_features, seed=seed)
    state = model.init(pts)
    for _ in range(n_warmup):
        state = model.update(state, gen.sample())
    probe = jnp.asarray(pts, jnp.float32)
    ref_ids, _ = _assign(state["centroids"], probe, impl="fused",
                         precision="fp32")
    ids, _ = _assign(state["centroids"], probe, impl="fused",
                     precision=precision)
    agree = float(jnp.mean((ids == ref_ids).astype(jnp.float32)))
    _AGREEMENT_CACHE[key] = agree
    return agree


_AGREEMENT_CACHE: dict = {}
