"""Streaming (mini-batch) k-means in JAX — the paper's lightest workload
(25 clusters, §III.2).

The paper's pattern: "the model is updated based on the incoming data; model
updates are managed via the parameter service". We implement exactly that:

* ``assign(points)`` — nearest-centroid ids + distances (inference /
  outlier score). The assignment hot loop has a Pallas TPU kernel
  (kernels/kmeans.py) selected with ``impl='pallas'``; the default jnp path
  is numerically identical (kernels/ref.py *is* this math).
* ``update(points)`` — one mini-batch k-means step (Sculley 2010): per-seen-
  count learning rates, so repeated messages converge like the paper's
  streaming updates.
* ``outlier_scores(points)`` — distance to the assigned centroid; thresholded
  at ``mean + 3·std`` of running distances.

State is a plain pytree ``{"centroids", "counts"}`` so it round-trips the
ParameterService and checkpoints unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("impl",))
def _assign(centroids, points, impl: str = "jnp"):
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.kmeans_assign(points, centroids)
    # ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2 (MXU-matmul form)
    x2 = jnp.sum(points * points, axis=1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=1)
    d2 = x2 - 2.0 * points @ centroids.T + c2[None, :]
    d2 = jnp.maximum(d2, 0.0)
    ids = jnp.argmin(d2, axis=1)
    dmin = jnp.sqrt(jnp.take_along_axis(d2, ids[:, None], axis=1)[:, 0])
    return ids, dmin


@jax.jit
def _update(centroids, counts, points):
    """Mini-batch k-means step (per-count learning rate)."""
    ids, _ = _assign(centroids, points)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(ids, k, dtype=points.dtype)          # (N,K)
    batch_counts = onehot.sum(0)                                  # (K,)
    sums = onehot.T @ points                                      # (K,F)
    new_counts = counts + batch_counts
    lr = jnp.where(batch_counts > 0, batch_counts /
                   jnp.maximum(new_counts, 1.0), 0.0)[:, None]
    means = sums / jnp.maximum(batch_counts, 1.0)[:, None]
    new_centroids = centroids * (1.0 - lr) + means * lr
    return new_centroids, new_counts


@dataclass
class KMeans:
    n_clusters: int = 25
    n_features: int = 32
    seed: int = 0
    impl: str = "jnp"               # jnp | pallas

    def init(self, sample: Optional[np.ndarray] = None):
        if sample is not None and len(sample) >= self.n_clusters:
            idx = np.random.default_rng(self.seed).choice(
                len(sample), self.n_clusters, replace=False)
            cent = jnp.asarray(sample[idx], jnp.float32)
        else:
            cent = jax.random.normal(
                jax.random.key(self.seed),
                (self.n_clusters, self.n_features)) * 5.0
        return {"centroids": cent,
                "counts": jnp.zeros((self.n_clusters,), jnp.float32)}

    def assign(self, state, points) -> Tuple[jnp.ndarray, jnp.ndarray]:
        pts = jnp.asarray(points, jnp.float32)
        return _assign(state["centroids"], pts, impl=self.impl)

    def update(self, state, points):
        pts = jnp.asarray(points, jnp.float32)
        cent, counts = _update(state["centroids"], state["counts"], pts)
        return {"centroids": cent, "counts": counts}

    def outlier_scores(self, state, points) -> jnp.ndarray:
        _, d = self.assign(state, points)
        return d

    def inertia(self, state, points) -> float:
        _, d = self.assign(state, points)
        return float(jnp.sum(d * d))

    def make_processor(self, param_service=None, model_name: str = "kmeans",
                       train: bool = True):
        """FaaS ``process_cloud`` handler: score + (optionally) update +
        publish to the parameter service — the paper's model-update loop."""
        holder = {"state": None, "version": 0}

        def process_cloud(context, data=None):
            pts = np.asarray(data, np.float64)
            if holder["state"] is None:
                if param_service is not None and model_name in \
                        param_service.names():
                    v, tree = param_service.fetch(model_name)
                    holder["state"] = jax.tree.map(jnp.asarray, tree)
                    holder["version"] = v
                else:
                    holder["state"] = self.init(pts)
            elif param_service is not None:
                newer = param_service.fetch_if_newer(
                    model_name, holder["version"])
                if newer is not None:
                    holder["version"] = newer[0]
                    holder["state"] = jax.tree.map(jnp.asarray, newer[1])
            scores = self.outlier_scores(holder["state"], pts)
            if train:
                holder["state"] = self.update(holder["state"], pts)
                if param_service is not None:
                    holder["version"] = param_service.publish(
                        model_name, holder["state"])
            s = np.asarray(scores)
            thresh = s.mean() + 3.0 * s.std()
            return {"n_outliers": int((s > thresh).sum()),
                    "mean_score": float(s.mean())}

        return process_cloud
