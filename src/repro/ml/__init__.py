"""Paper ML workloads (§III.2) in pure JAX: k-means, isolation forest,
auto-encoder — the three outlier-detection models Pilot-Edge characterizes —
plus the Mini-App synthetic data generator [11]."""
from repro.ml.autoencoder import AutoEncoder
from repro.ml.datagen import MiniAppGenerator, message_nbytes
from repro.ml.isoforest import IsolationForest
from repro.ml.kmeans import KMeans

__all__ = ["AutoEncoder", "IsolationForest", "KMeans", "MiniAppGenerator",
           "message_nbytes"]
