"""Mini-App synthetic data generator (paper §III: "Synthetic data is
generated using the Mini-App data generator [11]").

Messages are blocks of ``n_points × n_features`` float64 points — the paper
uses 25–10,000 points × 32 features, 8 B/value serialized, i.e. 7 KB–2.6 MB
per message. Data is drawn from a Gaussian-mixture of ``n_clusters`` centers
(the k-means workload's 25 clusters) with a configurable fraction of uniform
outliers, so the three outlier detectors have actual outliers to find.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# the paper's message-size sweep
PAPER_POINTS = (25, 250, 2_500, 10_000)
N_FEATURES = 32
BYTES_PER_VALUE = 8


def message_nbytes(n_points: int, n_features: int = N_FEATURES) -> int:
    """Serialized payload size, paper accounting (8 B/value)."""
    return n_points * n_features * BYTES_PER_VALUE


@dataclass
class MiniAppGenerator:
    n_points: int = 2_500
    n_features: int = N_FEATURES
    n_clusters: int = 25
    outlier_frac: float = 0.02
    cluster_std: float = 1.0
    spread: float = 10.0          # cluster-center box half-width
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    centers: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.centers = self._rng.uniform(
            -self.spread, self.spread,
            size=(self.n_clusters, self.n_features))

    def sample(self, n_points: Optional[int] = None) -> np.ndarray:
        """One message: (n_points, n_features) float64, ~outlier_frac
        uniform-box outliers mixed in."""
        n = n_points if n_points is not None else self.n_points
        which = self._rng.integers(0, self.n_clusters, size=n)
        pts = (self.centers[which]
               + self._rng.normal(0.0, self.cluster_std,
                                  size=(n, self.n_features)))
        n_out = int(round(self.outlier_frac * n))
        if n_out:
            idx = self._rng.choice(n, size=n_out, replace=False)
            pts[idx] = self._rng.uniform(-4 * self.spread, 4 * self.spread,
                                         size=(n_out, self.n_features))
        return pts

    def sample_with_labels(self, n_points: Optional[int] = None):
        """(points, is_outlier) for detector-quality checks."""
        n = n_points if n_points is not None else self.n_points
        pts = self.sample(n)
        # recompute outlier mask by distance to nearest center. Inliers sit
        # at ~std*sqrt(F) from their center (chi distribution), so 3x that
        # radius cleanly separates the uniform-box outliers.
        d = np.linalg.norm(pts[:, None, :] - self.centers[None], axis=-1)
        is_out = d.min(axis=1) > 3.0 * self.cluster_std * np.sqrt(
            self.n_features)
        return pts, is_out

    def make_producer(self, n_points: Optional[int] = None):
        """FaaS ``produce_edge`` handler bound to this generator."""
        def produce_edge(context):
            return self.sample(n_points)
        return produce_edge
