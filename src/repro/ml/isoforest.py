"""Isolation forest in JAX — the paper's mid-complexity workload (§III.2).

"Isolation forests [17] are an ensemble technique where each task partitions
the dataset randomly into trees. An outlier is defined by the number of steps
required to isolate a data point ... We use the PyOD [18] implementation and
a default of 100 ensemble tasks."

PyOD wraps sklearn's IsolationForest: 100 trees, subsample ψ=256,
max_depth=⌈log₂ψ⌉=8. We build the forest *vectorized*: trees are heap-layout
arrays (feature/threshold/leaf-size per node), constructed level-by-level
with masked segment min/max (no data-dependent recursion — JAX-native), and
vmapped over the 100 trees. Scoring descends all trees in lockstep with
``lax.fori_loop``.

Anomaly score (Liu et al. 2008): s(x) = 2^(−E[h(x)]/c(ψ)), where h(x) is
path length + c(leaf_size) continuation, c(n) = 2H(n−1) − 2(n−1)/n.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

EULER_GAMMA = 0.5772156649015329


def _c(n):
    """Average unsuccessful-search path length in a BST of n nodes."""
    n = jnp.asarray(n, jnp.float32)
    h = jnp.log(jnp.maximum(n - 1.0, 1.0)) + EULER_GAMMA
    return jnp.where(n > 1.0, 2.0 * h - 2.0 * (n - 1.0) / n, 0.0)


def _build_tree(key, pts, max_depth: int):
    """One isolation tree over pts (psi, F) — heap arrays of size
    2^(max_depth+1)-1. Returns dict(feature, threshold, is_leaf, size).

    Level-synchronous construction with *segment* ops: each point knows its
    node; per-node min/max of the (randomly chosen) split feature are
    ``segment_min/max`` over node ids — O(psi) per level, no
    (psi × nodes × features) mask blow-up.
    """
    psi, F = pts.shape
    n_nodes = 2 ** (max_depth + 1) - 1
    first_leaf = 2 ** max_depth - 1          # nodes at the bottom level

    feature = jnp.zeros((n_nodes,), jnp.int32)
    threshold = jnp.zeros((n_nodes,), jnp.float32)
    is_leaf = jnp.zeros((n_nodes,), bool)
    size = jnp.zeros((n_nodes,), jnp.float32).at[0].set(psi)
    assign = jnp.zeros((psi,), jnp.int32)    # every point starts at root

    def level(d, carry):
        feature, threshold, is_leaf, size, assign, key = carry
        start = 2 ** d - 1
        width = 2 ** d
        key, kf, kt = jax.random.split(key, 3)
        local = assign - start
        valid = (local >= 0) & (local < width)
        seg = jnp.where(valid, local, width)             # invalid -> dump
        feat = jax.random.randint(kf, (width,), 0, F)    # per-node feature
        # each point's value of ITS node's split feature
        my_feat = feat[jnp.clip(local, 0, width - 1)]
        val = jnp.take_along_axis(pts, my_feat[:, None], 1)[:, 0]
        lo = jax.ops.segment_min(jnp.where(valid, val, jnp.inf), seg,
                                 num_segments=width + 1)[:width]
        hi = jax.ops.segment_max(jnp.where(valid, val, -jnp.inf), seg,
                                 num_segments=width + 1)[:width]
        counts = jax.ops.segment_sum(valid.astype(jnp.float32), seg,
                                     num_segments=width + 1)[:width]
        u = jax.random.uniform(kt, (width,))
        thr = lo + u * (hi - lo)
        # a node is splittable if >1 point and the chosen feature varies
        splittable = (counts > 1.0) & (hi > lo)
        node_ids = start + jnp.arange(width)
        feature = feature.at[node_ids].set(feat)
        threshold = threshold.at[node_ids].set(thr)
        is_leaf = is_leaf.at[node_ids].set(~splittable)
        # route points: left = 2i+1, right = 2i+2; points at leaves stay
        my_leaf = is_leaf[assign] | (assign < start)     # already settled
        go_left = val <= threshold[assign]
        child = jnp.where(go_left, 2 * assign + 1, 2 * assign + 2)
        new_assign = jnp.where(my_leaf | ~valid, assign, child)
        # record child sizes
        width2 = 2 * width
        start2 = 2 ** (d + 1) - 1
        local2 = new_assign - start2
        valid2 = (local2 >= 0) & (local2 < width2)
        seg2 = jnp.where(valid2, local2, width2)
        counts2 = jax.ops.segment_sum(valid2.astype(jnp.float32), seg2,
                                      num_segments=width2 + 1)[:width2]
        size = size.at[start2 + jnp.arange(width2)].set(counts2)
        return feature, threshold, is_leaf, size, new_assign, key

    carry = (feature, threshold, is_leaf, size, assign, key)
    for d in range(max_depth):          # static unroll: max_depth small (8)
        carry = level(d, carry)
    feature, threshold, is_leaf, size, assign, key = carry
    # bottom-level nodes are leaves by construction
    is_leaf = is_leaf.at[first_leaf:].set(True)
    return {"feature": feature, "threshold": threshold,
            "is_leaf": is_leaf, "size": size}


def _path_length(tree, x, max_depth: int):
    """Expected path length of points x (N,F) through one tree."""
    n = x.shape[0]

    def step(d, carry):
        node, depth, done = carry
        feat = tree["feature"][node]
        thr = tree["threshold"][node]
        leaf = tree["is_leaf"][node]
        newly_done = leaf & ~done
        go_left = jnp.take_along_axis(x, feat[:, None], 1)[:, 0] <= thr
        child = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
        node = jnp.where(leaf | done, node, child)
        depth = jnp.where(done | newly_done, depth, depth + 1)
        return node, depth, done | newly_done

    node = jnp.zeros((n,), jnp.int32)
    depth = jnp.zeros((n,), jnp.float32)
    done = jnp.zeros((n,), bool)
    node, depth, done = jax.lax.fori_loop(0, max_depth, step,
                                          (node, depth, done))
    leaf_size = tree["size"][node]
    return depth + _c(leaf_size)


@partial(jax.jit, static_argnames=("max_depth",))
def _score(forest, x, psi, max_depth: int):
    pl = jax.vmap(lambda t: _path_length(t, x, max_depth))(forest)
    eh = pl.mean(0)
    return jnp.power(2.0, -eh / jnp.maximum(_c(psi), 1e-6))


@partial(jax.jit, static_argnames=("n_trees", "psi", "max_depth"))
def _fit(key, pts, n_trees: int, psi: int, max_depth: int):
    n = pts.shape[0]
    ks = jax.random.split(key, n_trees)

    def one(k):
        k1, k2 = jax.random.split(k)
        idx = jax.random.randint(k1, (psi,), 0, n)
        return _build_tree(k2, pts[idx], max_depth)

    return jax.vmap(one)(ks)


@dataclass
class IsolationForest:
    n_trees: int = 100
    psi: int = 256                 # subsample size (sklearn default)
    seed: int = 0

    @property
    def max_depth(self) -> int:
        return int(np.ceil(np.log2(self.psi)))

    def fit(self, points):
        pts = jnp.asarray(points, jnp.float32)
        psi = min(self.psi, pts.shape[0])
        forest = _fit(jax.random.key(self.seed), pts, self.n_trees,
                      psi, self.max_depth)
        return {"forest": forest, "psi": jnp.float32(psi)}

    def outlier_scores(self, state, points):
        pts = jnp.asarray(points, jnp.float32)
        return _score(state["forest"], pts, state["psi"], self.max_depth)

    def make_processor(self, param_service=None, model_name: str = "iforest",
                       train: bool = True):
        """FaaS handler: refit on each message (the paper's streaming
        model-update pattern — 100 ensemble tasks per message)."""
        holder = {"state": None, "version": 0}

        def process_cloud(context, data=None):
            pts = np.asarray(data, np.float64)
            if holder["state"] is None and param_service is not None \
                    and model_name in param_service.names():
                v, tree = param_service.fetch(model_name)
                holder["state"] = jax.tree.map(jnp.asarray, tree)
                holder["version"] = v
            if train or holder["state"] is None:
                holder["state"] = self.fit(pts)
                if param_service is not None:
                    holder["version"] = param_service.publish(
                        model_name, holder["state"])
            scores = np.asarray(
                self.outlier_scores(holder["state"], pts))
            return {"n_outliers": int((scores > 0.6).sum()),
                    "mean_score": float(scores.mean())}

        return process_cloud
