"""Auto-encoder outlier detector — the paper's heaviest workload (§III.2).

"We use the Keras-based auto-encoder implementation of PyOD with four hidden
layers with a size of [64, 32, 32, 64], and thus, a total number of 11,552
parameters."

PyOD's (Keras-era) builder prepends an input-width layer and appends the
reconstruction layer, so hidden_neurons=[64,32,32,64] over 32 features
yields dense sizes [32, 64, 32, 32, 64, 32] + output(32):

    32→32 (1,056) + 32→64 (2,112) + 64→32 (2,080) + 32→32 (1,056)
    + 32→64 (2,112) + 64→32 (2,080) + 32→32 (1,056)  =  11,552  ✓

We reproduce exactly that topology in JAX (ReLU hidden activations, linear
output, MSE reconstruction loss) with Adam; the outlier score is the
per-point reconstruction error, as in PyOD.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import make_optimizer


def _layer_sizes(n_features: int, hidden: Tuple[int, ...]):
    """PyOD topology (see module doc): input F, dense widths
    [F, *hidden, F], then the reconstruction output F — seven dense layers
    for hidden=(64,32,32,64), 11,552 params at F=32."""
    return [n_features, n_features, *hidden, n_features, n_features]
    # sizes[0] is the input width; the rest are layer output widths.


def ae_init(key, n_features: int = 32,
            hidden: Tuple[int, ...] = (64, 32, 32, 64)):
    sizes = _layer_sizes(n_features, hidden)
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, din, dout in zip(keys, sizes[:-1], sizes[1:]):
        w = jax.random.normal(k, (din, dout)) * jnp.sqrt(2.0 / din)
        params.append({"w": w.astype(jnp.float32),
                       "b": jnp.zeros((dout,), jnp.float32)})
    return params


def ae_param_count(params) -> int:
    return sum(int(np.prod(p["w"].shape)) + int(p["b"].shape[0])
               for p in params)


@jax.jit
def ae_forward(params, x):
    h = x
    for i, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


@jax.jit
def ae_recon_error(params, x):
    """Per-point L2 reconstruction error — the PyOD outlier score."""
    r = ae_forward(params, x)
    return jnp.sqrt(jnp.sum((r - x) ** 2, axis=-1))


@jax.jit
def ae_loss(params, x):
    r = ae_forward(params, x)
    return jnp.mean((r - x) ** 2)


@dataclass
class AutoEncoder:
    n_features: int = 32
    hidden: Tuple[int, ...] = (64, 32, 32, 64)
    lr: float = 1e-3
    epochs_per_batch: int = 1
    seed: int = 0

    def __post_init__(self):
        self._opt = make_optimizer("adamw", lambda s: self.lr,
                                   weight_decay=0.0)
        self._step = jax.jit(self._make_step())

    def _make_step(self):
        opt = self._opt

        def step(params, opt_state, stepno, x):
            grads = jax.grad(ae_loss)(params, x)
            updates, new_opt = opt.update(grads, opt_state, params, stepno)
            new_params = jax.tree.map(lambda p, u: p + u, params, updates)
            return new_params, new_opt, ae_loss(new_params, x)
        return step

    def init(self):
        params = ae_init(jax.random.key(self.seed), self.n_features,
                         self.hidden)
        return {"params": params, "opt": self._opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, state, points):
        x = self._norm(points)
        params, opt, stepno = state["params"], state["opt"], state["step"]
        loss = None
        for _ in range(self.epochs_per_batch):
            params, opt, loss = self._step(params, opt, stepno, x)
            stepno = stepno + 1
        return {"params": params, "opt": opt, "step": stepno}, float(loss)

    def outlier_scores(self, state, points):
        return ae_recon_error(state["params"], self._norm(points))

    @staticmethod
    def _norm(points):
        x = jnp.asarray(points, jnp.float32)
        mu = x.mean(0, keepdims=True)
        sd = x.std(0, keepdims=True) + 1e-6
        return (x - mu) / sd

    def make_processor(self, param_service=None, model_name: str = "ae",
                       train: bool = True):
        holder = {"state": None, "version": 0}

        def process_cloud(context, data=None):
            pts = np.asarray(data, np.float64)
            if holder["state"] is None:
                if (param_service is not None
                        and model_name in param_service.names()):
                    v, tree = param_service.fetch(model_name)
                    holder["state"] = jax.tree.map(jnp.asarray, tree)
                    holder["version"] = v
                else:
                    holder["state"] = self.init()
            scores = self.outlier_scores(holder["state"], pts)
            if train:
                holder["state"], loss = self.update(holder["state"], pts)
                if param_service is not None:
                    holder["version"] = param_service.publish(
                        model_name, holder["state"])
            s = np.asarray(scores)
            thresh = s.mean() + 3.0 * s.std()
            return {"n_outliers": int((s > thresh).sum()),
                    "mean_score": float(s.mean())}

        return process_cloud
