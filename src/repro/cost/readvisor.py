"""Online re-advisory: watch a live run, hot-swap placement under drift.

The :class:`~repro.cost.advisor.PlacementAdvisor` ranks placements
*before* a run; this module closes the loop **during** one (ROADMAP item
3's dynamic half).  A :class:`ReAdvisor` periodically compares the
*observed* shaped-hop delay of a watched stage — the
``topic.<name>.wan_delay_s`` / ``msgs_in`` counters the broker stamps on
every shaped produce — against the :class:`~repro.cost.model.CostModel`
prediction for every candidate tier, and when the observed ranking flips
beyond a hysteresis tolerance it emits a swap decision.  The executors
apply it live: :meth:`~repro.core.faas.ContinuumPipeline.rebind_stage`
re-binds the stage's pilot and re-prices the adjacent hop shapers, then
the stage's consumer fleet migrates epoch-wise (old members drain out at
their next loop top, a same-size replacement fleet spawns on the new
pilot), with the hop's at-least-once + dedup machinery covering the
hand-off window.

Scoring (per candidate tier ``T``, all per-message means over the last
tick window)::

    pred(T) = serialize(mean_bytes, src->T) + latency(src->T)/2
              + compute(flops, T, fleet_workers)
    score(current) uses max(observed_hop_delay, predicted_hop) instead
    of the predicted hop — observation only ever *raises* the current
    tier's cost (queueing under a degraded band), never lowers it below
    the physical floor.

A swap fires only when ``score(current) > hysteresis × score(best)`` —
within tolerance the advisor stays quiet (the hysteresis property the
chaos suite pins), and ``cooldown_s`` / ``max_swaps`` stop flapping.
Under the single-threaded SimExecutor every tick reads deterministic
counters at deterministic virtual times, so decision and swap timestamps
are bit-identical run to run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple


@dataclass(frozen=True)
class ReAdviseSpec:
    """Scenario-level re-advisory knobs (what ``Scenario.readvise``
    carries); :func:`repro.sim.scenarios.build_pipeline` turns it into a
    live :class:`ReAdvisor` with the scenario's cost model and pilots.

    ``targets`` are candidate tiers for the watched stage (the current
    tier is always scored, listed or not).  ``min_samples`` is the
    per-window observation floor — fewer shaped messages than that in a
    tick window and the advisor abstains (no decision from noise).
    """
    stage: str = "process_cloud"
    targets: Tuple[str, ...] = ("cloud", "fog")
    interval_s: float = 0.25
    hysteresis: float = 1.5
    min_samples: int = 8
    cooldown_s: float = 1.0
    max_swaps: int = 1
    apply_delay_s: float = 0.05


@dataclass
class SwapDecision:
    """One re-advisory verdict: move ``stage`` from ``from_tier`` to
    ``to_tier``.  ``scores`` holds the per-tier effective seconds the
    ranking was decided on; ``t_applied`` is stamped by the executor
    when the migration actually lands (``apply_delay_s`` later)."""
    stage: str
    from_tier: str
    to_tier: str
    t_decided: float
    observed_hop_s: float
    scores: Dict[str, float] = field(default_factory=dict)
    t_applied: Optional[float] = None


class ReAdvisor:
    """Watch one stage's observed hop delay; decide placement hot-swaps.

    Parameters
    ----------
    cost: the :class:`~repro.cost.model.CostModel` predictions are priced
        against (band-adjusted — the same model the run's service pricing
        uses).
    stage: name of the watched (consumer) stage.
    flops: per-message work of the watched stage, priced per candidate
        tier at that tier's fleet rate.
    targets: candidate tier -> :class:`~repro.core.pilot.Pilot` to re-bind
        onto; the decision's ``pilot_for(to_tier)`` hands it to
        ``rebind_stage``.
    Remaining knobs match :class:`ReAdviseSpec`.
    """

    def __init__(self, cost, *, stage: str, flops: float,
                 targets: Mapping[str, Any],
                 interval_s: float = 0.25, hysteresis: float = 1.5,
                 min_samples: int = 8, cooldown_s: float = 1.0,
                 max_swaps: int = 1, apply_delay_s: float = 0.05):
        if hysteresis < 1.0:
            raise ValueError(f"hysteresis must be >= 1.0 (a factor), "
                             f"got {hysteresis}")
        if not targets:
            raise ValueError("readvisor needs at least one target tier")
        self.cost = cost
        self.stage = stage
        self.flops = float(flops)
        self.targets: Dict[str, Any] = dict(targets)
        self.interval_s = float(interval_s)
        self.hysteresis = float(hysteresis)
        self.min_samples = int(min_samples)
        self.cooldown_s = float(cooldown_s)
        self.max_swaps = int(max_swaps)
        self.apply_delay_s = float(apply_delay_s)
        self.swap_log: List[dict] = []
        self.decisions: List[SwapDecision] = []
        self._last: Dict[str, float] = {}
        self._cooldown_until = 0.0
        self._swaps = 0

    # -- lifecycle ---------------------------------------------------------

    def begin(self, t0: float) -> None:
        """Reset window state at run start (executors call this)."""
        self._last = {"msgs": 0.0, "delay": 0.0, "bytes": 0.0}
        self._cooldown_until = t0
        self._swaps = 0
        self.swap_log = []
        self.decisions = []

    def pilot_for(self, tier: str):
        return self.targets[tier]

    def applied(self, dec: SwapDecision, t: float) -> None:
        """Executor callback: the migration landed at clock time ``t``."""
        dec.t_applied = t
        self._cooldown_until = t + self.cooldown_s
        self.swap_log.append({
            "stage": dec.stage, "from": dec.from_tier, "to": dec.to_tier,
            "t_decided": dec.t_decided, "t_applied": t,
            "observed_hop_s": dec.observed_hop_s,
        })

    # -- scoring -----------------------------------------------------------

    def _hop_pred_s(self, src_tier: str, tier: str,
                    mean_bytes: float) -> float:
        """Predicted per-message shaped-hop delay src->tier: serialization
        at the routed link's bandwidth plus half the round trip — exactly
        what :class:`~repro.core.broker.WanShaper` charges (sans queueing,
        which only observation can reveal)."""
        if src_tier == tier:
            return 0.0
        link = self.cost.route(src_tier, tier).as_link()
        return mean_bytes * 8.0 / link.bandwidth_bps + link.latency_s / 2.0

    def scores(self, *, src_tier: str, current_tier: str,
               mean_bytes: float, observed_hop_s: float
               ) -> Dict[str, float]:
        """Effective per-message seconds for every candidate tier (and
        the current one).  The current tier is scored on
        ``max(observed, predicted)`` — a degraded band shows up as
        queueing the prediction can't see; an unshaped or warming-up hop
        falls back to the physical prediction."""
        out: Dict[str, float] = {}
        for tier, pilot in self.targets.items():
            workers = pilot.resource.n_workers
            pred = self._hop_pred_s(src_tier, tier, mean_bytes)
            if tier == current_tier:
                pred = max(observed_hop_s, pred)
            out[tier] = pred + self.cost.compute_s(self.flops, tier,
                                                   workers)
        if current_tier not in out:
            # the current binding is always in the ranking, even when it
            # is not a re-bind candidate
            pred = max(observed_hop_s,
                       self._hop_pred_s(src_tier, current_tier,
                                        mean_bytes))
            out[current_tier] = pred + self.cost.compute_s(
                self.flops, current_tier, 1)
        return out

    def step(self, *, now: float, metrics, topic: str, current_tier: str,
             src_tier: str) -> Optional[SwapDecision]:
        """One observation tick.  Reads the watched hop topic's produce
        counters, diffs them against the previous tick (the window), and
        returns a :class:`SwapDecision` when the ranking flips beyond
        hysteresis — else ``None``.  Counters advance every tick whether
        or not a decision fires, so each window is disjoint."""
        msgs = metrics.counter(f"topic.{topic}.msgs_in")
        delay = metrics.counter(f"topic.{topic}.wan_delay_s")
        nbytes = metrics.counter(f"topic.{topic}.bytes_in")
        last = self._last
        d_msgs = msgs - last["msgs"]
        d_delay = delay - last["delay"]
        d_bytes = nbytes - last["bytes"]
        last["msgs"], last["delay"], last["bytes"] = msgs, delay, nbytes
        if d_msgs < self.min_samples:
            return None
        if self._swaps >= self.max_swaps or now < self._cooldown_until:
            return None
        mean_delay = d_delay / d_msgs
        mean_bytes = d_bytes / d_msgs
        sc = self.scores(src_tier=src_tier, current_tier=current_tier,
                         mean_bytes=mean_bytes,
                         observed_hop_s=mean_delay)
        best = min(sc, key=lambda t: (sc[t], t))
        if best == current_tier or best not in self.targets:
            return None
        if sc[current_tier] <= self.hysteresis * sc[best]:
            return None                      # within tolerance: stay put
        dec = SwapDecision(stage=self.stage, from_tier=current_tier,
                           to_tier=best, t_decided=now,
                           observed_hop_s=mean_delay, scores=sc)
        # the budget is spent at decision time (not apply time) so ticks
        # landing inside the apply delay can't emit duplicate decisions
        self._swaps += 1
        self._cooldown_until = now + self.cooldown_s
        self.decisions.append(dec)
        return dec
