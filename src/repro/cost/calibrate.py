"""Calibrate per-model compute costs from the *measured* ``repro.ml``
kernels instead of hand-tuned analytic constants.

Two calibration sources, composable:

1. **Roofline (HLO) flops** — deterministic: each workload's real JAX
   kernels (k-means assign+update, the autoencoder train step, isolation
   forest fit+score) are compiled and costed with the trip-count-aware
   :class:`~repro.roofline.hlo_cost.HloCostModel`.  This yields
   ``kernel_flops_per_point`` — what one kernel invocation actually
   executes, per data point.
2. **Measured wall-time samples** — optional: real per-message service
   times on a given tier.  :meth:`Calibrator.fit_service` fits the
   *efficiency* (achieved fraction of the tier device's peak — small-batch
   dense kernels land far below peak) and a **lognormal service-time noise
   model** (``sigma`` = std of log service time), which is what the DES
   straggler machinery needs to make speculation meaningful.

The committed ``calibration.json`` next to this module is the default
calibration everything loads: HLO flops measured in this container
(regenerate with ``python -m repro.cost.calibrate --out ...``) plus
efficiencies/noise fitted to the paper's testbed wall times (PyOD's
Keras autoencoder trains its default 100 epochs per batch; RasPi/EC2
achieve a small fraction of peak on these small dense kernels).  The
defaults keep every consumer deterministic — live recalibration is a tool
invocation, never an import-time side effect.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence

from repro.cost.profiles import DEFAULT_PROFILE, ContinuumProfile

CALIBRATION_PATH = os.path.join(os.path.dirname(__file__),
                                "calibration.json")

# calibration reference shape: the paper's default message
CAL_N_POINTS = 2_500
CAL_N_FEATURES = 32

# analytic workload defaults shared with sim.scenarios (defined once,
# here in the cost subsystem): the hybrid edge pre-aggregation shrink
# factor, its per-point cost, and the Mini-App generation cost per point
DEFAULT_HYBRID_REDUCE = 10
DEFAULT_PREPROCESS_FLOPS_PER_POINT = 200.0
DEFAULT_GEN_S_PER_POINT = 2e-6


@dataclass(frozen=True)
class ModelCost:
    """Calibrated cost of one processing model.

    ``kernel_flops_per_point`` × ``invocations_per_message`` is the real
    work one message triggers; dividing by ``efficiency`` expresses it as
    peak-rate-equivalent flops so every consumer can price service time as
    ``effective_flops / (device.peak_flops × workers)``.
    """
    name: str
    kernel_flops_per_point: float      # HLO-measured, one invocation
    kernel_bytes_per_point: float      # HLO bytes (roofline memory term)
    invocations_per_message: float     # workload heaviness (e.g. AE epochs)
    efficiency: float                  # achieved fraction of device peak
    sigma: float                       # lognormal service-noise (log-space)
    output_bytes: int                  # serialized model output / message
    hybrid_reduce: int = DEFAULT_HYBRID_REDUCE
    preprocess_flops_per_point: float = DEFAULT_PREPROCESS_FLOPS_PER_POINT
    source: str = "roofline"           # roofline | measured | analytic
    precision: str = "fp32"            # fp32 | bf16 | int8 (kernel variant)

    @property
    def flops_per_point(self) -> float:
        """Real flops one message executes, per point."""
        return self.kernel_flops_per_point * self.invocations_per_message

    @property
    def effective_flops_per_point(self) -> float:
        """Peak-rate-equivalent flops per point (folds in efficiency)."""
        return self.flops_per_point / max(self.efficiency, 1e-9)


def load_calibration(path: Optional[str] = None) -> Dict[str, ModelCost]:
    """Load a calibration file (the committed one by default)."""
    with open(path or CALIBRATION_PATH) as f:
        doc = json.load(f)
    fields = {f.name for f in dataclasses.fields(ModelCost)}
    return {name: ModelCost(**{k: v for k, v in entry.items()
                               if k in fields})
            for name, entry in doc["models"].items()}


def save_calibration(costs: Mapping[str, ModelCost], path: str,
                     meta: Optional[dict] = None) -> None:
    doc = {"meta": dict(meta or {}),
           "models": {name: dataclasses.asdict(mc)
                      for name, mc in sorted(costs.items())}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")


# ---------------------------------------------------------------------------
# roofline measurement of the real repro.ml kernels
# ---------------------------------------------------------------------------


def _hlo_cost(fn, *args):
    """(flops, bytes) of a jitted callable via the trip-count-aware HLO
    parser (jax imported lazily: calibration is a tool, not an import-time
    dependency)."""
    import jax

    from repro.roofline.hlo_cost import HloCostModel
    m = HloCostModel(jax.jit(fn).lower(*args).compile().as_text())
    return m.flops(), m.bytes_accessed()


def _measure_kmeans(n_points: int, n_features: int, n_clusters: int = 25,
                    precision: str = "fp32"):
    """Per-message work: ONE fused assign+update pass — exactly what
    ``KMeans.make_processor`` runs per message.  The costed lowering is
    the fused jnp formulation (distance expansion + scatter-add
    membership stats): the Pallas kernel is a custom-call the HLO cost
    model prices as free, so the jnp lowering of the same one-pass
    algorithm is the roofline proxy.  Historically this summed a separate
    assign pass plus a two-pass update (re-assign + (K,N)@(N,F) one-hot
    matmul) — ~5.2k flops/pt where the fused pass needs ~1.8k."""
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as S

    from repro.ml.kmeans import _assign_update
    cent = S((n_clusters, n_features), jnp.float32)
    cnts = S((n_clusters,), jnp.float32)
    pts = S((n_points, n_features), jnp.float32)
    f, b = _hlo_cost(
        lambda c, n, p: _assign_update(c, n, p, impl="fused",
                                       precision=precision),
        cent, cnts, pts)
    return f / n_points, b / n_points


def _make_kmeans_variant_measurer(precision: str):
    def measure(n_points: int, n_features: int, n_clusters: int = 25):
        return _measure_kmeans(n_points, n_features, n_clusters,
                               precision=precision)
    return measure


def _measure_autoencoder(n_points: int, n_features: int):
    """Per-invocation work: one Adam train step over the PyOD topology
    (the workload's ``invocations_per_message`` counts the epochs)."""
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as S

    from repro.ml.autoencoder import AutoEncoder
    ae = AutoEncoder(n_features=n_features)
    st = ae.init()
    x = S((n_points, n_features), jnp.float32)
    step = jnp.zeros((), jnp.int32)
    fs, bs = _hlo_cost(lambda p, o, s, xx: ae._step(p, o, s, xx),
                       st["params"], st["opt"], step, x)
    return fs / n_points, bs / n_points


def _measure_isoforest(n_points: int, n_features: int):
    """Per-message work: refit the 100-tree forest + score the message
    (``IsolationForest.make_processor`` refits on every message)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax import ShapeDtypeStruct as S

    from repro.ml.isoforest import IsolationForest, _fit, _score
    isf = IsolationForest()
    pts = S((n_points, n_features), jnp.float32)
    ff, bf = _hlo_cost(
        lambda p: _fit(jax.random.key(0), p, isf.n_trees, isf.psi,
                       isf.max_depth), pts)
    forest = isf.fit(np.zeros((max(isf.psi, 2), n_features),
                              np.float32))["forest"]
    fs, bs = _hlo_cost(
        lambda fo, p: _score(fo, p, jnp.float32(isf.psi), isf.max_depth),
        forest, pts)
    return (ff + fs) / n_points, (bf + bs) / n_points


_MEASURERS = {
    "kmeans": _measure_kmeans,
    "kmeans_bf16": _make_kmeans_variant_measurer("bf16"),
    "kmeans_int8": _make_kmeans_variant_measurer("int8"),
    "autoencoder": _measure_autoencoder,
    "isoforest": _measure_isoforest,
}

# Paper-testbed service fit (used when no wall-time samples are supplied):
# invocations (PyOD's Keras AE trains its default 100 epochs per batch;
# k-means/iforest run once per message), efficiency (fitted from the
# paper's Fig-2/3 wall times — small dense kernels achieve a small
# fraction of peak), and lognormal service noise fitted from measured
# per-message samples (lighter kernels jitter relatively more).
_PAPER_SERVICE_FIT = {
    "kmeans": dict(invocations_per_message=1.0, efficiency=0.65,
                   sigma=0.25, output_bytes=25 * CAL_N_FEATURES * 8),
    # precision variants of the same fused kernel: identical invocation
    # structure and noise; the narrower datapaths sustain a slightly
    # higher fraction of (their much higher) precision-scaled peak
    "kmeans_bf16": dict(invocations_per_message=1.0, efficiency=0.65,
                        sigma=0.25, output_bytes=25 * CAL_N_FEATURES * 8,
                        precision="bf16"),
    "kmeans_int8": dict(invocations_per_message=1.0, efficiency=0.70,
                        sigma=0.25, output_bytes=25 * CAL_N_FEATURES * 8,
                        precision="int8"),
    "autoencoder": dict(invocations_per_message=100.0, efficiency=0.15,
                        sigma=0.10, output_bytes=2_048),
    "isoforest": dict(invocations_per_message=1.0, efficiency=0.45,
                      sigma=0.20, output_bytes=2_048),
}


class Calibrator:
    """Fits :class:`ModelCost` entries from the two calibration sources."""

    def __init__(self, profile: Optional[ContinuumProfile] = None,
                 n_points: int = CAL_N_POINTS,
                 n_features: int = CAL_N_FEATURES):
        self.profile = profile or DEFAULT_PROFILE
        self.n_points = n_points
        self.n_features = n_features

    # -- source 1: roofline flops of the compiled kernels ------------------

    def measure_kernel(self, model: str):
        """(flops_per_point, bytes_per_point) of one kernel invocation of
        ``model``, from trip-count-aware HLO cost analysis."""
        try:
            measure = _MEASURERS[model]
        except KeyError:
            raise KeyError(f"no kernel measurer for {model!r}; "
                           f"known: {sorted(_MEASURERS)}") from None
        return measure(self.n_points, self.n_features)

    # -- source 2: measured wall-time samples ------------------------------

    def fit_service(self, samples_s: Sequence[float], *,
                    flops_per_message: float, tier: str = "cloud",
                    n_workers: int = 1):
        """Fit (efficiency, sigma) from measured per-message service times.

        efficiency = flops / (peak × arithmetic-mean(t)), with the mean
        taken as the lognormal ``exp(μ + σ²/2)``; sigma is the std of log
        service time.  Together they define the *mean-one* lognormal
        service-time model ``t ~ eff_service × LogNormal(-σ²/2, σ)`` that
        :meth:`repro.cost.model.CostModel.service_model` applies — fitting
        against the arithmetic mean makes the round trip exact (samples
        generated by ``service_model`` refit to the same parameters).
        """
        ts = [float(t) for t in samples_s if t > 0]
        if not ts:
            raise ValueError("need at least one positive sample")
        logs = [math.log(t) for t in ts]
        mu = sum(logs) / len(logs)
        var = (sum((x - mu) ** 2 for x in logs) / (len(logs) - 1)
               if len(logs) > 1 else 0.0)
        peak = self.profile.tier(tier).device.peak_flops * n_workers
        efficiency = flops_per_message / (peak * math.exp(mu + var / 2.0))
        return min(efficiency, 1.0), math.sqrt(var)

    def sample_service(self, model: str, n_messages: int = 5):
        """Wall-time per-message samples of the real processor on this
        host (jit warmed first) — input for :meth:`fit_service`."""
        import time

        from repro import ml
        maker = {
            "kmeans": ml.KMeans,
            "kmeans_bf16": lambda: ml.KMeans(precision="bf16"),
            "kmeans_int8": lambda: ml.KMeans(precision="int8"),
            "autoencoder": ml.AutoEncoder,
            "isoforest": ml.IsolationForest,
        }[model]()
        process = maker.make_processor()
        gen = ml.MiniAppGenerator(n_points=self.n_points,
                                  n_features=self.n_features)
        ctx = type("Ctx", (), {"attempt": 0})()
        process(ctx, data=gen.sample())          # warm the jit caches
        samples = []
        for _ in range(n_messages):
            data = gen.sample()
            t0 = time.perf_counter()
            process(ctx, data=data)
            samples.append(time.perf_counter() - t0)
        return samples

    def measure_service(self, model: str, *, n_messages: int = 5,
                        tier: str = "cloud",
                        kernel_flops_per_point: Optional[float] = None):
        """Run the real processor ``n_messages`` times and fit
        (efficiency, sigma) on this host — a *container* calibration, not
        the committed paper-testbed one.  Pass ``kernel_flops_per_point``
        to skip the kernel recompile when it was already measured."""
        if kernel_flops_per_point is None:
            kernel_flops_per_point, _ = self.measure_kernel(model)
        fit = _PAPER_SERVICE_FIT[model]
        flops = (kernel_flops_per_point * fit["invocations_per_message"]
                 * self.n_points)
        return self.fit_service(self.sample_service(model, n_messages),
                                flops_per_message=flops, tier=tier)

    # -- assembly ----------------------------------------------------------

    def calibrate(self, *, measure_service: bool = False,
                  models: Optional[Sequence[str]] = None
                  ) -> Dict[str, ModelCost]:
        """Full calibration: roofline flops always; efficiency/sigma from
        live wall-time samples when ``measure_service`` (container fit),
        otherwise the committed paper-testbed service fit."""
        out: Dict[str, ModelCost] = {}
        for name in models or sorted(_MEASURERS):
            kf, kb = self.measure_kernel(name)
            fit = dict(_PAPER_SERVICE_FIT[name])
            if name.startswith("kmeans"):
                # the published output is the k x d centroid table — it
                # scales with the calibration's feature count
                fit["output_bytes"] = 25 * self.n_features * 8
            source = "roofline"
            if measure_service:
                eff, sigma = self.measure_service(
                    name, kernel_flops_per_point=kf)
                fit.update(efficiency=eff, sigma=sigma)
                source = "measured"
            out[name] = ModelCost(
                name=name, kernel_flops_per_point=round(kf, 3),
                kernel_bytes_per_point=round(kb, 3),
                invocations_per_message=fit["invocations_per_message"],
                efficiency=fit["efficiency"], sigma=fit["sigma"],
                output_bytes=fit["output_bytes"], source=source,
                precision=fit.get("precision", "fp32"))
        return out


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=CALIBRATION_PATH,
                    help="where to write the calibration JSON")
    ap.add_argument("--points", type=int, default=CAL_N_POINTS)
    ap.add_argument("--features", type=int, default=CAL_N_FEATURES)
    ap.add_argument("--measure-service", action="store_true",
                    help="fit efficiency/noise from live wall-time samples "
                         "on this host (default: keep the committed "
                         "paper-testbed service fit)")
    args = ap.parse_args(argv)
    cal = Calibrator(n_points=args.points, n_features=args.features)
    costs = cal.calibrate(measure_service=args.measure_service)
    import jax
    save_calibration(costs, args.out, meta={
        "n_points": args.points, "n_features": args.features,
        "jax_version": jax.__version__,
        "generated_by": "python -m repro.cost.calibrate",
        "service_fit": ("measured on this host"
                        if args.measure_service else "paper testbed"),
    })
    for name, mc in sorted(costs.items()):
        print(f"{name:>12}: {mc.kernel_flops_per_point:>12.1f} flops/pt "
              f"x {mc.invocations_per_message:g} inv "
              f"/ eff {mc.efficiency:g} "
              f"= {mc.effective_flops_per_point:.3e} effective flops/pt "
              f"(sigma={mc.sigma:g})")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
