"""The unified continuum cost model.

One object answers every "how long will this take" question in the repo —
the :class:`~repro.core.placement.PlacementEngine` scores pilots through
it, :mod:`repro.sim.scenarios` prices DES stage service times with it, and
the :class:`~repro.cost.advisor.PlacementAdvisor` sweeps it under the real
pipeline.  All parameters flow from :mod:`repro.cost.profiles` (devices /
tiers / links) and :mod:`repro.cost.calibrate` (per-model costs measured
from the compiled ``repro.ml`` kernels), never from per-module constants.

Service-time model: ``t = effective_flops / (peak_flops × workers)``,
optionally × a lognormal noise factor ``LogNormal(-σ²/2, σ)`` (mean 1)
whose σ comes from measured wall-time samples — the noise model the DES
straggler machinery needs.
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Tuple

from repro.cost.calibrate import ModelCost, load_calibration
from repro.cost.profiles import (DEFAULT_PROFILE, ContinuumProfile,
                                 LinkModel, Route)

# cloud-side result ingest for edge-placed models: merging a published
# model output costs a few flops per serialized value (the only "analytic"
# constant left, and it lives here, in the cost subsystem)
INGEST_FLOPS_PER_VALUE = 50.0


class CostModel:
    """Predicts per-task compute / transfer / service time on a continuum.

    Parameters
    ----------
    profile: the hardware continuum (tiers/devices/links); defaults to the
        paper-testbed :data:`~repro.cost.profiles.DEFAULT_PROFILE`.
    costs: per-model :class:`~repro.cost.calibrate.ModelCost` entries;
        defaults to the committed kernel calibration.
    """

    def __init__(self, profile: Optional[ContinuumProfile] = None,
                 costs: Optional[Mapping[str, ModelCost]] = None):
        self.profile = profile or DEFAULT_PROFILE
        self.costs: Dict[str, ModelCost] = dict(
            costs if costs is not None else load_calibration())

    def with_wan(self, band: str) -> "CostModel":
        """The same costs priced over a named WAN band."""
        return CostModel(self.profile.with_wan(band), self.costs)

    def with_metro(self, band: str) -> "CostModel":
        """The same costs priced over a named metro (edge→fog) band."""
        return CostModel(self.profile.with_metro(band), self.costs)

    # -- lookups -----------------------------------------------------------

    @property
    def links(self) -> Dict[Tuple[str, str], LinkModel]:
        """Inter-tier link table (the PlacementEngine's view)."""
        return dict(self.profile.links)

    def model_cost(self, name: str) -> ModelCost:
        try:
            return self.costs[name]
        except KeyError:
            raise KeyError(f"no calibrated cost for model {name!r}; "
                           f"known: {sorted(self.costs)}") from None

    def link(self, a: str, b: str) -> LinkModel:
        return self.profile.link(a, b)

    def route(self, src: str, dst: str, nbytes: float = 0.0) -> Route:
        """Shortest-time multi-hop route between two tiers (see
        :meth:`~repro.cost.profiles.ContinuumProfile.route`)."""
        return self.profile.route(src, dst, nbytes)

    def tier_flops(self, tier: str, n_workers: int = 1,
                   precision: str = "fp32") -> float:
        """Aggregate peak FLOP/s of ``n_workers`` devices of a tier, at a
        kernel precision (reduced-precision datapaths run at a multiple of
        the fp32 peak — see :meth:`DeviceProfile.speedup`)."""
        dev = self.profile.tier(tier).device
        return dev.peak_flops * dev.speedup(precision) * max(n_workers, 1)

    # -- primitive estimates ----------------------------------------------

    def compute_s(self, flops: float, tier: str, n_workers: int = 1,
                  precision: str = "fp32") -> float:
        """Seconds to execute ``flops`` (peak-rate-equivalent) on a tier."""
        return flops / max(self.tier_flops(tier, n_workers, precision), 1.0)

    def transfer_s(self, nbytes: float, src: str, dst: str) -> float:
        """Seconds to move ``nbytes`` between tiers (0 bytes = free),
        priced over the *routed* path: tiers without a direct link pay
        every hop's serialization plus the accumulated per-hop latency."""
        if not nbytes:
            return 0.0
        return self.route(src, dst, nbytes).transfer_s(nbytes)

    # -- per-model estimates ----------------------------------------------

    def model_compute_s(self, model: str, n_points: int, tier: str,
                        n_workers: int = 1) -> float:
        """Full-model service time for one ``n_points`` message, priced at
        the tier's peak for the model's calibrated kernel precision."""
        mc = self.model_cost(model)
        return self.compute_s(mc.effective_flops_per_point * n_points,
                              tier, n_workers, mc.precision)

    def preprocess_s(self, model: str, n_points: int, tier: str,
                     n_workers: int = 1) -> float:
        """Edge pre-aggregation time (the hybrid placement's edge stage)."""
        mc = self.model_cost(model)
        return self.compute_s(mc.preprocess_flops_per_point * n_points,
                              tier, n_workers)

    def ingest_bytes_s(self, output_bytes: float, tier: str,
                       n_workers: int = 1) -> float:
        """Cloud-side merge of ``output_bytes`` of published model output
        (priced at :data:`INGEST_FLOPS_PER_VALUE` per serialized value)."""
        values = output_bytes / 8.0
        return self.compute_s(values * INGEST_FLOPS_PER_VALUE, tier,
                              n_workers)

    def ingest_s(self, model: str, tier: str, n_workers: int = 1) -> float:
        """Cloud-side merge of an edge-placed model's published output."""
        return self.ingest_bytes_s(self.model_cost(model).output_bytes,
                                   tier, n_workers)

    # -- calibrated service model (what the executors consume) -------------

    def service_model(self, stage_times: Mapping[str, float], *,
                      sigma: float = 0.0, seed: int = 0
                      ) -> Callable[[str, object, object], float]:
        """Build a ``service_model(stage, ctx, payload)`` callable for
        :class:`~repro.core.executor.SimExecutor` /
        :class:`~repro.core.executor.ThreadedExecutor` from per-stage base
        times.

        With ``sigma > 0`` every charge is multiplied by a mean-1
        lognormal draw (the calibrated straggler noise) from a seeded rng
        — runs stay bit-reproducible for a given seed under the
        single-threaded SimExecutor.  The draw is lock-guarded so the
        noisy model is also safe (though no longer bit-ordered) under
        ThreadedExecutor's concurrent consumers.
        """
        base = dict(stage_times)
        if sigma <= 0.0:
            return lambda stage, ctx, payload: base.get(stage, 0.0)
        import threading

        import numpy as np
        rng = np.random.default_rng([seed & 0xFFFFFFFF, 0xC057])
        lock = threading.Lock()
        mu = -0.5 * sigma * sigma

        def model(stage, ctx, payload):
            t = base.get(stage, 0.0)
            if t <= 0.0:
                return t
            with lock:
                z = rng.normal(mu, sigma)
            return t * float(np.exp(z))

        return model

    def tier_service_model(self, stage_flops: Mapping[str, float], *,
                           resolve: Callable[[str], Tuple[str, int]],
                           sigma: float = 0.0, seed: int = 0,
                           stage_precision: Optional[Mapping[str, str]]
                           = None
                           ) -> Callable[[str, object, object], float]:
        """Like :meth:`service_model`, but per-stage *FLOPs* are priced at
        the tier a stage executes on **at charge time** — ``resolve(stage)``
        returns the live ``(tier, n_workers)`` binding.  This is what makes
        a mid-run placement hot-swap re-price service automatically: after
        the ReAdvisor rebinds a stage from cloud to fog, the very next
        charge runs at the fog device's peak rate, with no service-model
        rebuild.  Noise draws (``sigma > 0``) come from the same seeded
        stream as :meth:`service_model`, in charge order, so swapped runs
        stay bit-reproducible under the single-threaded SimExecutor.

        ``stage_precision`` names the kernel precision a stage's flops run
        at (default fp32) — a quantized model's compute stage is priced at
        the resolved tier's int8 peak, whatever tier it lands on."""
        flops = dict(stage_flops)
        precision = dict(stage_precision or {})
        if sigma > 0.0:
            import threading

            import numpy as np
            rng = np.random.default_rng([seed & 0xFFFFFFFF, 0xC057])
            lock = threading.Lock()
            mu = -0.5 * sigma * sigma
        else:
            rng = None

        def model(stage, ctx, payload):
            f = flops.get(stage, 0.0)
            if f <= 0.0:
                return 0.0
            tier, workers = resolve(stage)
            t = self.compute_s(f, tier, workers,
                               precision.get(stage, "fp32"))
            if rng is None:
                return t
            with lock:
                z = rng.normal(mu, sigma)
            return t * float(np.exp(z))

        return model


_DEFAULT: Optional[CostModel] = None


def default_cost_model() -> CostModel:
    """The shared default CostModel (committed calibration + paper-testbed
    profile) — cached, read-only by convention."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = CostModel()
    return _DEFAULT
