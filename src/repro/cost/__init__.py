"""Unified continuum cost subsystem.

* :mod:`repro.cost.profiles`  — devices / tiers / links (the one shared
  link table: ``WAN_BANDS``, ``DEFAULT_PROFILE``),
* :mod:`repro.cost.calibrate` — per-model costs measured from the compiled
  ``repro.ml`` kernels (roofline HLO flops) and/or wall-time samples
  (efficiency + lognormal service noise); the committed
  ``calibration.json`` is the deterministic default,
* :mod:`repro.cost.model`     — :class:`CostModel`, the single
  compute/transfer/service-time oracle the placement engine, the DES
  scenarios and the advisor all consume,
* :mod:`repro.cost.advisor`   — :class:`PlacementAdvisor`, a DES-backed
  ranked placement recommendation on the genuine pipeline (re-exported
  lazily: it imports the sim/core stack, which imports this package).
"""
from repro.cost.calibrate import (CALIBRATION_PATH, Calibrator, ModelCost,
                                  load_calibration, save_calibration)
from repro.cost.model import CostModel, default_cost_model
from repro.cost.profiles import (DEFAULT_PROFILE, DEFAULT_WAN_BAND,
                                 WAN_BANDS, ContinuumProfile, DeviceProfile,
                                 Hop, LinkModel, Route, TierProfile,
                                 Topology)

_LAZY = ("PlacementAdvisor", "AdvisorReport", "Advice")

__all__ = [
    "LinkModel", "DeviceProfile", "TierProfile", "ContinuumProfile",
    "Topology", "Route", "Hop",
    "WAN_BANDS", "DEFAULT_WAN_BAND", "DEFAULT_PROFILE",
    "ModelCost", "Calibrator", "load_calibration", "save_calibration",
    "CALIBRATION_PATH",
    "CostModel", "default_cost_model",
    *_LAZY,
]


def __getattr__(name):
    if name in _LAZY:
        from repro.cost import advisor
        return getattr(advisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
