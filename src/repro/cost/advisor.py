"""DES-backed placement advisor (the paper's headline claim, §I:
applications "evaluate task placement based on multiple factors (e.g.,
model complexities, throughput, and latency)").

:class:`PlacementAdvisor` runs the *genuine*
:class:`~repro.core.faas.EdgeToCloudPipeline` under
:class:`~repro.core.executor.SimExecutor` across
{placements} × {WAN bands} — real broker offsets, consumer groups, dedup,
WAN token bucket, only time is virtual — and returns a ranked
recommendation with predicted throughput/latency per cell.  Because every
cell is a deterministic DES run, the recommendation is bit-identical
across invocations.

Entry points::

    report = PlacementAdvisor().advise("kmeans")
    report.best("10mbit").placement          # 'edge' (transfer-bound)
    print(report.table())

    # or straight from a pipeline (reads model/n_points from its context):
    report = pipe.run(placement="advise")
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.cost.model import CostModel, default_cost_model
from repro.sim.scenarios import (PLACEMENTS, ModelSpec, Scenario,
                                 model_specs, run_scenario)


@dataclass(frozen=True)
class Advice:
    """One evaluated (placement, WAN band) cell."""
    model: str
    placement: str
    wan_band: str
    throughput_msgs_s: float
    latency_mean_s: float
    latency_p95_s: float
    wan_mbytes: float
    makespan_s: float
    tier_estimates: Dict[str, float] = field(default_factory=dict)

    def row(self) -> Dict[str, object]:
        return {"model": self.model, "placement": self.placement,
                "wan": self.wan_band,
                "msgs_per_s": self.throughput_msgs_s,
                "lat_mean_s": self.latency_mean_s,
                "lat_p95_s": self.latency_p95_s,
                "wan_mb": self.wan_mbytes,
                "makespan_s": self.makespan_s}


@dataclass
class AdvisorReport:
    """Ranked recommendation across placements × WAN bands."""
    model: str
    cells: List[Advice]

    def ranking(self, band: Optional[str] = None) -> List[Advice]:
        """Cells (optionally one band's) by predicted throughput, best
        first; ties broken by lower mean latency, then placement name so
        the order is total and reproducible."""
        cells = [c for c in self.cells
                 if band is None or c.wan_band == band]
        return sorted(cells, key=lambda c: (-c.throughput_msgs_s,
                                            c.latency_mean_s, c.placement))

    def best(self, band: str) -> Advice:
        rank = self.ranking(band)
        if not rank:
            raise ValueError(f"no advice for band {band!r}")
        return rank[0]

    def rows(self) -> List[Dict[str, object]]:
        """JSON-able rows with per-band rank and the recommendation flag
        (rank 1 in its band) — the BENCH_placement.json shape. Bands keep
        their evaluation order (ascending bandwidth by default)."""
        out = []
        for band in dict.fromkeys(c.wan_band for c in self.cells):
            for i, c in enumerate(self.ranking(band)):
                row = c.row()
                row["rank"] = i + 1
                row["recommended"] = i == 0
                out.append(row)
        return out

    def table(self) -> str:
        hdr = (f"{'model':>12} {'wan':>8} {'placement':>9} {'rank':>4} "
               f"{'msg/s':>9} {'lat-mean s':>10} {'lat-p95 s':>9} "
               f"{'WAN MB':>8}")
        lines = [hdr, "-" * len(hdr)]
        for r in self.rows():
            mark = " <- recommended" if r["recommended"] else ""
            lines.append(
                f"{r['model']:>12} {r['wan']:>8} {r['placement']:>9} "
                f"{r['rank']:>4} {r['msgs_per_s']:>9.3f} "
                f"{r['lat_mean_s']:>10.3f} {r['lat_p95_s']:>9.3f} "
                f"{r['wan_mb']:>8.2f}{mark}")
        return "\n".join(lines)


class PlacementAdvisor:
    """Evaluate placements for a workload by emulating the real pipeline.

    ``n_messages`` trades prediction fidelity for advisory wall time (the
    whole default grid runs in well under a second)."""

    def __init__(self, cost_model: Optional[CostModel] = None, *,
                 n_messages: int = 32, n_devices: int = 4,
                 n_consumers: Optional[int] = None, n_points: int = 2_500,
                 seed: int = 0, service_sigma: float = 0.0):
        self.cost = cost_model or default_cost_model()
        self.n_messages = n_messages
        self.n_devices = n_devices
        self.n_consumers = n_consumers
        self.n_points = n_points
        self.seed = seed
        self.service_sigma = service_sigma

    @classmethod
    def from_pipeline(cls, pipe, *, n_messages: int = 32,
                      **kw) -> "PlacementAdvisor":
        """Build an advisor matching a pipeline's shape; the workload
        (``model``, ``n_points``) is read from its ``function_context``
        and the cost model from its placement engine (so the advisory and
        the engine's own scoring stay mutually consistent — note the
        engine's legacy ``edge_flops``/``device_flops``/``links``
        overrides are *not* part of its cost model and don't reach the
        advisory; customize via a ``CostModel`` on a custom profile
        instead).
        ``n_points`` must be declared (there or via ``kw``) — silently
        assuming a message size would misprice the transfer side."""
        kw.setdefault("cost_model", pipe.placement_engine.cost)
        if "n_points" not in kw:
            n_points = pipe.context.get("n_points")
            if n_points is None:
                raise ValueError(
                    "advising needs function_context['n_points'] (points "
                    "per message) — transfer costs scale with it")
            kw["n_points"] = int(n_points)
        return cls(n_messages=n_messages, n_devices=pipe.n_edge_devices,
                   n_consumers=pipe.cloud_consumers, **kw)

    def advise(self, model: Union[str, ModelSpec] = "kmeans", *,
               placements: Sequence[str] = PLACEMENTS,
               bands: Optional[Sequence[str]] = None) -> AdvisorReport:
        # resolve string names against *this advisor's* calibration (a
        # custom cost_model re-prices the specs, not just the tier rates)
        if isinstance(model, str):
            self.cost.model_cost(model)    # unknown name → helpful KeyError
            spec = model_specs(self.cost)[model]
        else:
            spec = model
        cells: List[Advice] = []
        if bands is None:
            # this cost model's own bands (a custom profile sweeps *its*
            # table), ascending bandwidth rather than lexicographic
            table = self.cost.profile.wan_bands
            bands = sorted(table, key=lambda b: table[b].bandwidth)
        for band in bands:
            for placement in placements:
                r = run_scenario(Scenario(
                    model=spec, placement=placement, wan_band=band,
                    n_messages=self.n_messages, n_devices=self.n_devices,
                    n_consumers=self.n_consumers, n_points=self.n_points,
                    seed=self.seed, service_sigma=self.service_sigma,
                    cost=self.cost))
                cells.append(Advice(
                    model=spec.name, placement=placement, wan_band=band,
                    throughput_msgs_s=r.throughput_msgs_s,
                    latency_mean_s=r.latency_mean_s,
                    latency_p95_s=r.latency_p95_s,
                    wan_mbytes=r.wan_mbytes, makespan_s=r.makespan_s,
                    tier_estimates=dict(r.placement_estimates)))
        return AdvisorReport(model=spec.name, cells=cells)
