"""DES-backed placement advisor (the paper's headline claim, §I:
applications "evaluate task placement based on multiple factors (e.g.,
model complexities, throughput, and latency)").

:class:`PlacementAdvisor` runs the *genuine* pipeline (the two-stage
:class:`~repro.core.faas.EdgeToCloudPipeline` wrapper, or the 3-stage
edge→fog→cloud :class:`~repro.core.faas.ContinuumPipeline` for the fog
placement) under :class:`~repro.core.executor.SimExecutor` across
{placements over the full tier set} × {WAN bands} — real broker offsets,
consumer groups, dedup, WAN token bucket, only time is virtual — and
returns a ranked recommendation whose every cell carries its per-stage
tier vector (``Advice.tiers``).  The ranking is **multi-objective**: every cell reports
predicted throughput, the p50/p95/p99 latency tail, and exact WAN bytes;
``latency_budget=`` / ``wan_budget=`` constraints *filter-then-rank*
(feasible cells outrank infeasible ones, but infeasible cells stay in the
report, flagged — an impossible budget yields a ranked-but-flagged
recommendation, never an empty one).  ``hybrid_reduce=`` sweeps the hybrid
placement's edge pre-aggregation factor the same way placements are swept.

Tail fidelity: by default each cell runs with the workload's *calibrated*
lognormal service noise (``calibration.json``'s per-model sigma — pass
``service_sigma=0.0`` for the noise-free view) and can run the DES
straggler speculation (``speculative_factor=``), so p95/p99 and the
speculation win/loss counters reflect the straggler behaviour real edge
deployments rank placements by.  Because every cell is a deterministic
DES run, the recommendation is bit-identical across invocations.

Entry points::

    report = PlacementAdvisor().advise("kmeans")
    report.best("10mbit").placement          # 'edge' (transfer-bound)
    print(report.table())

    # budget-constrained, sweeping the hybrid pre-aggregation factor:
    report = PlacementAdvisor().advise(
        "kmeans", latency_budget=2.0, wan_budget=5.0,
        hybrid_reduce=(5, 10, 20))

    # or straight from a pipeline (reads model/n_points from its context):
    report = pipe.run(placement="advise", latency_budget=2.0)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.cost.model import CostModel, default_cost_model
from repro.sim.scenarios import (PLACEMENTS, ModelSpec, Scenario,
                                 model_specs, run_scenario)


@dataclass(frozen=True)
class Advice:
    """One evaluated (placement, WAN band[, hybrid_reduce]) cell.
    ``tiers`` is the per-stage execution tier vector of the emulated
    pipeline (e.g. ``('edge', 'fog', 'cloud')`` for the 3-stage fog
    placement)."""
    model: str
    placement: str
    wan_band: str
    throughput_msgs_s: float
    latency_mean_s: float
    latency_p95_s: float
    wan_mbytes: float
    makespan_s: float
    tier_estimates: Dict[str, float] = field(default_factory=dict)
    latency_p50_s: float = 0.0
    latency_p99_s: float = 0.0
    wan_bytes: float = 0.0
    tiers: Tuple[str, ...] = ()           # per-stage tier vector
    hybrid_reduce: Optional[int] = None   # set on hybrid/fog cells only
    metro_band: Optional[str] = None      # fog cells: swept edge→fog band
    feasible: bool = True                 # meets the advise() budgets
    spec_launches: int = 0                # straggler speculation accounting
    spec_wins: int = 0
    spec_losses: int = 0
    spec_cancelled: int = 0
    # the precision placement axis: the model's kernel precision and its
    # assignment-agreement rate vs the fp32 reference on the fixed
    # MiniAppGenerator probe (1.0 for fp32 models; the accuracy half of
    # every accuracy-vs-latency precision cell)
    precision: str = "fp32"
    agreement_vs_fp32: float = 1.0

    def row(self) -> Dict[str, object]:
        return {"model": self.model, "placement": self.placement,
                "tiers": list(self.tiers),
                "wan": self.wan_band,
                "precision": self.precision,
                "agreement_vs_fp32": self.agreement_vs_fp32,
                "msgs_per_s": self.throughput_msgs_s,
                "lat_mean_s": self.latency_mean_s,
                "lat_p50_s": self.latency_p50_s,
                "lat_p95_s": self.latency_p95_s,
                "lat_p99_s": self.latency_p99_s,
                "wan_mb": self.wan_mbytes,
                "wan_bytes": self.wan_bytes,
                "makespan_s": self.makespan_s,
                "hybrid_reduce": self.hybrid_reduce,
                "metro": self.metro_band,
                "feasible": self.feasible,
                "spec_launches": self.spec_launches,
                "spec_wins": self.spec_wins,
                "spec_losses": self.spec_losses,
                "spec_cancelled": self.spec_cancelled}


@dataclass
class AdvisorReport:
    """Ranked recommendation across placements × WAN bands.

    ``latency_budget`` / ``wan_budget`` record the constraints the cells
    were judged against (None = unconstrained)."""
    model: str
    cells: List[Advice]
    latency_budget: Optional[float] = None
    wan_budget: Optional[float] = None

    def ranking(self, band: Optional[str] = None) -> List[Advice]:
        """Cells (optionally one band's), budget-feasible cells first,
        then by predicted throughput; ties broken by lower mean latency,
        then placement name and hybrid_reduce so the order is total and
        reproducible.  Infeasible cells are *ranked, not dropped* — an
        impossible budget still yields a full (flagged) ranking."""
        cells = [c for c in self.cells
                 if band is None or c.wan_band == band]
        return sorted(cells, key=lambda c: (not c.feasible,
                                            -c.throughput_msgs_s,
                                            c.latency_mean_s, c.placement,
                                            c.hybrid_reduce or 0,
                                            c.metro_band or ""))

    def best(self, band: str) -> Advice:
        rank = self.ranking(band)
        if not rank:
            raise ValueError(f"no advice for band {band!r}")
        return rank[0]

    def feasible_cells(self, band: Optional[str] = None) -> List[Advice]:
        """The cells that meet both budgets (may be empty — ``best`` then
        returns the least-bad infeasible cell, flagged)."""
        return [c for c in self.ranking(band) if c.feasible]

    def rows(self) -> List[Dict[str, object]]:
        """JSON-able rows with per-band rank and the recommendation flag
        (rank 1 in its band) — the BENCH_placement.json shape. Bands keep
        their evaluation order (ascending bandwidth by default)."""
        out = []
        for band in dict.fromkeys(c.wan_band for c in self.cells):
            for i, c in enumerate(self.ranking(band)):
                row = c.row()
                row["rank"] = i + 1
                row["recommended"] = i == 0
                out.append(row)
        return out

    def table(self) -> str:
        hdr = (f"{'model':>12} {'wan':>8} {'placement':>9} {'path':>5} "
               f"{'red':>4} {'rank':>4} {'msg/s':>9} {'lat-p50 s':>9} "
               f"{'lat-p95 s':>9} {'lat-p99 s':>9} {'WAN MB':>8}")
        lines = [hdr, "-" * len(hdr)]
        for r in self.rows():
            mark = " <- recommended" if r["recommended"] else ""
            if not r["feasible"]:
                mark += " [over budget]"
            red = "-" if r["hybrid_reduce"] is None else r["hybrid_reduce"]
            path = "-".join(t[0] for t in r["tiers"])
            lines.append(
                f"{r['model']:>12} {r['wan']:>8} {r['placement']:>9} "
                f"{path:>5} {red:>4} {r['rank']:>4} "
                f"{r['msgs_per_s']:>9.3f} "
                f"{r['lat_p50_s']:>9.3f} {r['lat_p95_s']:>9.3f} "
                f"{r['lat_p99_s']:>9.3f} {r['wan_mb']:>8.2f}{mark}")
        return "\n".join(lines)


class PlacementAdvisor:
    """Evaluate placements for a workload by emulating the real pipeline.

    ``n_messages`` trades prediction fidelity for advisory wall time (the
    whole default grid runs in well under a second).

    ``service_sigma=None`` (the default) applies each workload's
    *calibrated* lognormal service noise — tail-latency columns reflect
    the measured straggler behaviour, not a fiction of uniform service
    times; pass ``0.0`` to rank on noise-free service times.
    ``speculative_factor`` additionally runs the DES straggler
    speculation in every cell (0 = off)."""

    def __init__(self, cost_model: Optional[CostModel] = None, *,
                 n_messages: int = 32, n_devices: int = 4,
                 n_consumers: Optional[int] = None, n_points: int = 2_500,
                 seed: int = 0, service_sigma: Optional[float] = None,
                 speculative_factor: float = 0.0):
        self.cost = cost_model or default_cost_model()
        self.n_messages = n_messages
        self.n_devices = n_devices
        self.n_consumers = n_consumers
        self.n_points = n_points
        self.seed = seed
        self.service_sigma = service_sigma
        self.speculative_factor = speculative_factor

    @classmethod
    def from_pipeline(cls, pipe, *, n_messages: int = 32,
                      **kw) -> "PlacementAdvisor":
        """Build an advisor matching a pipeline's shape; the workload
        (``model``, ``n_points``) is read from its ``function_context``,
        the cost model from its placement engine (so the advisory and
        the engine's own scoring stay mutually consistent — note the
        engine's legacy ``edge_flops``/``device_flops``/``links``
        overrides are *not* part of its cost model and don't reach the
        advisory; customize via a ``CostModel`` on a custom profile
        instead) and the straggler knob from its ``speculative_factor``.
        ``n_points`` must be declared (there or via ``kw``) — silently
        assuming a message size would misprice the transfer side."""
        kw.setdefault("cost_model", pipe.placement_engine.cost)
        kw.setdefault("speculative_factor",
                      pipe._runtime_kw["speculative_factor"])
        if "n_points" not in kw:
            n_points = pipe.context.get("n_points")
            if n_points is None:
                raise ValueError(
                    "advising needs function_context['n_points'] (points "
                    "per message) — transfer costs scale with it")
            kw["n_points"] = int(n_points)
        return cls(n_messages=n_messages, n_devices=pipe.n_edge_devices,
                   n_consumers=pipe.cloud_consumers, **kw)

    def advise(self, model: Union[str, ModelSpec] = "kmeans", *,
               placements: Sequence[str] = PLACEMENTS,
               bands: Optional[Sequence[str]] = None,
               latency_budget: Optional[float] = None,
               wan_budget: Optional[float] = None,
               hybrid_reduce: Optional[Sequence[int]] = None,
               metro_bands: Optional[Sequence[str]] = None
               ) -> AdvisorReport:
        """Sweep {placements} × {bands} (× {hybrid_reduce} for the hybrid
        placement, × {metro_bands} for the fog placement) and rank
        multi-objectively.

        ``metro_bands`` sweeps the edge→fog metro link for fog cells the
        same way WAN bands sweep the cloud hop (names from the profile's
        ``metro_bands`` table); other placements never ride the metro
        hop and are evaluated once per WAN band.  ``latency_budget``
        caps predicted p95 end-to-end latency (seconds); ``wan_budget``
        caps megabytes through the WAN for the whole advisory run.
        Cells violating either are flagged infeasible and rank after
        every feasible cell."""
        # resolve string names against *this advisor's* calibration (a
        # custom cost_model re-prices the specs, not just the tier rates)
        if isinstance(model, str):
            self.cost.model_cost(model)    # unknown name → helpful KeyError
            spec = model_specs(self.cost)[model]
        else:
            spec = model
        # accuracy half of the precision axis: assignment agreement vs
        # the fp32 reference on the fixed probe (deterministic, cached;
        # jax only loads for actual reduced-precision specs)
        if spec.precision == "fp32":
            agreement = 1.0
        else:
            from repro.ml.kmeans import assignment_agreement
            agreement = assignment_agreement(spec.precision)
        cells: List[Advice] = []
        if bands is None:
            # this cost model's own bands (a custom profile sweeps *its*
            # table), ascending bandwidth rather than lexicographic
            table = self.cost.profile.wan_bands
            bands = sorted(table, key=lambda b: table[b].bandwidth)
        reduces = tuple(int(x) for x in hybrid_reduce or ())
        metros = tuple(metro_bands or ())
        for m in metros:                   # unknown name → helpful error
            if m not in self.cost.profile.metro_bands:
                raise ValueError(
                    f"unknown metro band {m!r}; known: "
                    f"{sorted(self.cost.profile.metro_bands)}")
        # hybrid and fog both pre-aggregate (on the edge vs on the fog
        # tier), so the reduce-factor sweep applies to both placements
        reduced_placements = ("hybrid", "fog")
        for band in bands:
            for placement in placements:
                sweep = reduces if placement in reduced_placements \
                    and reduces else (None,)
                # only the fog placement rides the edge→fog metro hop
                msweep = metros if placement == "fog" and metros \
                    else (None,)
                for red, metro in ((r_, m_) for r_ in sweep
                                   for m_ in msweep):
                    mspec = (spec if red is None
                             else dataclasses.replace(spec,
                                                      hybrid_reduce=red))
                    sc = Scenario(
                        model=mspec, placement=placement, wan_band=band,
                        n_messages=self.n_messages,
                        n_devices=self.n_devices,
                        n_consumers=self.n_consumers,
                        n_points=self.n_points,
                        metro_band=metro,
                        seed=self.seed, service_sigma=self.service_sigma,
                        speculative_factor=self.speculative_factor,
                        cost=self.cost)
                    r = run_scenario(sc)
                    feasible = (
                        (latency_budget is None
                         or r.latency_p95_s <= latency_budget)
                        and (wan_budget is None
                             or r.wan_mbytes <= wan_budget))
                    cells.append(Advice(
                        model=spec.name, placement=placement,
                        tiers=r.tiers,
                        wan_band=band,
                        throughput_msgs_s=r.throughput_msgs_s,
                        latency_mean_s=r.latency_mean_s,
                        latency_p50_s=r.latency_p50_s,
                        latency_p95_s=r.latency_p95_s,
                        latency_p99_s=r.latency_p99_s,
                        wan_mbytes=r.wan_mbytes, wan_bytes=r.wan_bytes,
                        makespan_s=r.makespan_s,
                        hybrid_reduce=(mspec.hybrid_reduce
                                       if placement in reduced_placements
                                       else None),
                        metro_band=metro,
                        feasible=feasible,
                        spec_launches=r.spec_launches,
                        spec_wins=r.spec_wins,
                        spec_losses=r.spec_losses,
                        spec_cancelled=r.spec_cancelled,
                        precision=spec.precision,
                        agreement_vs_fp32=agreement,
                        tier_estimates=dict(r.placement_estimates)))
        return AdvisorReport(model=spec.name, cells=cells,
                             latency_budget=latency_budget,
                             wan_budget=wan_budget)
