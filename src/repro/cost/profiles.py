"""Continuum hardware profiles — the single source of truth for every
device / tier / link parameter in the repo.

Before this subsystem existed the cost knowledge was triplicated:
``core/placement.py`` hardcoded ``EDGE_FLOPS``/``DEVICE_FLOPS``/
``DEFAULT_LINKS``, ``sim/scenarios.py`` hardcoded its own ``WAN_BANDS``
(with drifted latencies), and ``roofline/`` measured real HLO costs that
nothing consumed.  Now:

* :class:`DeviceProfile` — one device's sustained peak rates (the paper's
  testbed: RasPi-4-class edge nodes, EC2-class cloud workers),
* :class:`TierProfile` — a continuum tier (edge / cloud / hpc) backed by a
  device profile plus its intra-tier link,
* :class:`LinkModel`  — bandwidth (bytes/s) + latency between tiers,
* :data:`WAN_BANDS`   — the paper's iPerf bands as the one shared link
  table (``sim.scenarios.WAN_BANDS`` and ``core.placement.DEFAULT_LINKS``
  are both import-time snapshots of this dict — pinned equal by a
  regression test),
* :class:`ContinuumProfile` — the assembled continuum the
  :class:`~repro.cost.model.CostModel` prices against.

Per-model compute costs (FLOPs/point, efficiencies, service-time noise)
live next door in :mod:`repro.cost.calibrate` — measured from the compiled
``repro.ml`` kernels, not asserted.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Mapping, Optional, Tuple


@dataclass(frozen=True)
class LinkModel:
    """Bandwidth (bytes/s) + latency between tiers."""
    bandwidth: float
    latency_s: float = 0.0

    @property
    def bandwidth_bps(self) -> float:
        """Bandwidth in bits/s (the WanShaper's unit)."""
        return self.bandwidth * 8.0


# The paper's iPerf WAN bands (§III, Fig 2/3): bandwidth is stored in
# bytes/s (LinkModel's unit); ``.bandwidth_bps`` recovers the bits/s the
# WanShaper wants. The constrained 10 Mbit/s point is the band the
# placement-sensitivity experiments run at.
WAN_BANDS: Dict[str, LinkModel] = {
    "10mbit": LinkModel(bandwidth=10e6 / 8.0, latency_s=0.150),
    "50mbit": LinkModel(bandwidth=50e6 / 8.0, latency_s=0.150),
    "100mbit": LinkModel(bandwidth=100e6 / 8.0, latency_s=0.140),
}
DEFAULT_WAN_BAND = "10mbit"


@dataclass(frozen=True)
class DeviceProfile:
    """Sustained peak rates of one device class."""
    name: str
    peak_flops: float              # FLOP/s at full efficiency
    mem_bw: float = 0.0            # bytes/s (roofline memory term)
    memory_gb: float = 4.0


# The paper's testbed devices. Edge = RasPi-class (1 core / 4 GB Dask
# task); cloud/hpc = one EC2-class worker core-set per Dask worker.
RASPI_4B = DeviceProfile("raspi-4b", peak_flops=5e9, mem_bw=4e9,
                         memory_gb=4.0)
CLOUD_CPU = DeviceProfile("cloud-cpu", peak_flops=50e9, mem_bw=20e9,
                          memory_gb=16.0)


@dataclass(frozen=True)
class TierProfile:
    """One continuum tier: which device backs it + its intra-tier link."""
    tier: str
    device: DeviceProfile
    # within a tier messages ride local links (LAN / host loopback)
    intra_link: LinkModel = LinkModel(bandwidth=10e9, latency_s=0.0)


@dataclass(frozen=True)
class ContinuumProfile:
    """The assembled continuum: tiers + inter-tier links + WAN bands."""
    name: str
    tiers: Mapping[str, TierProfile]
    links: Mapping[Tuple[str, str], LinkModel]
    wan_bands: Mapping[str, LinkModel] = field(
        default_factory=lambda: dict(WAN_BANDS))
    default_wan: str = DEFAULT_WAN_BAND

    def tier(self, name: str) -> TierProfile:
        try:
            return self.tiers[name]
        except KeyError:
            raise KeyError(f"unknown tier {name!r}; "
                           f"known: {sorted(self.tiers)}") from None

    def wan(self, band: Optional[str] = None) -> LinkModel:
        return self.wan_bands[band or self.default_wan]

    def link(self, a: str, b: str) -> LinkModel:
        """Link between two tiers; same-tier rides the intra-tier link,
        unknown cross-tier pairs fall back to the default WAN band with a
        conservative doubled latency."""
        if a == b:
            tp = self.tiers.get(a)
            return tp.intra_link if tp else LinkModel(10e9, 0.0)
        link = self.links.get((a, b)) or self.links.get((b, a))
        if link is not None:
            return link
        wan = self.wan()
        return LinkModel(bandwidth=wan.bandwidth,
                         latency_s=2.0 * max(wan.latency_s, 0.1))

    def with_wan(self, band: str) -> "ContinuumProfile":
        """The same continuum with every WAN link re-priced at a named
        band (the Fig-3 sweep's knob).  A link counts as WAN when it
        currently carries one of this profile's band prices — tier names
        don't matter, so custom continuums re-price correctly too."""
        wan = self.wan(band)
        band_links = set(self.wan_bands.values())
        links = {pair: (wan if link in band_links else link)
                 for pair, link in self.links.items()}
        return replace(self, links=links, default_wan=band)


def _default_profile() -> ContinuumProfile:
    wan = WAN_BANDS[DEFAULT_WAN_BAND]
    return ContinuumProfile(
        name="paper-testbed",
        tiers={
            "edge": TierProfile("edge", RASPI_4B),
            "cloud": TierProfile("cloud", CLOUD_CPU),
            "hpc": TierProfile("hpc", CLOUD_CPU),
        },
        links={
            ("edge", "cloud"): wan,
            ("edge", "hpc"): wan,
            ("cloud", "hpc"): LinkModel(bandwidth=1e9, latency_s=0.020),
        })


# the profile everything defaults to: the paper's RasPi + EC2 testbed with
# the constrained 10 Mbit/s WAN between edge and cloud/hpc
DEFAULT_PROFILE = _default_profile()
