"""Continuum hardware profiles — the single source of truth for every
device / tier / link parameter in the repo.

Before this subsystem existed the cost knowledge was triplicated:
``core/placement.py`` hardcoded ``EDGE_FLOPS``/``DEVICE_FLOPS``/
``DEFAULT_LINKS``, ``sim/scenarios.py`` hardcoded its own ``WAN_BANDS``
(with drifted latencies), and ``roofline/`` measured real HLO costs that
nothing consumed.  Now:

* :class:`DeviceProfile` — one device's sustained peak rates (the paper's
  testbed: sensor-class devices, RasPi-4-class edge nodes, fog gateways,
  EC2-class cloud workers),
* :class:`TierProfile` — a continuum tier (device / edge / fog / cloud /
  hpc) backed by a device profile plus its intra-tier link,
* :class:`LinkModel`  — bandwidth (bytes/s) + latency between tiers,
* :class:`Topology`   — the tier *graph*: tiers as nodes, links as edges,
  deterministic shortest-time multi-hop routing (:class:`Route`) with
  per-hop latency accumulation,
* :data:`WAN_BANDS`   — the paper's iPerf bands as the one shared link
  table (``sim.scenarios.WAN_BANDS`` and ``core.placement.DEFAULT_LINKS``
  are both import-time snapshots of the default continuum instance —
  pinned equal by a regression test),
* :class:`ContinuumProfile` — the assembled continuum the
  :class:`~repro.cost.model.CostModel` prices against.  The default
  instance is the 4-tier device/edge/fog/cloud continuum (plus the hpc
  accounting tier): transfers between tiers without a direct link ride
  the topology's routed multi-hop path.

Per-model compute costs (FLOPs/point, efficiencies, service-time noise)
live next door in :mod:`repro.cost.calibrate` — measured from the compiled
``repro.ml`` kernels, not asserted.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Mapping, Optional, Tuple


@dataclass(frozen=True)
class LinkModel:
    """Bandwidth (bytes/s) + latency between tiers."""
    bandwidth: float
    latency_s: float = 0.0

    @property
    def bandwidth_bps(self) -> float:
        """Bandwidth in bits/s (the WanShaper's unit)."""
        return self.bandwidth * 8.0


# The paper's iPerf WAN bands (§III, Fig 2/3): bandwidth is stored in
# bytes/s (LinkModel's unit); ``.bandwidth_bps`` recovers the bits/s the
# WanShaper wants. The constrained 10 Mbit/s point is the band the
# placement-sensitivity experiments run at.
WAN_BANDS: Dict[str, LinkModel] = {
    "10mbit": LinkModel(bandwidth=10e6 / 8.0, latency_s=0.150),
    "50mbit": LinkModel(bandwidth=50e6 / 8.0, latency_s=0.150),
    "100mbit": LinkModel(bandwidth=100e6 / 8.0, latency_s=0.140),
}
DEFAULT_WAN_BAND = "10mbit"


@dataclass(frozen=True)
class Hop:
    """One directed traversal of a link along a route."""
    src: str
    dst: str
    link: LinkModel


@dataclass(frozen=True)
class Route:
    """A multi-hop path through the continuum topology.

    Transfer time is store-and-forward: every hop serializes the full
    message (``nbytes / bandwidth``) and adds its own latency — per-hop
    latency *accumulates*, it is not collapsed to the slowest hop.
    """
    src: str
    dst: str
    hops: Tuple[Hop, ...]

    @property
    def tiers(self) -> Tuple[str, ...]:
        """The tier sequence the route visits (src first)."""
        return (self.src,) + tuple(h.dst for h in self.hops)

    @property
    def latency_s(self) -> float:
        return sum(h.link.latency_s for h in self.hops)

    def transfer_s(self, nbytes: float) -> float:
        """Seconds to move ``nbytes`` end to end (store-and-forward)."""
        return sum(nbytes / h.link.bandwidth + h.link.latency_s
                   for h in self.hops)

    def as_link(self) -> LinkModel:
        """The serialized-equivalent single link: store-and-forward over
        the hops equals one link with the harmonic-sum bandwidth and the
        accumulated latency, for *any* message size."""
        if not self.hops:
            return LinkModel(bandwidth=float("inf"), latency_s=0.0)
        inv_bw = sum(1.0 / h.link.bandwidth for h in self.hops)
        return LinkModel(bandwidth=1.0 / inv_bw, latency_s=self.latency_s)


class Topology:
    """The continuum tier graph: tiers as nodes, links as undirected
    edges, Dijkstra shortest-*time* routing.

    Edge weight for a transfer of ``nbytes`` is the store-and-forward hop
    time ``nbytes / bandwidth + latency_s``; with ``nbytes=0`` routing
    minimizes accumulated latency.  Ties break on (hop count, tier name)
    so routes are deterministic — a run is a pure function of the profile.
    """

    def __init__(self, links: Mapping[Tuple[str, str], LinkModel],
                 tiers: Iterable[str] = ()):
        self._adj: Dict[str, Dict[str, LinkModel]] = {t: {} for t in tiers}
        for (a, b), link in links.items():
            self._adj.setdefault(a, {})[b] = link
            self._adj.setdefault(b, {})[a] = link

    @property
    def tiers(self) -> Tuple[str, ...]:
        return tuple(sorted(self._adj))

    def neighbors(self, tier: str) -> Dict[str, LinkModel]:
        return dict(self._adj.get(tier, {}))

    def link(self, a: str, b: str) -> Optional[LinkModel]:
        """The direct link between two tiers, or None."""
        return self._adj.get(a, {}).get(b)

    def route(self, src: str, dst: str,
              nbytes: float = 0.0) -> Optional[Route]:
        """Shortest-time route ``src → dst`` for an ``nbytes`` message, or
        None when the tiers are disconnected.  ``route(a, a)`` is the
        empty route (zero hops, zero time)."""
        if src == dst:
            return Route(src, dst, ())
        if src not in self._adj or dst not in self._adj:
            return None
        # (total_time, hop_count, tier) keys: deterministic and
        # latency-accumulating; hop count then name break exact ties
        best: Dict[str, Tuple[float, int]] = {src: (0.0, 0)}
        prev: Dict[str, Tuple[str, LinkModel]] = {}
        heap: List[Tuple[float, int, str]] = [(0.0, 0, src)]
        done = set()
        while heap:
            t, n, tier = heapq.heappop(heap)
            if tier in done:
                continue
            done.add(tier)
            if tier == dst:
                break
            for nxt in sorted(self._adj[tier]):
                if nxt in done:
                    continue
                link = self._adj[tier][nxt]
                cost = t + nbytes / link.bandwidth + link.latency_s
                cand = (cost, n + 1)
                if nxt not in best or cand < best[nxt]:
                    best[nxt] = cand
                    prev[nxt] = (tier, link)
                    heapq.heappush(heap, (cost, n + 1, nxt))
        if dst not in prev:
            return None
        hops: List[Hop] = []
        at = dst
        while at != src:
            frm, link = prev[at]
            hops.append(Hop(frm, at, link))
            at = frm
        return Route(src, dst, tuple(reversed(hops)))


@dataclass(frozen=True)
class DeviceProfile:
    """Sustained peak rates of one device class.

    ``bf16_speedup``/``int8_speedup`` are the peak-rate multipliers a
    reduced-precision kernel variant enjoys on this device class — the
    hardware half of the precision placement axis.  Narrow-datapath SIMD
    roughly doubles per precision halving on general-purpose cores; the
    sensing SoC carries an NPU-class int8 path (the usual edge-accelerator
    story: int8 MACs are an order of magnitude denser than fp32).
    """
    name: str
    peak_flops: float              # FLOP/s at full efficiency (fp32)
    mem_bw: float = 0.0            # bytes/s (roofline memory term)
    memory_gb: float = 4.0
    bf16_speedup: float = 2.0
    int8_speedup: float = 4.0

    def speedup(self, precision: str = "fp32") -> float:
        """Peak-rate multiplier for a kernel precision variant."""
        if precision == "fp32":
            return 1.0
        if precision == "bf16":
            return self.bf16_speedup
        if precision == "int8":
            return self.int8_speedup
        raise ValueError(f"unknown precision {precision!r}")


# The continuum's device classes, sensor to datacenter. Device = the
# sensing SoC next to the data; edge = RasPi-class (1 core / 4 GB Dask
# task); fog = a metro gateway box between edge site and datacenter;
# cloud/hpc = one EC2-class worker core-set per Dask worker.
#
# The sensing SoC is the precision story's extreme point: an FPU-less
# MCU core does *software-emulated* fp32 at ~10 MFLOP/s, but carries a
# micro-NPU/DSP int8 path (Coral/K210-class) two orders of magnitude
# denser — fp32 models are infeasible where their int8 variants are not.
DEVICE_SOC = DeviceProfile("device-soc", peak_flops=1e7, mem_bw=1e9,
                           memory_gb=0.5, bf16_speedup=4.0,
                           int8_speedup=100.0)
RASPI_4B = DeviceProfile("raspi-4b", peak_flops=5e9, mem_bw=4e9,
                         memory_gb=4.0)
FOG_NODE = DeviceProfile("fog-node", peak_flops=20e9, mem_bw=10e9,
                         memory_gb=8.0)
CLOUD_CPU = DeviceProfile("cloud-cpu", peak_flops=50e9, mem_bw=20e9,
                          memory_gb=16.0)

# non-WAN continuum links of the default topology: the device→edge local
# hop (wireless/LAN) and the edge→fog metro hop. Distinct latency values
# from every WAN band so ``with_wan`` re-pricing never touches them.
DEVICE_EDGE_LINK = LinkModel(bandwidth=100e6 / 8.0, latency_s=0.005)
EDGE_FOG_LINK = LinkModel(bandwidth=100e6 / 8.0, latency_s=0.020)
CLOUD_HPC_LINK = LinkModel(bandwidth=1e9, latency_s=0.020)

# Metro (edge→fog) bands, sweepable exactly like the WAN bands.  All
# share the 20 ms metro latency — distinct from every WAN band's 140+ ms
# and from the 5 ms device hop, so ``with_wan`` / ``with_metro``
# re-pricing never cross-match each other's links.  The default
# ``100mbit`` band *is* :data:`EDGE_FOG_LINK`, so profiles that never
# sweep the metro hop are unchanged.
METRO_BANDS: Dict[str, LinkModel] = {
    "10mbit": LinkModel(bandwidth=10e6 / 8.0, latency_s=0.020),
    "50mbit": LinkModel(bandwidth=50e6 / 8.0, latency_s=0.020),
    "100mbit": EDGE_FOG_LINK,
}
DEFAULT_METRO_BAND = "100mbit"


@dataclass(frozen=True)
class TierProfile:
    """One continuum tier: which device backs it + its intra-tier link."""
    tier: str
    device: DeviceProfile
    # within a tier messages ride local links (LAN / host loopback)
    intra_link: LinkModel = LinkModel(bandwidth=10e9, latency_s=0.0)


@dataclass(frozen=True)
class ContinuumProfile:
    """The assembled continuum: tiers + inter-tier links + WAN bands."""
    name: str
    tiers: Mapping[str, TierProfile]
    links: Mapping[Tuple[str, str], LinkModel]
    wan_bands: Mapping[str, LinkModel] = field(
        default_factory=lambda: dict(WAN_BANDS))
    default_wan: str = DEFAULT_WAN_BAND
    metro_bands: Mapping[str, LinkModel] = field(
        default_factory=lambda: dict(METRO_BANDS))
    default_metro: str = DEFAULT_METRO_BAND

    def tier(self, name: str) -> TierProfile:
        try:
            return self.tiers[name]
        except KeyError:
            raise KeyError(f"unknown tier {name!r}; "
                           f"known: {sorted(self.tiers)}") from None

    def wan(self, band: Optional[str] = None) -> LinkModel:
        return self.wan_bands[band or self.default_wan]

    def metro(self, band: Optional[str] = None) -> LinkModel:
        return self.metro_bands[band or self.default_metro]

    @property
    def topology(self) -> Topology:
        """The tier graph (links as undirected edges) this profile routes
        multi-hop transfers over — built once per profile (the profile is
        frozen, so the graph is a pure function of it)."""
        topo = self.__dict__.get("_topology")
        if topo is None:
            topo = Topology(self.links, tiers=self.tiers)
            object.__setattr__(self, "_topology", topo)
        return topo

    def _fallback_link(self) -> LinkModel:
        """Disconnected tier pairs price at the default WAN band with a
        conservative doubled latency (the historical unknown-pair rule)."""
        wan = self.wan()
        return LinkModel(bandwidth=wan.bandwidth,
                         latency_s=2.0 * max(wan.latency_s, 0.1))

    def route(self, a: str, b: str, nbytes: float = 0.0) -> Route:
        """Shortest-time route between two tiers.  Same-tier traffic rides
        the intra-tier link as a single hop; cross-tier traffic takes the
        topology's routed path (one hop when a direct link exists — a
        detour is never picked unless it is strictly faster); tiers the
        topology cannot connect fall back to a single synthetic
        default-WAN hop so pricing never dead-ends."""
        if a == b:
            tp = self.tiers.get(a)
            intra = tp.intra_link if tp else LinkModel(10e9, 0.0)
            return Route(a, b, (Hop(a, b, intra),))
        r = self.topology.route(a, b, nbytes)
        if r is not None:
            return r
        return Route(a, b, (Hop(a, b, self._fallback_link()),))

    def link(self, a: str, b: str) -> LinkModel:
        """Effective link between two tiers: the direct link when one
        exists, otherwise the routed path's serialized-equivalent link
        (harmonic-sum bandwidth, accumulated latency)."""
        if a == b:
            tp = self.tiers.get(a)
            return tp.intra_link if tp else LinkModel(10e9, 0.0)
        link = self.links.get((a, b)) or self.links.get((b, a))
        if link is not None:
            return link
        return self.route(a, b).as_link()

    def with_wan(self, band: str) -> "ContinuumProfile":
        """The same continuum with every WAN link re-priced at a named
        band (the Fig-3 sweep's knob).  A link counts as WAN when it
        currently carries one of this profile's band prices — tier names
        don't matter, so custom continuums re-price correctly too."""
        wan = self.wan(band)
        band_links = set(self.wan_bands.values())
        links = {pair: (wan if link in band_links else link)
                 for pair, link in self.links.items()}
        return replace(self, links=links, default_wan=band)

    def with_metro(self, band: str) -> "ContinuumProfile":
        """The same continuum with every metro (edge→fog) link re-priced
        at a named metro band — the fog-placement analog of
        :meth:`with_wan`.  A link counts as metro when it currently
        carries one of this profile's metro band prices."""
        metro = self.metro(band)
        band_links = set(self.metro_bands.values())
        links = {pair: (metro if link in band_links else link)
                 for pair, link in self.links.items()}
        return replace(self, links=links, default_metro=band)


def _default_profile() -> ContinuumProfile:
    wan = WAN_BANDS[DEFAULT_WAN_BAND]
    return ContinuumProfile(
        name="paper-testbed",
        tiers={
            "device": TierProfile("device", DEVICE_SOC),
            "edge": TierProfile("edge", RASPI_4B),
            "fog": TierProfile("fog", FOG_NODE),
            "cloud": TierProfile("cloud", CLOUD_CPU),
            "hpc": TierProfile("hpc", CLOUD_CPU),
        },
        links={
            ("device", "edge"): DEVICE_EDGE_LINK,
            ("edge", "fog"): EDGE_FOG_LINK,
            ("fog", "cloud"): wan,
            ("edge", "cloud"): wan,
            ("edge", "hpc"): wan,
            ("cloud", "hpc"): CLOUD_HPC_LINK,
        })


# the profile everything defaults to: the 4-tier device/edge/fog/cloud
# continuum (plus the hpc accounting tier) built on the paper's RasPi +
# EC2 testbed, with the constrained 10 Mbit/s WAN between edge/fog and
# cloud/hpc. Tiers without a direct link (e.g. device→cloud) route
# multi-hop through the topology.
DEFAULT_PROFILE = _default_profile()
