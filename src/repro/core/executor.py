"""Execution strategies for :class:`~repro.core.faas.ContinuumPipeline`
(and its two-stage :class:`~repro.core.faas.EdgeToCloudPipeline` wrapper).

The pipeline's task loops (source devices, per-stage consumers) are
written once, as *cooperative generator bodies* (``faas._source_body`` /
``faas._stage_body``) that yield effects instead of blocking:

* :class:`Sleep`   — wait a number of seconds,
* :class:`Service` — charge a stage's service time (priced by the
  strategy's ``service_model``; zero by default),
* :class:`Poll`    — fetch the next message from a consumer group.

Both strategies accept any ``service_model(stage, ctx, payload) -> s``
callable; :meth:`repro.cost.model.CostModel.service_model` builds the
*calibrated* one — per-stage times derived from the measured ``repro.ml``
kernel costs, optionally with the calibrated lognormal service-time noise
(seeded, so DES runs stay bit-reproducible).

Two strategies interpret those effects:

* :class:`ThreadedExecutor` — real threads on :class:`TaskRuntime`
  (production / live-demo behaviour; effects resolve to blocking calls).
  This is the default and matches the pre-refactor pipeline exactly.
* :class:`SimExecutor` — a single-threaded discrete-event simulation on
  :class:`~repro.sim.scheduler.EventScheduler`: bodies run as DES actors,
  consumers are *event-driven* (woken by broker append notifications and
  exact WAN-visibility times — no polling sleeps), heartbeat monitoring,
  retries, crash/rebalance injection and the lag-driven
  :class:`~repro.core.elastic.AutoScaler` all run as scheduled events on
  one virtual clock. A run is a pure function of (pipeline config,
  executor config, seed): metrics are bit-identical across repeats.

``pipe.run(scheduler=SimExecutor(...))`` therefore exercises the *genuine*
pipeline — same broker offsets, consumer-group rebalances, dedup and
metrics stamps as production — under reproducible virtual time.

Both strategies speculate on stragglers at service-charge granularity
(``speculative_factor``, mirroring :class:`TaskRuntime`'s knob): a charge
running past ``factor × trailing median`` races a backup draw of the
service model, first completion wins, with deterministic win/loss/cancel
accounting (see :class:`SpeculationStats`).  Speculation is
**capacity-aware** (Dask-style work stealing): a backup occupies a
*different, idle* consumer slot of the same stage — under the DES the
first parked stage-mate is stolen for the duration of the race (it is
not woken for new messages until the race resolves); when no stage-mate
is idle the backup is not launched at all
(``runtime.speculative_no_capacity`` counts those skips).
"""
from __future__ import annotations

import itertools
import statistics
import threading
from collections import defaultdict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.broker import WanShaper
from repro.core.runtime import TaskContext, TaskRuntime
from repro.sim.clock import NULL_LOCK, SimClock
from repro.sim.scheduler import ActorKilled, EventScheduler

# service_model(stage, ctx, payload) -> seconds of service time to charge
ServiceModel = Callable[[str, TaskContext, Any], float]


# ---------------------------------------------------------------------------
# straggler speculation (shared between the strategies)
# ---------------------------------------------------------------------------


class SpeculationStats:
    """Trailing per-stage service durations + win/loss accounting.

    Mirrors :class:`~repro.core.runtime.TaskRuntime`'s straggler rule at
    *service-charge* granularity: once a stage has ``min_samples``
    completed charges, any charge still running past
    ``speculative_factor × trailing median`` gets a backup launched with a
    fresh service-model draw; the first completion wins.  Counters
    (``runtime.speculative_launches`` / ``_wins`` / ``_losses`` /
    ``_cancelled``) land in the run's MetricsRegistry; wins + losses +
    cancelled always equals launches.
    """

    MIN_SAMPLES = 3          # TaskRuntime._median_duration's warmup bar
    WINDOW = 256             # trailing window, trimmed like TaskRuntime

    def __init__(self, factor: float, metrics):
        self.factor = factor
        self.metrics = metrics
        self._durations: Dict[str, List[float]] = defaultdict(list)
        self._lock = threading.Lock()

    def record(self, stage: str, duration_s: float) -> None:
        if duration_s <= 0.0:
            return
        with self._lock:
            d = self._durations[stage]
            d.append(duration_s)
            if len(d) > self.WINDOW:
                del d[:self.WINDOW // 2]

    def threshold(self, stage: str) -> Optional[float]:
        """``factor × trailing median`` — or None during warmup."""
        with self._lock:
            d = self._durations[stage]
            if len(d) < self.MIN_SAMPLES:
                return None
            return self.factor * statistics.median(d)

    # -- accounting -------------------------------------------------------

    def launched(self) -> None:
        self.metrics.incr("runtime.speculative_launches")

    def resolved(self, backup_won: bool) -> None:
        self.metrics.incr("runtime.speculative_wins" if backup_won
                          else "runtime.speculative_losses")

    def cancelled(self) -> None:
        self.metrics.incr("runtime.speculative_cancelled")

    def no_capacity(self) -> None:
        """A straggler qualified for a backup but no idle slot of its
        stage existed to steal — the backup was not launched."""
        self.metrics.incr("runtime.speculative_no_capacity")

    # -- inline form (ThreadedExecutor) -----------------------------------

    def charge(self, stage: str, primary_s: float,
               redraw: Callable[[], float], *,
               try_steal: Optional[Callable[[], bool]] = None) -> float:
        """First-completion-wins arithmetic for a blocking strategy: a
        charge that would run past the threshold launches a backup
        (``redraw`` — a fresh draw of the same service model) at the
        threshold, and the effective charge is whichever finishes first.
        Threads can't race two sleeps for one generator step, so the race
        is resolved inline — same accounting, same clock outcome as the
        DES's event-scheduled race.

        Capacity awareness: when ``try_steal`` is given, the backup only
        launches if it returns True (an idle slot of this stage was
        claimed).  The claim is *kept* — the caller releases it after
        sleeping the effective charge, so the slot stays occupied for
        the race's duration like the DES helper.  Without the hook
        capacity is unconstrained (the pre-work-stealing behaviour, kept
        for unit use)."""
        if primary_s <= 0.0:
            return primary_s
        th = self.threshold(stage)
        if th is None or primary_s <= th:
            self.record(stage, primary_s)
            return primary_s
        if try_steal is not None and not try_steal():
            self.no_capacity()
            self.record(stage, primary_s)
            return primary_s
        self.launched()
        backup_total = th + max(redraw(), 0.0)
        backup_won = backup_total < primary_s
        self.resolved(backup_won)
        effective = min(primary_s, backup_total)
        self.record(stage, effective)
        return effective


# ---------------------------------------------------------------------------
# effects
# ---------------------------------------------------------------------------


class Sleep:
    """Wait ``seconds`` (virtual under SimExecutor, clock-real otherwise).

    Effects are mutable slotted records on purpose: a pipeline body
    allocates one per effect kind and rewrites its fields per iteration
    (the interpreter consumes an effect synchronously at the yield point,
    so reuse is safe) — at a million messages the per-yield dataclass
    churn was a measurable slice of the event loop."""

    __slots__ = ("seconds",)

    def __init__(self, seconds: float):
        self.seconds = seconds


class Service:
    """Charge the strategy's service model for one ``stage`` invocation."""

    __slots__ = ("stage", "payload")

    def __init__(self, stage: str, payload: Any = None):
        self.stage = stage
        self.payload = payload


class Poll:
    """Next message from ``group`` for ``consumer_id`` — or ``None``.

    Threaded: a blocking ``group.poll(timeout_s)`` (periodic ``None``
    returns let the body re-check stop/idle conditions). Sim: the actor
    parks until an append notification, the message's WAN ``ready_at``, a
    stop, or ``wake_at`` (the body's idle deadline) — no idle ticking.

    ``stage`` names the polling stage so the threaded strategy can keep
    its per-stage idle-slot ledger (capacity-aware speculation).
    """

    __slots__ = ("group", "consumer_id", "timeout_s", "wake_at", "stage")

    def __init__(self, group: Any, consumer_id: str, timeout_s: float = 0.2,
                 wake_at: Optional[float] = None,
                 stage: Optional[str] = None):
        self.group = group
        self.consumer_id = consumer_id
        self.timeout_s = timeout_s
        self.wake_at = wake_at
        self.stage = stage


# ---------------------------------------------------------------------------
# threaded strategy (today's behaviour)
# ---------------------------------------------------------------------------


class ThreadedExecutor:
    """Run the pipeline bodies on real threads via :class:`TaskRuntime`.

    ``service_model`` is optional wall-pacing (used by live demos to make
    stage costs real — ``examples/edge_to_cloud_outlier.py`` paces with
    the calibrated continuum costs, and a slow-marked test pins its
    throughput against the SimExecutor prediction); by default effects
    cost nothing and behaviour is identical to the historical
    thread-scheduled pipeline.

    ``speculative_factor`` (default: the pipeline's) enables straggler
    speculation at service-charge granularity when a service model is
    set: a charge running past ``factor × trailing median`` launches a
    backup draw, first completion wins (see :class:`SpeculationStats`).
    Charge-level speculation supersedes :class:`TaskRuntime`'s whole-body
    speculation (re-running an entire consumer loop only manufactures
    duplicates), so the runtimes get ``speculative_factor=0`` then.
    """

    def __init__(self, *, service_model: Optional[ServiceModel] = None,
                 speculative_factor: Optional[float] = None):
        self.service_model = service_model
        self.speculative_factor = speculative_factor
        self.speculation: Optional[SpeculationStats] = None

    def run(self, pipe, *, n_messages: int, timeout_s: float,
            collect_results: bool):
        clock = pipe._clock
        if getattr(clock, "auto_advance", False):
            # concurrent waiters would race a fast-forward clock past the
            # run deadline while work is in flight; auto-advance virtual
            # time belongs to the single-threaded SimExecutor.
            raise ValueError(
                "ThreadedExecutor needs a wall clock or a manually driven "
                "SimClock(auto_advance=False); pass "
                "scheduler=SimExecutor(...) for auto-advance virtual time")
        state = pipe._setup_run(n_messages, timeout_s, collect_results)
        t0 = clock.now()
        factor = (self.speculative_factor
                  if self.speculative_factor is not None
                  else pipe._runtime_kw["speculative_factor"])
        runtime_kw = dict(pipe._runtime_kw)
        # per-run reset: a reused executor must not carry the previous
        # pipeline's stats (or metrics registry) into this run
        self.speculation = None
        # the executor-level factor overrides the pipeline's for *all*
        # speculation (an explicit 0.0 disables it outright, matching
        # SimExecutor); with a service model the charge-level race
        # supersedes TaskRuntime's whole-body speculation, without one
        # the runtimes speculate bodies at the resolved factor
        runtime_kw["speculative_factor"] = factor
        if factor > 0 and self.service_model is not None:
            self.speculation = SpeculationStats(factor, pipe.metrics)
            runtime_kw["speculative_factor"] = 0.0

        def _try_steal(stage: str) -> bool:
            """Claim an idle slot of ``stage`` for a backup (work
            stealing): only consumers currently parked in a poll count."""
            with state.lock:
                if state.idle.get(stage, 0) > 0:
                    state.idle[stage] -= 1
                    return True
            return False

        def _release_slot(stage: str) -> None:
            with state.lock:
                state.idle[stage] = state.idle.get(stage, 0) + 1

        def interpret(ctx: TaskContext, eff: Any) -> Any:
            if isinstance(eff, Sleep):
                clock.sleep(max(eff.seconds, 0.0))
                return None
            if isinstance(eff, Service):
                s = (self.service_model(eff.stage, ctx, eff.payload)
                     if self.service_model else 0.0)
                stole = False
                if self.speculation is not None and s > 0:
                    def steal():
                        nonlocal stole
                        stole = _try_steal(eff.stage)
                        return stole
                    # the claim is held for the duration of the
                    # effective charge (released below, after the
                    # sleep), mirroring the DES's helper occupancy —
                    # overlapping stragglers cannot all steal one slot
                    s = self.speculation.charge(
                        eff.stage, s,
                        lambda: self.service_model(eff.stage, ctx,
                                                   eff.payload),
                        try_steal=steal)
                try:
                    if s > 0:
                        clock.sleep(s)
                finally:
                    if stole:
                        _release_slot(eff.stage)
                return None
            if isinstance(eff, Poll):
                # idle-slot ledger: a consumer blocked in a poll is a
                # steal target for capacity-aware speculation
                if eff.stage is not None:
                    with state.lock:
                        state.idle[eff.stage] = \
                            state.idle.get(eff.stage, 0) + 1
                try:
                    return eff.group.poll(eff.consumer_id,
                                          timeout_s=eff.timeout_s)
                finally:
                    if eff.stage is not None:
                        with state.lock:
                            state.idle[eff.stage] -= 1
            raise TypeError(f"unknown pipeline effect {eff!r}")

        runtimes = [TaskRuntime(stage.pilot, pipe.metrics,
                                interpreter=interpret, **runtime_kw)
                    for stage in pipe.stages]
        producer_futs = [
            runtimes[0].submit(pipe._source_body, state, i,
                               state.per_device[i])
            for i in range(pipe.stage_tasks(0))]
        consumer_futs = []
        for si in range(1, len(pipe.stages)):
            consumer_futs.extend(
                runtimes[si].submit(pipe._stage_body, state, si,
                                    pipe.stage_cid(si, i))
                for i in range(pipe.stage_tasks(si)))

        # online re-advisory: a daemon monitor thread ticks the attached
        # ReAdvisor against the wall clock; a decision re-binds the
        # watched stage, bumps its placement epoch (old threads drain at
        # their next poll loop-top) and submits a replacement fleet on a
        # fresh TaskRuntime bound to the winning pilot
        rv = pipe._readvise
        rv_thread = None
        if rv is not None:
            rv_si = next(i for i, st in enumerate(pipe.stages)
                         if st.name == rv.stage)
            if rv_si == 0:
                raise ValueError("the source stage cannot be re-advised — "
                                 "watch a consumer stage")
            stage_seq = {si: itertools.count(pipe.stage_tasks(si))
                         for si in range(1, len(pipe.stages))}
            rv.begin(t0)

            def _rv_loop():
                while not state.stop.wait(rv.interval_s):
                    dec = rv.step(
                        now=clock.now(), metrics=pipe.metrics,
                        topic=state.topics[rv_si - 1].name,
                        current_tier=pipe.stages[rv_si].pilot.tier,
                        src_tier=pipe.stages[rv_si - 1].pilot.tier)
                    if dec is None:
                        continue
                    pipe.metrics.event(
                        "readvise_decision", stage=pipe.stages[rv_si].name,
                        from_tier=dec.from_tier, to_tier=dec.to_tier)
                    if rv.apply_delay_s > 0 and state.stop.wait(
                            rv.apply_delay_s):
                        return
                    pipe.rebind_stage(pipe.stages[rv_si].name,
                                      rv.pilot_for(dec.to_tier))
                    with state.lock:
                        state.stage_epoch[rv_si] = \
                            state.stage_epoch.get(rv_si, 0) + 1
                    rt = TaskRuntime(pipe.stages[rv_si].pilot, pipe.metrics,
                                     interpreter=interpret, **runtime_kw)
                    runtimes.append(rt)
                    for _ in range(pipe.stage_tasks(rv_si)):
                        cid = pipe.stage_cid(rv_si, next(stage_seq[rv_si]))
                        pipe.metrics.event("consumer_spawned", consumer=cid)
                        consumer_futs.append(
                            rt.submit(pipe._stage_body, state, rv_si, cid))
                    rv.applied(dec, clock.now())

            rv_thread = threading.Thread(target=_rv_loop, daemon=True,
                                         name="readvise-monitor")
            rv_thread.start()

        # the semaphore wait is real (worker threads are real) but the
        # deadline is measured on the injected clock; with a virtual clock
        # the real wait must stay short so deadline advances (driven from
        # another thread) are observed promptly
        deadline = t0 + timeout_s
        remaining = n_messages
        while remaining > 0:
            wait_s = min(deadline - clock.now(), timeout_s)
            if clock.virtual:
                wait_s = min(wait_s, 0.05)
            if state.processed_sem.acquire(timeout=max(wait_s, 0.01)):
                remaining -= 1
            elif clock.now() >= deadline:
                break
        state.stop.set()
        wall = (state.t_done if state.t_done is not None
                else clock.now()) - t0     # before any shutdown nudging
        if rv_thread is not None:
            rv_thread.join(timeout=5.0)
        for f in producer_futs + consumer_futs:
            # with a manual virtual clock, workers may be parked inside
            # clock.sleep waiting for time the external driver will never
            # provide once the run is over — tick the clock while joining
            # so their poll loops observe stop and exit
            for _ in range(1000):           # ~10 s real bound per future
                if clock.virtual:
                    clock.advance(0.01)
                try:
                    f.result(timeout=0.01)
                    break
                except TimeoutError:
                    continue
                except Exception:  # noqa: BLE001 — task errors already counted
                    break
        for rt in runtimes:
            rt.shutdown(wait=False)
        return pipe._finish(state, wall)


# ---------------------------------------------------------------------------
# DES strategy
# ---------------------------------------------------------------------------


class _PollWait:
    """A consumer actor parked on an empty Poll, waiting to be woken.
    ``timeout_ev`` is the scheduled fallback wake (WAN ready_at or the
    body's idle deadline), cancelled when something wakes the wait first.

    One instance per consumer record, reused across parks: ``gen`` is
    bumped on every re-park so wake callbacks scheduled for an earlier
    park (an append's wake event racing a timeout, say) recognise
    themselves as stale instead of waking the *next* park early.
    ``topic_id``/``parts`` record where the wait is registered in the
    run's per-(topic, partition) waiter index."""

    __slots__ = ("rec", "actor", "eff", "resolved", "timeout_ev", "gen",
                 "topic_id", "parts")

    def __init__(self, rec: dict, actor, eff: Poll):
        self.rec = rec
        self.actor = actor
        self.eff = eff
        self.resolved = False
        self.timeout_ev = None
        self.gen = 0
        self.topic_id = 0
        self.parts: Sequence[int] = ()


class _ServiceOp:
    """One in-flight Service charge racing an (eventual) speculative
    backup.  ``primary_ev`` fires at the primary draw's completion;
    ``check_ev`` fires at ``factor × trailing median`` and — if an idle
    stage-mate's slot can be stolen — launches the backup on that slot;
    ``backup_ev`` fires at the backup's completion.  Whichever completion
    event fires first resolves the op, cancels the loser, releases the
    stolen slot, and resumes the actor."""

    __slots__ = ("rec", "actor", "stage", "ctx", "payload", "t0",
                 "primary_ev", "check_ev", "backup_ev", "backup_launched",
                 "resolved", "helper", "helper_eff")

    def __init__(self, rec: dict, actor, stage: str, payload: Any,
                 t0: float):
        self.rec = rec
        self.actor = actor
        self.stage = stage
        self.payload = payload
        self.t0 = t0
        self.primary_ev = None
        self.check_ev = None
        self.backup_ev = None
        self.backup_launched = False
        self.resolved = False
        self.helper = None         # the stage-mate whose slot the backup runs on
        self.helper_eff = None     # its interrupted Poll, re-attempted on release

    def cancel_events(self) -> None:
        for ev in (self.primary_ev, self.check_ev, self.backup_ev):
            if ev is not None:
                ev.cancel()
        self.primary_ev = self.check_ev = self.backup_ev = None


class _NullSemaphore:
    """No-op semaphore for the single-owner DES path: the DES never
    blocks on ``processed_sem`` (completion is observed via
    ``state.stop``), so the per-message release is pure lock traffic."""

    __slots__ = ()

    def release(self, n: int = 1) -> None:
        pass

    def acquire(self, blocking: bool = True,
                timeout: Optional[float] = None) -> bool:
        return True


class SimExecutor:
    """Single-threaded DES strategy: the whole pipeline run — producers,
    consumers, WAN visibility, heartbeat monitoring, retries, crash
    injection, autoscaling — executes as events on one auto-advance
    :class:`SimClock`, bit-reproducibly. Single use: build one per run.

    Parameters
    ----------
    clock: the pipeline's auto-advance ``SimClock`` (adopted from the
        pipeline if omitted — the pipeline must then have been constructed
        with one, so broker/metrics stamps share the virtual timeline).
    service_model: prices ``Service`` effects (seconds per stage call) —
        how emulated runs charge compute time for stages whose real
        execution is instantaneous in virtual time.
    producer_offsets: per-device start offsets (virtual seconds) so edge
        devices don't boot in lockstep.
    crash_plan: objects with ``at_s`` / ``consumer_idx`` /
        ``restart_after_s`` / optional ``kind`` (``"crash"`` raises inside
        the consumer mid-run; ``"silent"`` goes dark so the heartbeat
        monitor must detect the loss). ``repro.sim.scenarios.FailureSpec``
        matches this shape.
    drift_plan: mid-run environment drift events — objects with ``at_s``
        and ``kind`` (``"band"``: re-price a hop's live
        :class:`~repro.core.broker.WanShaper` in place —
        ``hop``/``bandwidth_bps``/``rtt_s``; ``"churn"``: grow/shrink a
        consumer stage's fleet by ``delta`` — ``stage`` defaults to the
        final stage; ``"outage"``: kill every consumer of stages bound
        to ``tier``), each with optional ``restore_after_s``.
        ``repro.sim.scenarios.DriftSpec`` matches this shape.  Scheduled
        as ordinary events, so drifted runs stay bit-reproducible.
    readvisor: a :class:`~repro.cost.readvisor.ReAdvisor` watching the
        run's observed hop delay against the cost-model prediction; the
        executor ticks it every ``readvisor.interval_s`` of virtual time
        and applies its hot-swap decisions (stage re-bind + consumer
        migration).  ``pipe.run(readvise=...)`` is the other way to
        attach one.
    autoscaler: an :class:`~repro.core.elastic.AutoScaler` for the *final*
        stage, stepped every ``autoscale_interval_s`` of virtual time;
        after each resize the executor grows/shrinks the live consumer
        pool to the pilot's worker count (scaling decisions visibly
        change the dataflow).
    autoscalers: per-stage policies — a mapping of stage (index, negative
        index, or stage name) to AutoScaler, each reconciling *its* stage's
        consumer pool.  Stage 0 (the sources) cannot be autoscaled.  May be
        combined with ``autoscaler`` (which is shorthand for the final
        stage); a bursty open-loop arrival process typically wants a
        policy on every consumer stage so traffic doesn't just queue at
        the first hop.
    speculative_factor: straggler speculation at service-charge
        granularity (default: the pipeline's ``speculative_factor``,
        mirroring :class:`TaskRuntime`'s knob under virtual time).  A
        Service charge still running past ``factor × trailing median``
        of its stage's completed charges spawns a backup — a fresh draw
        of the service model racing the primary as scheduled events,
        first completion wins (see :class:`SpeculationStats`).  The
        backup is capacity-aware work stealing: it occupies the first
        *idle* (parked) stage-mate's slot, which stops taking new
        messages until the race resolves — and when no stage-mate is
        idle the backup is skipped (``runtime.speculative_no_capacity``).
        Win / loss / cancel counts land in the run metrics and stay
        bit-identical across repeats.
    """

    def __init__(self, clock: Optional[SimClock] = None, *,
                 service_model: Optional[ServiceModel] = None,
                 producer_offsets: Sequence[float] = (),
                 crash_plan: Sequence[Any] = (),
                 drift_plan: Sequence[Any] = (),
                 readvisor=None,
                 autoscaler=None,
                 autoscalers: Optional[Dict[Any, Any]] = None,
                 autoscale_interval_s: float = 0.2,
                 monitor_interval_s: float = 0.5,
                 speculative_factor: Optional[float] = None):
        self.clock = clock
        self.service_model = service_model
        self.producer_offsets = tuple(producer_offsets)
        self.crash_plan = tuple(crash_plan)
        self.drift_plan = tuple(drift_plan)
        self.readvisor = readvisor
        self.autoscaler = autoscaler
        self.autoscalers = dict(autoscalers) if autoscalers else {}
        self.autoscale_interval_s = autoscale_interval_s
        self.monitor_interval_s = monitor_interval_s
        self.speculative_factor = speculative_factor
        self.speculation: Optional[SpeculationStats] = None
        self.sched: Optional[EventScheduler] = None

    def _prepare(self, pipe, n_messages: int, timeout_s: float,
                 collect_results: bool):
        clock = pipe._clock
        if self.clock is None:
            self.clock = clock
        if self.clock is not clock:
            raise ValueError(
                "SimExecutor clock must be the pipeline's clock object "
                "(broker/metrics/autoscaler all stamp the same timeline)")
        if not (isinstance(clock, SimClock) and clock.auto_advance):
            raise ValueError(
                "SimExecutor needs the pipeline built on an auto-advance "
                "SimClock: EdgeToCloudPipeline(..., clock=SimClock())")
        self.sched = EventScheduler(clock)
        return pipe._setup_run(n_messages, timeout_s, collect_results)

    def run(self, pipe, *, n_messages: int, timeout_s: float,
            collect_results: bool):
        state = self._prepare(pipe, n_messages, timeout_s, collect_results)
        return _SimRun(self, pipe, state).execute()

    def begin(self, pipe, *, n_messages: int, timeout_s: float,
              collect_results: bool) -> "_SimRun":
        """Windowed entry point (sharded DES): set up and *start* a run —
        spawn every actor, subscribe topic callbacks — without draining
        the scheduler.  The caller advances virtual time in bounded
        windows via ``advance_to(t)`` (conservative time-window
        synchronization), injects cross-shard boundary messages between
        windows, and calls ``finish()`` when ``done``."""
        state = self._prepare(pipe, n_messages, timeout_s, collect_results)
        run = _SimRun(self, pipe, state)
        run.start()
        return run


class _SimRun:
    """One SimExecutor pipeline run's actor/task bookkeeping."""

    def __init__(self, ex: SimExecutor, pipe, state):
        self.ex = ex
        self.pipe = pipe
        self.state = state
        self.sched = ex.sched
        self.clock = ex.clock
        self.metrics = pipe.metrics
        self.max_retries = pipe._runtime_kw["max_retries"]
        self.heartbeat_timeout_s = pipe._runtime_kw["heartbeat_timeout_s"]
        self.tasks: Dict[str, dict] = {}
        self.consumer_recs: List[dict] = []       # spawn order (autoscale)
        self._task_seq = itertools.count()
        self._subs: List = []                     # per-topic callbacks
        # (id(topic), partition) -> {id(wait): wait}: which parked
        # consumers an append to that partition can possibly wake — the
        # O(1) replacement for scanning every task per message
        self._waiters: Dict[Any, Dict[int, _PollWait]] = {}
        self._rebal_ev = None        # coalesced pending rebalance wake-all
        self.shared: dict = {}
        # per-stage autoscaling: the legacy single `autoscaler` is
        # shorthand for the final stage; `autoscalers` maps stage
        # index/name to a scaler. cid counters continue each stage's
        # static numbering.
        self.autoscalers: Dict[int, Any] = {}
        if ex.autoscaler is not None:
            self.autoscalers[len(pipe.stages) - 1] = ex.autoscaler
        for key, scaler in ex.autoscalers.items():
            si = self._resolve_stage(key)
            if si == 0:
                raise ValueError("stage 0 (the sources) cannot be "
                                 "autoscaled — sources are not consumers")
            self.autoscalers[si] = scaler
        # every consumer stage gets a cid counter continuing its static
        # numbering: autoscaling, churn drift, outage recovery and swap
        # migration all mint fresh cids from it
        self._stage_seq: Dict[int, Any] = {
            si: itertools.count(pipe.stage_tasks(si))
            for si in range(1, len(pipe.stages))}
        # online re-advisory: executor-level readvisor wins; otherwise the
        # one run(readvise=...) parked on the pipeline (captured here —
        # launch() clears pipe._readvise when begin() returns)
        self.readvisor = (ex.readvisor if ex.readvisor is not None
                          else getattr(pipe, "_readvise", None))
        self._rv_stage: Optional[int] = None
        factor = (ex.speculative_factor if ex.speculative_factor is not None
                  else pipe._runtime_kw["speculative_factor"])
        self.speculation = (SpeculationStats(factor, pipe.metrics)
                            if factor > 0 and ex.service_model is not None
                            else None)
        ex.speculation = self.speculation

    def _resolve_stage(self, key) -> int:
        stages = self.pipe.stages
        if isinstance(key, str):
            for i, st in enumerate(stages):
                if st.name == key:
                    return i
            raise ValueError(f"unknown stage {key!r} "
                             f"(have {[s.name for s in stages]})")
        si = int(key)
        if si < 0:
            si += len(stages)
        if not 0 <= si < len(stages):
            raise ValueError(f"stage index {key} out of range")
        return si

    # -- lifecycle ---------------------------------------------------------

    def _elide_locks(self) -> None:
        """Single-owner lock elision: this DES run is the only thread
        touching its pipeline, broker topics, metrics and run state, so
        every internal lock on the per-event path is pure overhead (the
        ``--profile`` mode shows lock acquire/release and the locked
        ``poll_nowait`` variant as the top non-algorithmic costs).  Real
        locks are restored in :meth:`finish` so the pipeline objects stay
        safe for a later threaded run."""
        state, pipe = self.state, self.pipe
        state.lock = NULL_LOCK
        state.processed_sem = _NullSemaphore()
        pipe._fn_lock = NULL_LOCK
        self.metrics.elide_lock(True)
        for topic in state.topics:
            topic.single_owner = True
        if self.speculation is not None:
            self.speculation._lock = NULL_LOCK

    def _restore_locks(self) -> None:
        self.pipe._fn_lock = threading.Lock()
        self.metrics.elide_lock(False)
        self.state.lock = threading.Lock()
        if self.speculation is not None:
            self.speculation._lock = threading.Lock()

    def start(self) -> None:
        """Spawn every actor and periodic tick; events run on the first
        ``advance_to`` call."""
        pipe, state = self.pipe, self.state
        t0 = self.t0 = self.clock.now()
        self.deadline = t0 + state.timeout_s
        self._finished = False
        self._elide_locks()
        for topic in state.topics:
            cb = (lambda partition, ready_at, topic=topic:
                  self._on_append(topic, partition, ready_at))
            self._subs.append((topic, cb))
            topic.subscribe(cb)
        offs = self.ex.producer_offsets
        for i, count in enumerate(state.per_device):
            off = offs[i] if i < len(offs) else 0.0
            self._spawn("producer", None, stage=0,
                        at=t0 + max(off, 0.0),
                        body=lambda ctx, i=i, c=count:
                        pipe._source_body(ctx, state, i, c))
        for si in range(1, len(pipe.stages)):
            for i in range(pipe.stage_tasks(si)):
                self._spawn_consumer(pipe.stage_cid(si, i), si, at=t0)
        for f in self.ex.crash_plan:
            self.sched.at(t0 + float(f.at_s), lambda f=f: self._inject(f))
        for d in self.ex.drift_plan:
            self.sched.at(t0 + float(d.at_s),
                          lambda d=d: self._apply_drift(d))
        rv = self.readvisor
        if rv is not None:
            self._rv_stage = self._resolve_stage(rv.stage)
            if self._rv_stage == 0:
                raise ValueError("the source stage cannot be re-advised — "
                                 "watch a consumer stage")
            rv.begin(t0)
            self.sched.at(t0 + rv.interval_s, self._readvise_tick)
        if self.autoscalers:
            self.sched.after(self.ex.autoscale_interval_s,
                             self._autoscale_tick)
        self.sched.after(self.ex.monitor_interval_s, self._monitor_tick)

    def advance_to(self, t: float) -> None:
        """Drain events up to virtual time ``min(t, deadline)``.  On a
        window that drains early the clock still advances to the window
        edge (``EventScheduler.run(until=)`` semantics), so every shard
        observes the same window boundary."""
        self.sched.run(until=min(t, self.deadline),
                       stop=self.state.stop.is_set)

    @property
    def done(self) -> bool:
        """The run can make no more progress on its own: the pipeline
        reported completion (``stop``) or no events remain scheduled
        (an injected boundary message re-arms the scheduler)."""
        return self.state.stop.is_set() or len(self.sched) == 0

    def finish(self):
        """Close the run and return its :class:`PipelineResult`."""
        state = self.state
        if self._finished:
            return self._result
        self._finished = True
        if state.t_done is None:
            state.t_done = min(self.clock.now(), self.deadline)
        state.stop.set()
        for topic, cb in self._subs:
            topic.unsubscribe(cb)
        # unresolved speculation races at run end: the loser was never
        # decided — account the launched backups as cancelled so
        # wins + losses + cancelled always equals launches
        for rec in list(self.tasks.values()):
            self._cancel_service(rec)
        self._restore_locks()
        self._result = self.pipe._finish(state, state.t_done - self.t0)
        return self._result

    def execute(self):
        # the whole run is one scheduler call: the loop stays inside
        # EventScheduler.run (no per-event next_time/step round-trip),
        # stopping the moment the pipeline reports completion
        self.start()
        try:
            self.advance_to(self.deadline)
        finally:
            result = self.finish()
        return result

    # -- task spawning -----------------------------------------------------

    def _spawn(self, kind: str, cid: Optional[str], *, stage: int, body,
               at: Optional[float] = None) -> dict:
        pilot = self.pipe.stages[stage].pilot
        pilot.require_active()
        rec = {"task_id": f"{pilot.pilot_id}-sim-{next(self._task_seq)}",
               "kind": kind, "cid": cid, "stage": stage,
               "make_body": body, "pilot": pilot,
               "group": (self.state.groups[stage - 1]
                         if kind == "consumer" else None),
               "attempt": 0, "retries_left": self.max_retries,
               "actor": None, "ctx": None, "wait": None, "svc": None,
               "pollwait": None,                  # reusable _PollWait slot
               "helping": None,
               "sleep_until": 0.0,   # framework-scheduled wake (timed sleep)
               "last_beat": self.clock.now(), "exit_reason": None}
        self.tasks[rec["task_id"]] = rec
        if kind == "consumer":
            self.consumer_recs.append(rec)
        self.metrics.incr("runtime.submitted")
        self._launch(rec, at=at)
        return rec

    def _spawn_consumer(self, cid: str, stage: int,
                        at: Optional[float] = None) -> dict:
        pipe, state = self.pipe, self.state
        return self._spawn(
            "consumer", cid, stage=stage, at=at,
            body=lambda ctx, cid=cid, stage=stage:
            pipe._stage_body(ctx, state, stage, cid))

    def _launch(self, rec: dict, at: Optional[float] = None) -> None:
        if self.state.stop.is_set() or rec["task_id"] not in self.tasks:
            return
        pilot = rec["pilot"]
        ctx = TaskContext(
            pilot_id=pilot.pilot_id, tier=pilot.tier,
            task_id=rec["task_id"], attempt=rec["attempt"],
            shared=self.shared, clock=self.clock,
            _heartbeat=lambda: self._beat(rec))
        rec["ctx"] = ctx
        rec["last_beat"] = self.clock.now()
        rec["actor"] = self.sched.spawn(
            rec["make_body"](ctx), name=rec["task_id"], at=at,
            interpret=lambda actor, eff: self._interpret(rec, actor, eff),
            on_exit=lambda actor, exc, res: self._on_exit(rec, exc))
        if rec["kind"] == "consumer":
            # the new member's join rebalances partition assignments —
            # parked survivors may now own pending messages. Scheduled at
            # the same timestamp (later insertion seq), this runs right
            # after the actor's first step, i.e. after its group.join.
            # Coalesced: a fleet of same-instant launches (startup, an
            # autoscale burst) triggers ONE wake-all, after the *last*
            # join — reschedule (cancel + re-push, later seq) instead of
            # stacking an O(fleet) wake-all per member. Any not-yet-fired
            # wake is for this same instant (events run in time order),
            # so moving it behind the newest join loses nothing.
            if self._rebal_ev is not None:
                self._rebal_ev.cancel()
            self._rebal_ev = self.sched.at(
                self.clock.now() if at is None else at, self._rebal_wake)

    def _beat(self, rec: dict) -> None:
        rec["last_beat"] = self.clock.now()

    # -- effect interpretation --------------------------------------------

    def _interpret(self, rec: dict, actor, eff: Any) -> None:
        self._beat(rec)
        if isinstance(eff, Sleep):
            # a timed sleep is framework-scheduled, not hung: record the
            # wake time so the monitor leaves the actor alone (open-loop
            # trace replay sleeps out arbitrarily long arrival gaps)
            delay = max(eff.seconds, 0.0)
            rec["sleep_until"] = self.clock.now() + delay
            actor.resume(None, delay=delay)
            return
        if isinstance(eff, Service):
            model = self.ex.service_model
            secs = (model(eff.stage, rec["ctx"], eff.payload)
                    if model is not None else 0.0)
            if self.speculation is not None and secs > 0.0:
                self._begin_service(rec, actor, eff, max(secs, 0.0))
                return
            secs = max(secs, 0.0)
            rec["sleep_until"] = self.clock.now() + secs
            actor.resume(None, delay=secs)
            return
        if isinstance(eff, Poll):
            self._attempt_poll(rec, actor, eff)
            return
        actor.kill(TypeError(f"unknown pipeline effect {eff!r}"))

    def _attempt_poll(self, rec: dict, actor, eff: Poll) -> None:
        if not actor.alive:
            return
        state = self.state
        if state.stop.is_set() or state.n_processed >= state.n_messages:
            rec["wait"] = None
            actor.resume(None)
            return
        msg, ready = eff.group.poll_nowait(eff.consumer_id)
        if msg is not None:
            rec["wait"] = None
            self._beat(rec)
            actor.resume(msg)
            return
        # park until an append / stop / the fallback wake. Parked on the
        # framework — including waiting out a WAN-crossing message's exact
        # ready_at — is not a hung task: the monitor skips recs with a
        # live wait, and _beat keeps the timestamps honest.
        self._beat(rec)
        wait = rec["pollwait"]
        if wait is None:
            wait = _PollWait(rec, actor, eff)
            rec["pollwait"] = wait
        else:
            wait.actor = actor
            wait.eff = eff
            wait.resolved = False
            wait.timeout_ev = None
            wait.gen += 1
        rec["wait"] = wait
        # index the wait under its assigned (topic, partition) keys so an
        # append wakes exactly the consumers that can see the message
        group = eff.group
        tid = id(group.topic)
        parts = group.partitions_for(eff.consumer_id)
        wait.topic_id = tid
        wait.parts = parts
        waiters = self._waiters
        for p in parts:
            d = waiters.get((tid, p))
            if d is None:
                waiters[(tid, p)] = d = {}
            d[id(wait)] = wait
        if ready is not None:
            # message in flight across the WAN: exact wakeup at ready_at
            wait.timeout_ev = self.sched.at(
                ready, lambda w=wait, g=wait.gen: self._wake(w, False, g))
        elif eff.wake_at is not None:
            wait.timeout_ev = self.sched.at(
                eff.wake_at,
                lambda w=wait, g=wait.gen: self._wake(w, True, g))

    def _unregister(self, wait: _PollWait) -> None:
        waiters, tid = self._waiters, wait.topic_id
        for p in wait.parts:
            d = waiters.get((tid, p))
            if d is not None:
                d.pop(id(wait), None)
        wait.parts = ()

    def _wake(self, wait: _PollWait, timed_out: bool,
              gen: Optional[int] = None) -> None:
        if gen is not None and gen != wait.gen:
            return                      # wake scheduled for an earlier park
        if wait.resolved or not wait.actor.alive:
            return
        wait.resolved = True
        self._unregister(wait)
        wait.rec["wait"] = None
        if wait.timeout_ev is not None:
            wait.timeout_ev.cancel()
            wait.timeout_ev = None
        self._beat(wait.rec)
        if timed_out or self.state.stop.is_set():
            wait.actor.resume(None)
            return
        self._attempt_poll(wait.rec, wait.actor, wait.eff)

    def _on_append(self, topic, partition: int, ready_at: float) -> None:
        d = self._waiters.get((id(topic), partition))
        if not d:
            return
        now = self.clock.now()
        if ready_at < now:
            ready_at = now
        for wait in d.values():
            if wait.resolved:
                continue
            # a registration can outlive a rebalance for an instant (the
            # rebalance's _wake_all_parked is what re-registers) — only
            # wake waiters actually assigned this partition right now
            if partition not in wait.eff.group.partitions_for(
                    wait.eff.consumer_id):
                continue
            self.sched.at(ready_at,
                          lambda w=wait, g=wait.gen: self._wake(w, False, g))

    def _rebal_wake(self) -> None:
        self._rebal_ev = None
        self._wake_all_parked()

    def _wake_all_parked(self) -> None:
        """Rebalance wakeup: membership changed (join/leave), so parked
        consumers may now be assigned partitions with pending messages."""
        for rec in list(self.tasks.values()):
            wait = rec["wait"]
            if wait is not None and not wait.resolved:
                self._wake(wait, False)

    # -- speculative Service races ----------------------------------------

    def _begin_service(self, rec: dict, actor, eff: Service,
                       primary_s: float) -> None:
        """Charge a Service effect as a cancellable completion event so a
        speculative backup can race it (the no-speculation path stays the
        plain ``resume(delay=secs)`` — identical event count)."""
        op = _ServiceOp(rec, actor, eff.stage, eff.payload,
                        self.clock.now())
        rec["svc"] = op
        op.primary_ev = self.sched.after(
            primary_s, lambda: self._svc_done(op, backup_won=False))
        th = self.speculation.threshold(eff.stage)
        # schedule the straggler check even when threshold >= primary_s:
        # the DES doesn't peek at the draw, it observes the deadline pass
        # (the completion event fires first and cancels the check)
        if th is not None:
            op.check_ev = self.sched.after(
                th, lambda: self._svc_speculate(op))

    def _idle_helper(self, rec: dict) -> Optional[dict]:
        """The first stage-mate (spawn order — deterministic) currently
        parked in a poll whose slot a backup can steal."""
        for r in self.consumer_recs:
            if r is rec or r["stage"] != rec["stage"]:
                continue
            if r["task_id"] not in self.tasks or r["helping"] is not None:
                continue
            wait = r["wait"]
            if (wait is not None and not wait.resolved
                    and r["actor"] is not None and r["actor"].alive):
                return r
        return None

    def _svc_speculate(self, op: _ServiceOp) -> None:
        """The primary charge outlived ``factor × median``: steal an idle
        stage-mate's slot (work stealing — the backup occupies a
        *different* consumer slot, never the straggler's own), launch the
        backup — a fresh draw of the service model — and let the two
        completion events race.  No idle slot → no backup."""
        op.check_ev = None
        if op.resolved or not op.actor.alive or self.state.stop.is_set():
            return
        helper = self._idle_helper(op.rec)
        if helper is None:
            self.speculation.no_capacity()
            return
        # steal the slot: the helper stops listening for new messages
        # until the race resolves (its suspended Poll is re-attempted on
        # release)
        op.helper = helper
        op.helper_eff = helper["wait"].eff
        self._clear_wait(helper)
        helper["helping"] = op
        self._beat(helper)
        backup_s = max(self.ex.service_model(op.stage, op.rec["ctx"],
                                             op.payload), 0.0)
        op.backup_launched = True
        self.speculation.launched()
        self._beat(op.rec)                 # the backup is making progress
        op.backup_ev = self.sched.after(
            backup_s, lambda: self._svc_done(op, backup_won=True))

    def _release_helper(self, op: _ServiceOp) -> None:
        """Hand a stolen slot back: the helper resumes polling (unless
        the run is over or the helper died meanwhile)."""
        helper, eff = op.helper, op.helper_eff
        op.helper = op.helper_eff = None
        if helper is None:
            return
        if helper["helping"] is op:
            helper["helping"] = None
        self._beat(helper)
        if (helper["task_id"] in self.tasks
                and helper["actor"] is not None and helper["actor"].alive
                and not self.state.stop.is_set()):
            self._attempt_poll(helper, helper["actor"], eff)

    def _abort_lend(self, rec: dict) -> None:
        """A lent-out helper died (crash / silent loss / heartbeat): the
        slot its backup was running on is gone, so the backup dies with
        it — accounted as cancelled; the primary keeps running."""
        op = rec["helping"]
        if op is None:
            return
        rec["helping"] = None
        if op.resolved or not op.backup_launched:
            return
        if op.backup_ev is not None:
            op.backup_ev.cancel()
            op.backup_ev = None
        op.backup_launched = False
        op.helper = op.helper_eff = None
        self.speculation.cancelled()

    def _svc_done(self, op: _ServiceOp, backup_won: bool) -> None:
        if op.resolved or not op.actor.alive:
            return
        op.resolved = True
        op.cancel_events()
        op.rec["svc"] = None
        if op.backup_launched:
            self.speculation.resolved(backup_won)
        self._release_helper(op)
        self.speculation.record(op.stage, self.clock.now() - op.t0)
        self._beat(op.rec)
        op.actor.resume(None)

    def _cancel_service(self, rec: dict) -> None:
        """Abort an in-flight Service race (actor died / run ended): a
        launched-but-unresolved backup counts as cancelled and its stolen
        slot is handed back."""
        op = rec["svc"]
        if op is None:
            return
        rec["svc"] = None
        if op.resolved:
            return
        op.resolved = True
        op.cancel_events()
        if op.backup_launched:
            self.speculation.cancelled()
        self._release_helper(op)

    def _clear_wait(self, rec: dict) -> None:
        wait = rec["wait"]
        if wait is not None:
            wait.resolved = True
            self._unregister(wait)
            if wait.timeout_ev is not None:
                wait.timeout_ev.cancel()
                wait.timeout_ev = None
            rec["wait"] = None

    def _release_inflight(self, rec: dict) -> None:
        """A silently-dropped consumer can die holding a dedup reservation
        (its generator is never thrown into, so the body's exception
        handler can't release it). Release it here or the redeliveries of
        that message would be dropped as duplicates forever."""
        mid = self.state.inflight.pop(
            (rec["stage"], rec["cid"], rec["attempt"]), None)
        if mid is not None:
            with self.state.lock:
                self.state.seen[rec["stage"] - 1].discard(mid)

    # -- exits / failures / retries ---------------------------------------

    def _on_exit(self, rec: dict, exc: Optional[BaseException]) -> None:
        rec["actor"] = None
        self._clear_wait(rec)
        self._cancel_service(rec)
        self._abort_lend(rec)
        if exc is None:
            self.tasks.pop(rec["task_id"], None)
            self.metrics.incr("runtime.completed")
            return
        if isinstance(exc, ActorKilled):
            self.tasks.pop(rec["task_id"], None)
            if rec["kind"] == "consumer":
                rec["group"].leave(rec["cid"])
                self._wake_all_parked()
            if rec["exit_reason"] == "retire":
                self.metrics.event("consumer_retired", consumer=rec["cid"])
            else:
                self.metrics.event("consumer_crashed", consumer=rec["cid"])
            return
        self._task_error(rec, exc)

    def _task_error(self, rec: dict, exc: BaseException) -> None:
        self.metrics.incr("runtime.task_errors")
        self.metrics.event("task_error", task_id=rec["task_id"],
                           error=repr(exc)[:200])
        retries = rec["retries_left"]
        rec["retries_left"] = retries - 1
        if retries > 0 and not self.state.stop.is_set():
            self.metrics.incr("runtime.retries")
            rec["attempt"] += 1
            delay = 0.01 * (2 ** (self.max_retries - retries))
            self.sched.after(delay, lambda: self._launch(rec))
        else:
            self.tasks.pop(rec["task_id"], None)
            if rec["kind"] == "consumer":
                # free the failed member's partitions for the survivors
                rec["group"].leave(rec["cid"])
                self._wake_all_parked()
            self.metrics.event("task_failed", task_id=rec["task_id"])

    # -- crash / rebalance injection --------------------------------------

    def _inject(self, f: Any) -> None:
        if self.state.stop.is_set():
            return
        cid = f"consumer-{f.consumer_idx}"
        rec = next((r for r in self.consumer_recs
                    if r["cid"] == cid and r["actor"] is not None
                    and r["actor"].alive), None)
        if rec is not None:
            if getattr(f, "kind", "crash") == "silent":
                # the node goes dark: no exception, no cleanup — only the
                # heartbeat monitor can notice (frozen last_beat)
                rec["actor"].drop()
                self._clear_wait(rec)
                self._cancel_service(rec)
                self._abort_lend(rec)
                self._release_inflight(rec)
                rec["sleep_until"] = 0.0   # dark node: no known wake
            else:
                rec["exit_reason"] = "crash"
                rec["actor"].kill()
        restart = getattr(f, "restart_after_s", None)
        if restart is not None:
            self.sched.after(float(restart),
                             lambda: self._restart(f"{cid}-r"))

    def _restart(self, cid: str) -> None:
        if self.state.stop.is_set():
            return
        self.metrics.event("consumer_restarted", consumer=cid)
        self._spawn_consumer(cid, len(self.pipe.stages) - 1)

    # -- drift injection ---------------------------------------------------

    def _apply_drift(self, d: Any) -> None:
        """Apply one scheduled drift event (band / churn / outage) — an
        ordinary DES event, so drifted runs stay bit-reproducible."""
        if self.state.stop.is_set():
            return
        kind = getattr(d, "kind", "band")
        if kind == "band":
            self._drift_band(d)
        elif kind == "churn":
            self._drift_churn(d)
        elif kind == "outage":
            self._drift_outage(d)
        else:
            raise ValueError(f"unknown drift kind {kind!r}")

    def _drift_band(self, d: Any) -> None:
        """Re-price a hop's live shaper in place: the token bucket's
        ``_available_at`` backlog survives, so traffic already queued
        behind the old band drains at the *new* rate — what a real link
        degradation does to in-flight transfers."""
        topics = self.state.topics
        hop = int(getattr(d, "hop", -1) if getattr(d, "hop", None)
                  is not None else -1)
        if hop < 0:
            hop += len(topics)
        if not 0 <= hop < len(topics):
            raise ValueError(f"drift hop {hop} out of range "
                             f"(pipeline has {len(topics)} hops)")
        topic = topics[hop]
        shaper = topic.shaper
        old = None
        if shaper is None:
            shaper = WanShaper(bandwidth_bps=float(d.bandwidth_bps),
                               rtt_s=float(d.rtt_s), sleep=False)
            topic.shaper = shaper
            self.pipe._shapers[hop] = shaper
        else:
            old = (shaper.bandwidth_bps, shaper.rtt_s)
            if getattr(d, "bandwidth_bps", None) is not None:
                shaper.bandwidth_bps = float(d.bandwidth_bps)
            if getattr(d, "rtt_s", None) is not None:
                shaper.rtt_s = float(d.rtt_s)
        self.metrics.event("drift_band", hop=hop,
                           bandwidth_bps=shaper.bandwidth_bps,
                           rtt_s=shaper.rtt_s)
        restore = getattr(d, "restore_after_s", None)
        if restore is not None and old is not None:
            def _restore(shaper=shaper, old=old, hop=hop):
                if self.state.stop.is_set():
                    return
                shaper.bandwidth_bps, shaper.rtt_s = old
                self.metrics.event("drift_band_restored", hop=hop)
            self.sched.after(float(restore), _restore)

    def _churn(self, si: int, delta: int) -> None:
        """Grow (``delta > 0``) or retire (``delta < 0``) stage ``si``'s
        live consumer fleet — the shared core of churn drift and its
        restore."""
        if delta > 0:
            for _ in range(delta):
                cid = self.pipe.stage_cid(si, next(self._stage_seq[si]))
                self.metrics.event("consumer_spawned", consumer=cid)
                self._spawn_consumer(cid, si)
        elif delta < 0:
            alive = self._alive_consumers(si)
            for rec in alive[delta:]:          # retire the newest first
                if rec["actor"] is not None and rec["actor"].alive:
                    rec["exit_reason"] = "retire"
                    rec["actor"].kill()

    def _drift_churn(self, d: Any) -> None:
        si = (self._resolve_stage(d.stage)
              if getattr(d, "stage", None) is not None
              else len(self.pipe.stages) - 1)
        if si == 0:
            raise ValueError("stage 0 (the sources) cannot churn — "
                             "sources are not consumers")
        delta = int(getattr(d, "delta", 0))
        self._churn(si, delta)
        self.metrics.event("drift_churn", stage=self.pipe.stages[si].name,
                           delta=delta)
        restore = getattr(d, "restore_after_s", None)
        if restore is not None and delta:
            def _restore(si=si, delta=delta):
                if self.state.stop.is_set():
                    return
                self._churn(si, -delta)
                self.metrics.event("drift_churn_restored",
                                   stage=self.pipe.stages[si].name)
            self.sched.after(float(restore), _restore)

    def _drift_outage(self, d: Any) -> None:
        """A whole tier goes dark: every live consumer of stages bound to
        that tier is killed at once (crash semantics — group rebalance
        frees their partitions immediately).  ``restore_after_s`` brings
        the same head-counts back as fresh members."""
        tier = d.tier
        counts: Dict[int, int] = {}
        for si in range(1, len(self.pipe.stages)):
            if self.pipe.stages[si].pilot.tier != tier:
                continue
            alive = self._alive_consumers(si)
            counts[si] = len(alive)
            for rec in alive:
                if rec["actor"] is not None and rec["actor"].alive:
                    rec["exit_reason"] = "outage"
                    rec["actor"].kill()
        self.metrics.event("drift_outage", tier=tier,
                           consumers=sum(counts.values()))
        restore = getattr(d, "restore_after_s", None)
        if restore is not None:
            def _restore(counts=counts, tier=tier):
                if self.state.stop.is_set():
                    return
                for si, n in counts.items():
                    self._churn(si, n)
                self.metrics.event("drift_outage_restored", tier=tier)
            self.sched.after(float(restore), _restore)

    # -- online re-advisory (hot-swap) ------------------------------------

    def _readvise_tick(self) -> None:
        # like _monitor_tick: stop rescheduling once the run is over (or,
        # in a sharded run with no local sources, was never fed) so the
        # scheduler can drain and the shard can report done
        if self.state.stop.is_set() or not self.tasks:
            return
        rv, si, pipe = self.readvisor, self._rv_stage, self.pipe
        dec = rv.step(now=self.clock.now(), metrics=self.metrics,
                      topic=self.state.topics[si - 1].name,
                      current_tier=pipe.stages[si].pilot.tier,
                      src_tier=pipe.stages[si - 1].pilot.tier)
        if dec is not None:
            self.metrics.event("readvise_decision",
                               stage=pipe.stages[si].name,
                               from_tier=dec.from_tier,
                               to_tier=dec.to_tier)
            self.sched.after(rv.apply_delay_s,
                             lambda: self._apply_swap(dec))
        self.sched.after(rv.interval_s, self._readvise_tick)

    def _apply_swap(self, dec: Any) -> None:
        """Execute a re-advisory decision: re-bind the watched stage to
        the winning tier's pilot, then migrate its consumer fleet."""
        if self.state.stop.is_set():
            return
        rv, si, pipe = self.readvisor, self._rv_stage, self.pipe
        pipe.rebind_stage(pipe.stages[si].name, rv.pilot_for(dec.to_tier))
        self._migrate_stage(si)
        rv.applied(dec, self.clock.now())

    def _migrate_stage(self, si: int) -> None:
        """Epoch-based graceful migration: bump the stage's placement
        epoch (old-generation consumers drain out at their next loop top
        — any message they hold finishes and commits under the swapped
        binding first), nudge parked members so they notice now instead
        of at their idle deadline, and spawn a same-size replacement
        fleet on the new pilot.  The overlap window is covered by the
        hop's at-least-once + dedup machinery."""
        state = self.state
        state.stage_epoch[si] = state.stage_epoch.get(si, 0) + 1
        old = self._alive_consumers(si)
        for rec in old:
            wait = rec["wait"]
            if wait is not None and not wait.resolved:
                # timed_out=True resumes None: the body loops without
                # grabbing a message and hits the epoch check
                self._wake(wait, True)
        for _ in range(len(old)):
            cid = self.pipe.stage_cid(si, next(self._stage_seq[si]))
            self.metrics.event("consumer_spawned", consumer=cid)
            self._spawn_consumer(cid, si)

    # -- periodic machinery: heartbeats + autoscaler ----------------------

    def _monitor_tick(self) -> None:
        if self.state.stop.is_set() or not self.tasks:
            return
        now = self.clock.now()
        for rec in list(self.tasks.values()):
            if rec["wait"] is not None:        # parked = framework-idle
                continue
            if rec["helping"] is not None:     # lent to a backup race —
                continue                       # framework-busy, not hung
            if rec["actor"] is None:           # between retry launches
                continue
            if rec["sleep_until"] > now:       # timed sleep, known wake
                continue
            if now - rec["last_beat"] > self.heartbeat_timeout_s:
                rec["actor"].drop()
                rec["actor"] = None
                self._cancel_service(rec)
                self._abort_lend(rec)
                if rec["kind"] == "consumer":
                    self._release_inflight(rec)
                    # session timeout: rebalance the lost member out
                    rec["group"].leave(rec["cid"])
                    self._wake_all_parked()
                    self.metrics.event("consumer_lost", consumer=rec["cid"])
                self._task_error(
                    rec, TimeoutError(
                        f"heartbeat lost ({rec['task_id']})"))
        self.sched.after(self.ex.monitor_interval_s, self._monitor_tick)

    def _alive_consumers(self, stage: Optional[int] = None) -> List[dict]:
        """Consumers of ``stage`` (default: final) still alive — the pool
        that stage's autoscaler grows/shrinks (stages without a policy
        keep their static pools)."""
        if stage is None:
            stage = len(self.pipe.stages) - 1
        return [r for r in self.consumer_recs
                if r["stage"] == stage and r["task_id"] in self.tasks]

    def _autoscale_tick(self) -> None:
        if self.state.stop.is_set():
            return
        for si in sorted(self.autoscalers):
            scaler = self.autoscalers[si]
            scaler.step_once()
            target = self.pipe.stages[si].pilot.resource.n_workers
            alive = self._alive_consumers(si)
            if target > len(alive):
                for _ in range(target - len(alive)):
                    cid = self.pipe.stage_cid(si, next(self._stage_seq[si]))
                    self.metrics.event("consumer_spawned", consumer=cid)
                    self._spawn_consumer(cid, si)
            elif target < len(alive):
                for rec in alive[target:]:     # retire the newest first
                    if rec["actor"] is not None and rec["actor"].alive:
                        rec["exit_reason"] = "retire"
                        rec["actor"].kill()
        self.sched.after(self.ex.autoscale_interval_s, self._autoscale_tick)
