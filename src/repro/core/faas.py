"""FaaS API: ContinuumPipeline (N tiers) + EdgeToCloudPipeline (paper
§II-C, Listings 1 & 2).

The paper's pilot abstraction places tasks *anywhere along the
edge-to-cloud continuum*, so the pipeline is an N-stage dataflow, not a
hardwired edge→cloud pair:

* :class:`StageSpec` — one stage: a plain Python handler bound to a pilot
  (or ``placement='auto'`` to let the :class:`PlacementEngine` bind it),
* :class:`ContinuumPipeline` — N ordered stages connected by broker
  topics; every hop between consecutive stage tiers rides the continuum
  topology's *routed* link (multi-hop paths collapse to their
  serialized-equivalent bandwidth + accumulated latency) and stamps the
  shared MetricsRegistry,
* :class:`EdgeToCloudPipeline` — the paper's Listing-2 object, now a thin
  two-stage wrapper (``produce``[+``process_edge``] → broker →
  ``process_cloud``) so the historical API and every Fig-3 golden keep
  working unchanged.

A 4-tier device/edge/fog/cloud run is just four StageSpecs::

    ContinuumPipeline(stages=[
        StageSpec("sense", sense_fn, pilot=pilot_device),
        StageSpec("edge_agg", edge_fn, pilot=pilot_edge),
        StageSpec("fog_agg", fog_fn, pilot=pilot_fog),
        StageSpec("train", train_fn, pilot=pilot_cloud),
    ], function_context={...}).run(n_messages=512)

Execution strategy: the stage loops are cooperative generator bodies (see
:mod:`repro.core.executor`) selected by ``run(scheduler=)``:

* ``ThreadedExecutor`` (default) — real threads;
* ``SimExecutor`` — the same genuine pipeline as a single-threaded
  discrete-event simulation under an auto-advance
  :class:`~repro.sim.clock.SimClock`, bit-reproducible run to run.

Dynamism (paper §II-D): ``replace_function(stage, fn)`` hot-swaps a
stage's payload at runtime *without* re-allocating pilots, and pilots can
be resized through the PilotManager while the pipeline runs (the
AutoScaler drives the final stage's pool inside the DES — see
``SimExecutor(autoscaler=...)``).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

from repro.core.broker import Broker, ConsumerGroup, Topic, WanShaper
from repro.core.executor import Poll, Service, Sleep, ThreadedExecutor
from repro.core.monitoring import MetricsRegistry
from repro.core.params_service import ParameterService
from repro.core.pilot import Pilot
from repro.core.placement import PlacementEngine, TaskProfile
from repro.core.runtime import TaskContext
from repro.sim.clock import Clock, as_clock

ProduceFn = Callable[[TaskContext], Any]
ProcessFn = Callable[..., Any]

_run_ids = itertools.count()


@dataclass(frozen=True)
class StageSpec:
    """One stage of a :class:`ContinuumPipeline`.

    The source stage's ``handler`` has the produce signature
    ``f(ctx) -> data``; every later stage processes:
    ``f(ctx, data=None) -> data`` (the last stage's return value is the
    collected result).  Bind the stage to a ``pilot`` explicitly, or set
    ``placement='auto'`` and let the pipeline's
    :class:`~repro.core.placement.PlacementEngine` pick from the
    candidates handed to the constructor.  ``n_tasks`` is the stage's
    parallel task count (source: devices; consuming stages: consumers) —
    default: the bound pilot's worker count.
    """
    name: str
    handler: ProcessFn
    pilot: Optional[Pilot] = None
    placement: str = "explicit"        # explicit | auto
    n_tasks: Optional[int] = None


@dataclass
class PipelineResult:
    """What ``run`` returns: results + the linked metrics for Fig 2/3."""
    results: List[Any]
    metrics: MetricsRegistry
    n_produced: int
    n_processed: int
    wall_s: float

    def throughput(self):
        return self.metrics.throughput("processed")

    def latency(self):
        return self.metrics.summary("produced", "processed")

    def per_hop(self):
        return self.metrics.per_hop_latency()


@dataclass
class _RunState:
    """Per-``run`` shared state between the task bodies and the strategy.
    One topic/group/dedup-set per hop (stage ``i`` consumes
    ``topics[i-1]`` and produces into ``topics[i]``)."""
    topics: List[Topic]
    groups: List[ConsumerGroup]
    per_device: List[int]
    n_messages: int
    timeout_s: float
    collect: bool
    # open-loop traffic: per-device sorted absolute arrival times (seconds
    # from run start). None = closed-loop (devices produce back-to-back,
    # paced only by the service model).
    arrivals: Optional[List[Sequence[float]]] = None
    results: List[Any] = field(default_factory=list)
    seen: List[set] = field(default_factory=list)
    # (stage_idx, cid, attempt) -> msg_id currently holding a dedup
    # reservation, so the executor can release it if the attempt dies
    # without unwinding
    inflight: Dict = field(default_factory=dict)
    # stage name -> consumers currently parked in a poll (the threaded
    # strategy's idle-slot ledger for capacity-aware speculation)
    idle: Dict[str, int] = field(default_factory=dict)
    # stage idx -> placement epoch: bumped by a live hot-swap
    # (rebind_stage + executor migration). Consumers capture the epoch at
    # spawn and drain out gracefully when it moves past them, so swapped
    # stages never have two generations pulling from one group at once
    # beyond the hand-off window.
    stage_epoch: Dict[int, int] = field(default_factory=dict)
    lock: threading.Lock = field(default_factory=threading.Lock)
    stop: threading.Event = field(default_factory=threading.Event)
    processed_sem: threading.Semaphore = field(
        default_factory=lambda: threading.Semaphore(0))
    n_processed: int = 0
    t_done: Optional[float] = None      # clock time the target was reached


class ContinuumPipeline:
    """N ordered stages along the continuum, connected by broker topics.

    Each hop ``stage[i] → stage[i+1]`` gets its own topic; when the two
    stages sit on different tiers the hop is shaped by a
    :class:`~repro.core.broker.WanShaper` priced from the continuum
    topology's *routed* path between the tiers (multi-hop routes collapse
    to their serialized-equivalent bandwidth and accumulated latency).
    Pass ``shapers=[...]`` (one entry per hop, ``None`` = unshaped) to
    override.

    ``placement='auto'`` stages are bound at construction by scoring
    ``candidate_pilots`` through the placement engine (data flows from
    the previous stage's tier).
    """

    def __init__(self, *,
                 stages: Sequence[StageSpec],
                 function_context: Optional[dict] = None,
                 n_partitions: Optional[int] = None,
                 topic_name: str = "continuum",
                 shapers: Optional[Sequence[Optional[WanShaper]]] = None,
                 broker: Optional[Broker] = None,
                 parameter_service: Optional[ParameterService] = None,
                 placement: str = "explicit",
                 placement_engine: Optional[PlacementEngine] = None,
                 candidate_pilots: Optional[Mapping[str, Sequence[Pilot]]]
                 = None,
                 metrics: Optional[MetricsRegistry] = None,
                 max_retries: int = 2,
                 speculative_factor: float = 0.0,
                 heartbeat_timeout_s: float = 30.0,
                 truncate_logs: Optional[int] = None,
                 clock: Optional[Clock] = None):
        if len(stages) < 2:
            raise ValueError("a pipeline needs a source stage and at "
                             "least one processing stage")
        names = [s.name for s in stages]
        if len(set(names)) != len(names):
            raise ValueError(f"stage names must be unique, got {names}")
        if "consumer" in names[:-1]:
            # the final stage owns the "consumer-{i}" cid namespace that
            # crash injection, restarts and autoscaling address — an
            # intermediate stage of that name would collide with it
            raise ValueError(
                "'consumer' is reserved for the final stage's task ids; "
                "rename the intermediate stage")
        # an auto-advance SimClock here means the pipeline is destined for
        # run(scheduler=SimExecutor(...)); ThreadedExecutor re-checks and
        # rejects it at run time (threads can't coordinate on a clock that
        # fast-forwards under them).
        self._clock = as_clock(clock)
        self.metrics = metrics or MetricsRegistry(clock=self._clock)
        self.broker = broker or Broker(metrics=self.metrics,
                                       clock=self._clock)
        self.params = parameter_service or ParameterService(
            metrics=self.metrics)
        self.context = dict(function_context or {})
        self.placement_engine = placement_engine or PlacementEngine()
        self.placement = placement
        self.stages: List[StageSpec] = self._resolve_stages(
            list(stages), candidate_pilots or {})
        self.topic_name = topic_name
        # paper baseline: one partition per source device, the ratio kept
        # constant along every hop
        self.n_partitions = n_partitions or self.stage_tasks(0)
        if self.n_partitions <= 0:
            raise ValueError(
                "pipeline needs n_partitions >= 1; pass n_partitions= "
                "explicitly when the source stage has n_tasks=0")
        self._fns: Dict[str, Optional[ProcessFn]] = {
            s.name: s.handler for s in self.stages}
        self._fn_lock = threading.Lock()
        if shapers is None:
            self._shapers = [
                self._hop_shaper(a.pilot.tier, b.pilot.tier)
                for a, b in zip(self.stages[:-1], self.stages[1:])]
        else:
            if len(shapers) != len(self.stages) - 1:
                raise ValueError(
                    f"need one shaper per hop ({len(self.stages) - 1}), "
                    f"got {len(shapers)}")
            self._shapers = list(shapers)
        self._runtime_kw = dict(max_retries=max_retries,
                                speculative_factor=speculative_factor,
                                heartbeat_timeout_s=heartbeat_timeout_s,
                                clock=self._clock)
        # broker-log retention: reclaim hop-topic prefixes below the
        # group-minimum committed offset in batches of this many messages
        # (None = keep everything, the historical behavior).  Reclaimed
        # msg_ids are also evicted from the per-hop dedup sets, so pipeline
        # memory stays bounded — at the cost of windowed (not run-long)
        # duplicate suppression; see README "DES at 10M".
        self.truncate_logs = truncate_logs
        self._topics: List[Topic] = []
        self._topic: Optional[Topic] = None
        self._group: Optional[ConsumerGroup] = None
        self._run_groups: List[ConsumerGroup] = []
        self._arrival_plan: Optional[List[Sequence[float]]] = None
        # live online re-advisory: run(readvise=...) parks the ReAdvisor
        # here for the duration of the call; executors pick it up in
        # begin()/run() exactly like _arrival_plan
        self._readvise = None

    # -- construction helpers ------------------------------------------------

    def _resolve_stages(self, stages: List[StageSpec],
                        candidates: Mapping[str, Sequence[Pilot]]
                        ) -> List[StageSpec]:
        """Bind ``placement='auto'`` stages to the best candidate pilot
        (late binding, the paper's placement decision)."""
        import dataclasses
        resolved: List[StageSpec] = []
        for i, st in enumerate(stages):
            if st.pilot is not None:
                resolved.append(st)
                continue
            if st.placement != "auto":
                raise ValueError(
                    f"stage {st.name!r} has no pilot; bind one or set "
                    f"placement='auto' with candidate_pilots")
            cands = list(candidates.get(st.name, ()))
            if not cands:
                raise ValueError(
                    f"stage {st.name!r} is placement='auto' but no "
                    f"candidate_pilots[{st.name!r}] were provided")
            in_tier = (resolved[i - 1].pilot.tier if i > 0 else "edge")
            profile = TaskProfile(
                flops=float(self.context.get("task_flops", 1e9)),
                input_bytes=float(self.context.get("message_bytes", 1e6)),
                input_tier=in_tier,
                preferred_tiers=tuple(
                    self.context.get("preferred_tiers", ())))
            pilot = self.placement_engine.place(profile, cands).pilot
            resolved.append(dataclasses.replace(st, pilot=pilot))
        return resolved

    def _hop_shaper(self, src_tier: str,
                    dst_tier: str) -> Optional[WanShaper]:
        """Shape a hop by the routed link between its tiers (None for
        intra-tier hops — local traffic is not shaped)."""
        if src_tier == dst_tier:
            return None
        link = self.placement_engine.cost.route(src_tier,
                                                dst_tier).as_link()
        return WanShaper(bandwidth_bps=link.bandwidth_bps,
                         rtt_s=link.latency_s, sleep=False)

    def stage_tasks(self, idx: int) -> int:
        """Parallel task count of stage ``idx`` (negative ok).  An
        explicit ``n_tasks=0`` is honored (a sharded run's remote half
        owns that stage's tasks); only ``None`` falls back to the bound
        pilot's worker count."""
        st = self.stages[idx]
        if st.n_tasks is not None:
            return st.n_tasks
        return st.pilot.resource.n_workers

    def stage_cid(self, idx: int, i: int) -> str:
        """Consumer id of stage ``idx``'s ``i``-th task — the one naming
        rule both executors share.  The final stage owns the
        ``consumer-{i}`` namespace (crash injection, restarts and
        autoscaling address final-stage members by it); intermediate
        stages prefix with their own (reserved-checked) name."""
        if idx % len(self.stages) == len(self.stages) - 1:
            return f"consumer-{i}"
        return f"{self.stages[idx].name}-{i}"

    @property
    def n_source_tasks(self) -> int:
        return self.stage_tasks(0)

    @property
    def n_edge_devices(self) -> int:
        """Legacy alias: the source stage's device count."""
        return self.stage_tasks(0)

    @property
    def cloud_consumers(self) -> int:
        """Legacy alias: the final stage's consumer count."""
        return self.stage_tasks(-1)

    @property
    def stage_tiers(self) -> List[str]:
        """The per-stage execution tier vector (placement advisories and
        bench rows carry this)."""
        return [s.pilot.tier for s in self.stages]

    # -- dynamism ------------------------------------------------------------

    def replace_function(self, stage: str, fn: ProcessFn) -> None:
        """Hot-swap a stage payload at runtime (paper §II-D). No pilot
        re-allocation; in-flight messages finish under the old function."""
        if stage not in self._fns:
            raise KeyError(stage)
        with self._fn_lock:
            self._fns[stage] = fn
        self.metrics.event("function_replaced", stage=stage,
                           fn=getattr(fn, "__name__", repr(fn)))

    def _fn(self, stage: str) -> Optional[ProcessFn]:
        with self._fn_lock:
            return self._fns[stage]

    def rebind_stage(self, stage: str, pilot: Pilot) -> int:
        """Re-bind a stage to a different pilot at runtime — the placement
        half of a hot-swap (``replace_function`` is the payload half).
        Re-prices the adjacent hops' shapers from the routed link between
        the *new* tier pair, mutating the live run's hop topics in place
        so queued-but-unsent traffic rides the new link.  Returns the
        stage index.  The executors' migration machinery (epoch bump +
        consumer respawn) is what actually moves the running tasks; this
        method only flips the bindings."""
        import dataclasses
        names = [s.name for s in self.stages]
        try:
            idx = names.index(stage)
        except ValueError:
            raise KeyError(stage) from None
        old_tier = self.stages[idx].pilot.tier
        self.stages[idx] = dataclasses.replace(self.stages[idx],
                                               pilot=pilot)
        for hop in (idx - 1, idx):
            if not 0 <= hop < len(self.stages) - 1:
                continue
            shaper = self._hop_shaper(self.stages[hop].pilot.tier,
                                      self.stages[hop + 1].pilot.tier)
            old = self._shapers[hop]
            if old is not None and shaper is not None:
                # keep the live shaper object (its _available_at token
                # bucket holds queued traffic) and re-price it
                old.bandwidth_bps = shaper.bandwidth_bps
                old.rtt_s = shaper.rtt_s
            else:
                self._shapers[hop] = shaper
                if hop < len(self._topics):
                    self._topics[hop].shaper = shaper
        self.metrics.event("stage_rebound", stage=stage,
                           from_tier=old_tier, to_tier=pilot.tier)
        return idx

    def current_lag(self) -> int:
        """Broker lag of the live run's final consumer group — the
        natural ``lag_fn`` for an :class:`~repro.core.elastic.AutoScaler`
        watching this pipeline (0 when no run is active)."""
        g = self._group
        return g.lag() if g is not None else 0

    def stage_lag(self, stage_idx: int) -> int:
        """Broker lag of stage ``stage_idx``'s consumer group in the live
        run (0 when no run is active) — the per-stage ``lag_fn`` for
        per-stage autoscaling policies.  Stage 1..N-1 (stage 0, the
        sources, consumes nothing)."""
        groups = self._run_groups
        if not groups:
            return 0
        if not 1 <= stage_idx < len(self.stages):
            raise ValueError(f"stage_lag wants a consumer stage index in "
                             f"[1, {len(self.stages) - 1}], got {stage_idx}")
        return groups[stage_idx - 1].lag()

    # -- task bodies (cooperative; interpreted by the strategy) ---------------

    def _invoke_source(self, ctx: TaskContext) -> Any:
        return self._fn(self.stages[0].name)(ctx)

    def _source_body(self, ctx: TaskContext, state: _RunState,
                     device_idx: int, count: int):
        """One source device: generate → first topic, ``count`` times.

        Closed-loop (``state.arrivals is None``): produce back-to-back,
        each message charged ``Service(<source stage>)`` — the strategy's
        per-message generation cost (zero unless a service model is set).

        Open-loop (``state.arrivals`` set): the device releases messages
        at its pre-drawn absolute arrival times — traffic intensity is a
        property of the *arrival process*, not of how fast the pipeline
        drains, so bursts genuinely queue.  Generation cost is not
        charged (the arrival time already embodies when the message
        exists).

        One reused effect record per kind: the interpreter consumes the
        effect synchronously at the yield, so mutating it next iteration
        is safe — and a million-message run skips a million allocations.
        """
        topic = state.topics[0]
        partition = device_idx % self.n_partitions
        stage_name = self.stages[0].name
        arrivals = (state.arrivals[device_idx]
                    if state.arrivals is not None else None)
        if arrivals is not None:
            t0 = ctx.clock.now()
            slp = Sleep(0.0)
            for t_arr in arrivals:
                dt = t0 + t_arr - ctx.clock.now()
                if dt > 0:
                    slp.seconds = dt
                    yield slp
                if state.stop.is_set():
                    return
                data = self._invoke_source(ctx)
                topic.produce(data, partition=partition)
                ctx.heartbeat()
            return
        svc = Service(stage_name)
        for _ in range(count):
            if state.stop.is_set():
                return
            data = self._invoke_source(ctx)
            svc.payload = data
            yield svc
            svc.payload = None
            if state.stop.is_set():
                return
            topic.produce(data, partition=partition)
            ctx.heartbeat()

    def _stage_body(self, ctx: TaskContext, state: _RunState,
                    stage_idx: int, cid: str):
        """One consumer of stage ``stage_idx``: join the group, then
        poll → dedup → process → forward/collect → commit until the run
        stops or goes idle.  Every hop is at-least-once across
        rebalances; per-stage dedup by msg_id gives exactly-once *effect*
        end to end.  Intermediate stages forward their output into the
        next hop's topic under the originating message's identity, so
        produced→processed latency spans the whole continuum path."""
        group = state.groups[stage_idx - 1]
        group.join(cid)
        final = stage_idx == len(self.stages) - 1
        out_topic = None if final else state.topics[stage_idx]
        seen = state.seen[stage_idx - 1]
        stage_name = self.stages[stage_idx].name
        clock = ctx.clock
        # per-event attribute lookups hoisted into locals: this loop body
        # runs once per message and the bound-method/attribute chains
        # (state.stop.is_set, clock.now, metrics, heartbeat) are a
        # measurable slice of the profiled event loop at 1M+ messages
        now = clock.now
        stopped = state.stop.is_set
        metrics = self.metrics
        heartbeat = ctx.heartbeat
        commit = group.commit
        timeout_s = state.timeout_s
        inflight = state.inflight
        idle_deadline = now() + timeout_s
        # reused effect records (see _source_body): the interpreter reads
        # them synchronously at the yield point
        poll = Poll(group, cid, timeout_s=0.2, stage=stage_name)
        svc = Service(stage_name)
        epochs = state.stage_epoch
        my_epoch = epochs.get(stage_idx, 0)
        while not stopped():
            if epochs.get(stage_idx, 0) != my_epoch:
                # a hot-swap moved this stage to a new placement epoch:
                # any message this consumer finished is already committed,
                # so leaving the group here hands its partitions to the
                # replacement generation with at-least-once semantics
                # (dedup absorbs any redelivery overlap).
                group.leave(cid)
                metrics.event("consumer_drained", cid=cid,
                              stage=stage_name, epoch=my_epoch)
                return
            poll.wake_at = idle_deadline
            msg = yield poll
            if msg is None:
                if (state.n_processed >= state.n_messages
                        or now() >= idle_deadline):
                    return
                continue
            idle_deadline = now() + timeout_s
            with state.lock:
                dup = msg.msg_id in seen
                seen.add(msg.msg_id)               # reserve
            if dup:
                commit(msg)
                metrics.incr("pipeline.duplicates_dropped")
                continue
            inflight_key = (stage_idx, cid, ctx.attempt)
            inflight[inflight_key] = msg.msg_id
            try:
                data = msg.value()
                svc.payload = data
                yield svc
                svc.payload = None
                fn = self._fn(stage_name)
                out = fn(ctx, data=data)
            except BaseException:
                # release the dedup reservation so the redelivery (from
                # this task's retry or a rebalance) is processed, then let
                # the strategy's retry machinery handle the failure.
                with state.lock:
                    seen.discard(msg.msg_id)
                inflight.pop(inflight_key, None)
                raise
            # hop identity: forwarded messages carry the originating
            # msg_id in their key so the final stamp links end to end
            origin = msg.key or msg.msg_id
            if final:
                metrics.stamp(origin, "processed", bytes=msg.nbytes)
                commit(msg)
                inflight.pop(inflight_key, None)
                with state.lock:
                    state.n_processed += 1
                    if state.collect:
                        state.results.append(out)
                    if (state.n_processed >= state.n_messages
                            and state.t_done is None):
                        state.t_done = now()
                        state.stop.set()
                state.processed_sem.release()
            else:
                out_topic.produce(out, key=origin, partition=msg.partition,
                                  msg_id=f"{origin}+h{stage_idx}")
                commit(msg)
                inflight.pop(inflight_key, None)
            heartbeat()

    # -- run -------------------------------------------------------------------

    def _setup_run(self, n_messages: int, timeout_s: float,
                   collect_results: bool) -> _RunState:
        """Create the per-run topics/groups/state (called by the
        strategy)."""
        # run-counter suffix, not a wall-time suffix: virtual runs restart
        # the clock at 0 and must not collide on topic names
        run_id = next(_run_ids)
        topics: List[Topic] = []
        groups: List[ConsumerGroup] = []
        seen: List[set] = []
        lock = threading.Lock()
        for i, stage in enumerate(self.stages[1:], start=1):
            suffix = "" if i == 1 else f"-h{i - 1}"
            topics.append(self.broker.create_topic(
                f"{self.topic_name}-{run_id}{suffix}",
                n_partitions=self.n_partitions,
                shaper=self._shapers[i - 1],
                truncate_batch=self.truncate_logs))
            groups.append(ConsumerGroup(topics[-1],
                                        group_id=f"{stage.name}-group"))
            seen.append(set())
            if self.truncate_logs is not None:
                # a reclaimed message can never be redelivered, so its
                # dedup entry is dead weight — evict it.  This bounds the
                # other linear memory term (hop dedup sets) and narrows
                # exactly-once *effect* to the retention window.
                topics[-1].on_truncate(
                    self._make_dedup_evictor(seen[-1], lock))
        # paper: messages split across devices, one partition per device
        n_src = self.stage_tasks(0)
        arrivals = self._arrival_plan
        if arrivals is not None:
            if len(arrivals) != n_src:
                raise ValueError(
                    f"arrival plan has {len(arrivals)} device streams, "
                    f"pipeline has {n_src} source tasks")
            per_device = [len(a) for a in arrivals]
            n_messages = sum(per_device)
        elif n_src == 0:
            # a source-less shard (the producing half lives in another
            # process): messages arrive via Topic.inject only
            per_device = []
        else:
            per_device = [n_messages // n_src] * n_src
            for i in range(n_messages % n_src):
                per_device[i] += 1
        self._topics = topics
        self._topic = topics[0]
        self._group = groups[-1]
        self._run_groups = groups
        return _RunState(topics=topics, groups=groups,
                         per_device=per_device,
                         seen=seen, lock=lock,
                         n_messages=n_messages, timeout_s=timeout_s,
                         collect=collect_results, arrivals=arrivals)

    @staticmethod
    def _make_dedup_evictor(seen: set, lock: threading.Lock):
        """Truncation callback: drop dedup entries of reclaimed msg_ids
        (they can never be redelivered). ``lock`` is the run state's lock
        guarding ``seen``."""
        def _evict(partition: int, msg_ids: List[str]) -> None:
            with lock:
                seen.difference_update(msg_ids)
        return _evict

    def _finish(self, state: _RunState, wall_s: float) -> PipelineResult:
        self._group = None        # current_lag() reads 0 between runs
        self._run_groups = []     # stage_lag() likewise
        n_prod = int(self.metrics.counter(
            f"topic.{state.topics[0].name}.msgs_in"))
        return PipelineResult(results=state.results, metrics=self.metrics,
                              n_produced=n_prod,
                              n_processed=state.n_processed, wall_s=wall_s)

    def run(self, n_messages: Optional[int] = None,
            timeout_s: float = 600.0,
            collect_results: bool = True,
            scheduler=None, placement: Optional[str] = None,
            latency_budget: Optional[float] = None,
            wan_budget: Optional[float] = None,
            hybrid_reduce: Optional[List[int]] = None,
            arrival_plan: Optional[List[Sequence[float]]] = None,
            readvise=None):
        """Drive ``n_messages`` end-to-end (default 512 — what the paper
        sends per run).

        ``arrival_plan`` switches the sources to *open-loop* traffic: one
        sorted sequence of absolute arrival times (seconds from run
        start) per source device — e.g. drawn from
        :class:`repro.sim.scenarios.PoissonArrivals` /
        ``DiurnalArrivals`` / ``FlashCrowdArrivals``.  ``n_messages`` is
        then taken from the plan (and must not disagree if given).

        ``scheduler`` selects the execution strategy:
        :class:`~repro.core.executor.ThreadedExecutor` (default — real
        threads) or :class:`~repro.core.executor.SimExecutor`
        (single-threaded virtual time, bit-reproducible metrics).

        ``placement='advise'`` does not execute this pipeline at all:
        instead the :class:`~repro.cost.advisor.PlacementAdvisor` emulates
        a pipeline of this shape (devices/consumers; workload from
        ``function_context['model']`` / ``['n_points']``; straggler
        speculation from this pipeline's ``speculative_factor``) under
        its own ``SimExecutor`` across placements × WAN bands — every
        advisory cell carries its per-stage tier vector — and returns
        the ranked :class:`~repro.cost.advisor.AdvisorReport` — the
        paper's "evaluate task placement based on multiple factors" knob,
        multi-objectively: ``latency_budget`` caps predicted p95 latency
        (seconds), ``wan_budget`` caps advisory WAN megabytes (cells over
        budget are flagged infeasible and ranked last, never dropped),
        and ``hybrid_reduce`` sweeps the hybrid/fog placements' edge
        pre-aggregation factor.  An explicit ``n_messages`` sets the
        per-cell advisory fidelity (default 32 — the whole grid in a few
        hundred ms); ``timeout_s``/``collect_results`` do not apply and
        ``scheduler`` is rejected.

        ``readvise=ReAdvisor(...)`` attaches an *online* re-advisor
        (:class:`~repro.cost.readvisor.ReAdvisor`) for the duration of
        the run: the executor ticks it periodically (SimExecutor: a
        scheduled virtual-time event; ThreadedExecutor: a monitor
        thread), and when observed hop latency flips the placement
        ranking beyond hysteresis the watched stage is hot-swapped live
        via :meth:`rebind_stage` + consumer migration.
        """
        if placement == "advise":
            if scheduler is not None:
                raise ValueError(
                    "placement='advise' runs its own SimExecutor grid; "
                    "scheduler= does not apply")
            model = self.context.get("model")
            if model is None:
                raise ValueError(
                    "placement='advise' needs function_context['model'] "
                    "to name a calibrated workload (e.g. 'kmeans') — "
                    "advising for a guessed model would silently rank "
                    "the wrong trade-off")
            # imported lazily: the advisor rides the sim/scenarios stack,
            # which imports this module
            from repro.cost.advisor import PlacementAdvisor
            kw = {} if n_messages is None else {"n_messages": n_messages}
            return PlacementAdvisor.from_pipeline(self, **kw).advise(
                model, latency_budget=latency_budget,
                wan_budget=wan_budget, hybrid_reduce=hybrid_reduce)
        if (latency_budget is not None or wan_budget is not None
                or hybrid_reduce is not None):
            raise ValueError(
                "latency_budget/wan_budget/hybrid_reduce are advisory "
                "knobs — they only apply with placement='advise'")
        if placement is not None and placement != self.placement:
            raise ValueError(
                f"unsupported run-time placement {placement!r} "
                f"(constructor placement is {self.placement!r}; "
                f"run-time only supports 'advise')")
        if arrival_plan is not None:
            plan_total = sum(len(a) for a in arrival_plan)
            if n_messages is not None and n_messages != plan_total:
                raise ValueError(
                    f"n_messages={n_messages} disagrees with the arrival "
                    f"plan's {plan_total} arrivals — omit n_messages")
            n_messages = plan_total
        n_messages = 512 if n_messages is None else n_messages
        self._arrival_plan = arrival_plan
        self._readvise = readvise
        try:
            strategy = (scheduler if scheduler is not None
                        else ThreadedExecutor())
            return strategy.run(self, n_messages=n_messages,
                                timeout_s=timeout_s,
                                collect_results=collect_results)
        finally:
            self._arrival_plan = None
            self._readvise = None

    def launch(self, scheduler, *, n_messages: Optional[int] = None,
               timeout_s: float = 600.0, collect_results: bool = False,
               arrival_plan: Optional[List[Sequence[float]]] = None,
               readvise=None):
        """Start this pipeline under a :class:`SimExecutor` *without*
        draining it: returns the executor's windowed run handle
        (``start``-ed), which a caller advances in bounded virtual-time
        windows via ``advance_to(t)`` and closes with ``finish()``.

        This is the shard-aware entry point: a
        :class:`repro.sim.shard.ShardCoordinator` drives one handle per
        process in conservative time-window lock-step, delivering
        cross-shard boundary messages between windows.  ``run()`` is
        exactly ``launch(...)`` advanced to the deadline in one window.
        """
        if arrival_plan is not None:
            plan_total = sum(len(a) for a in arrival_plan)
            if n_messages is not None and n_messages != plan_total:
                raise ValueError(
                    f"n_messages={n_messages} disagrees with the arrival "
                    f"plan's {plan_total} arrivals — omit n_messages")
            n_messages = plan_total
        n_messages = 512 if n_messages is None else n_messages
        self._arrival_plan = arrival_plan
        self._readvise = readvise
        try:
            return scheduler.begin(self, n_messages=n_messages,
                                   timeout_s=timeout_s,
                                   collect_results=collect_results)
        finally:
            self._arrival_plan = None
            self._readvise = None


class EdgeToCloudPipeline(ContinuumPipeline):
    """Listing 2's object: the historical two-stage edge→cloud pipeline,
    kept as a thin :class:`ContinuumPipeline` wrapper.  Parameter names
    follow the paper's API; ``process_edge`` runs fused into the source
    stage (pre-aggregation next to the generator), exactly as before."""

    def __init__(self, *,
                 pilot_cloud_processing: Pilot,
                 pilot_edge: Pilot,
                 pilot_cloud_broker: Optional[Pilot] = None,
                 produce_function_handler: ProduceFn,
                 process_cloud_function_handler: ProcessFn,
                 process_edge_function_handler: Optional[ProcessFn] = None,
                 function_context: Optional[dict] = None,
                 n_edge_devices: Optional[int] = None,
                 n_partitions: Optional[int] = None,
                 topic_name: str = "edge-to-cloud",
                 wan_shaper: Optional[WanShaper] = None,
                 broker: Optional[Broker] = None,
                 parameter_service: Optional[ParameterService] = None,
                 placement: str = "explicit",
                 placement_engine: Optional[PlacementEngine] = None,
                 cloud_consumers: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 max_retries: int = 2,
                 speculative_factor: float = 0.0,
                 heartbeat_timeout_s: float = 30.0,
                 truncate_logs: Optional[int] = None,
                 clock: Optional[Clock] = None):
        self.pilot_edge = pilot_edge
        self.pilot_cloud = pilot_cloud_processing
        self.pilot_broker = pilot_cloud_broker or pilot_cloud_processing
        n_src = n_edge_devices or pilot_edge.resource.n_workers
        n_parts = n_partitions or n_src
        # keep Kafka:Dask partition ratio constant (paper: "we keep the
        # ratio of partitions constant between Kafka and Dask")
        stages = [
            StageSpec("produce", produce_function_handler,
                      pilot=pilot_edge, n_tasks=n_src),
            StageSpec("process_cloud", process_cloud_function_handler,
                      pilot=pilot_cloud_processing,
                      n_tasks=cloud_consumers or n_parts),
        ]
        super().__init__(
            stages=stages, function_context=function_context,
            n_partitions=n_parts, topic_name=topic_name,
            shapers=[wan_shaper], broker=broker,
            parameter_service=parameter_service, placement=placement,
            placement_engine=placement_engine, metrics=metrics,
            max_retries=max_retries,
            speculative_factor=speculative_factor,
            heartbeat_timeout_s=heartbeat_timeout_s,
            truncate_logs=truncate_logs, clock=clock)
        # process_edge is hot-swappable like a stage even though it runs
        # fused into the source body (legacy API)
        self._fns["process_edge"] = process_edge_function_handler

    def _invoke_source(self, ctx: TaskContext) -> Any:
        produce = self._fn("produce")
        data = produce(ctx)
        pe = self._fn("process_edge")
        if pe is not None:
            data = pe(ctx, data=data)
        return data
