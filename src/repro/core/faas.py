"""FaaS API + EdgeToCloudPipeline (paper §II-C, Listings 1 & 2).

The application provides up to three plain Python functions::

    def produce_edge(context) -> data                  # sensing / generation
    def process_edge(context, data=None) -> data       # pre-aggregation
    def process_cloud(context, data=None) -> result    # analytics / training

and instantiates::

    EdgeToCloudPipeline(
        pilot_cloud_processing=..., pilot_cloud_broker=..., pilot_edge=...,
        produce_function_handler=produce_edge,
        process_edge_function_handler=process_edge,      # optional
        process_cloud_function_handler=process_cloud,
        function_context={...},
    ).run(n_messages=512)

The framework then (step 2 of Fig 1) packages the functions into tasks,
binds them to pilots (placement), creates the broker topic (one partition
per edge device, the paper's baseline layout), and manages the dataflow
edge → [process_edge] → broker → cloud. All hops stamp the shared
MetricsRegistry; results are collected from the cloud stage.

Execution strategy: the producer/consumer loops are cooperative generator
bodies (see :mod:`repro.core.executor`) selected by ``run(scheduler=)``:

* ``ThreadedExecutor`` (default) — real threads, today's behaviour;
* ``SimExecutor`` — the same genuine pipeline as a single-threaded
  discrete-event simulation under an auto-advance
  :class:`~repro.sim.clock.SimClock`, bit-reproducible run to run.

Dynamism (paper §II-D): ``replace_function(stage, fn)`` hot-swaps a stage's
payload at runtime *without* re-allocating pilots (e.g. exchanging low- vs
high-fidelity models), and pilots can be resized through the PilotManager
while the pipeline runs (the AutoScaler drives this inside the DES — see
``SimExecutor(autoscaler=...)``).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.broker import Broker, ConsumerGroup, Topic, WanShaper
from repro.core.executor import Poll, Service, ThreadedExecutor
from repro.core.monitoring import MetricsRegistry
from repro.core.params_service import ParameterService
from repro.core.pilot import Pilot
from repro.core.placement import PlacementEngine, TaskProfile
from repro.core.runtime import TaskContext
from repro.sim.clock import Clock, as_clock

ProduceFn = Callable[[TaskContext], Any]
ProcessFn = Callable[..., Any]

_run_ids = itertools.count()


@dataclass
class PipelineResult:
    """What ``run`` returns: results + the linked metrics for Fig 2/3."""
    results: List[Any]
    metrics: MetricsRegistry
    n_produced: int
    n_processed: int
    wall_s: float

    def throughput(self):
        return self.metrics.throughput("processed")

    def latency(self):
        return self.metrics.summary("produced", "processed")

    def per_hop(self):
        return self.metrics.per_hop_latency()


@dataclass
class _RunState:
    """Per-``run`` shared state between the task bodies and the strategy."""
    topic: Topic
    group: ConsumerGroup
    per_device: List[int]
    n_messages: int
    timeout_s: float
    collect: bool
    results: List[Any] = field(default_factory=list)
    seen_ids: set = field(default_factory=set)
    # (cid, attempt) -> msg_id currently holding a dedup reservation, so
    # the executor can release it if the attempt dies without unwinding
    inflight: Dict = field(default_factory=dict)
    lock: threading.Lock = field(default_factory=threading.Lock)
    stop: threading.Event = field(default_factory=threading.Event)
    processed_sem: threading.Semaphore = field(
        default_factory=lambda: threading.Semaphore(0))
    n_processed: int = 0
    t_done: Optional[float] = None      # clock time the target was reached


class EdgeToCloudPipeline:
    """Listing 2's object. Parameter names follow the paper's API."""

    def __init__(self, *,
                 pilot_cloud_processing: Pilot,
                 pilot_edge: Pilot,
                 pilot_cloud_broker: Optional[Pilot] = None,
                 produce_function_handler: ProduceFn,
                 process_cloud_function_handler: ProcessFn,
                 process_edge_function_handler: Optional[ProcessFn] = None,
                 function_context: Optional[dict] = None,
                 n_edge_devices: Optional[int] = None,
                 n_partitions: Optional[int] = None,
                 topic_name: str = "edge-to-cloud",
                 wan_shaper: Optional[WanShaper] = None,
                 broker: Optional[Broker] = None,
                 parameter_service: Optional[ParameterService] = None,
                 placement: str = "explicit",
                 placement_engine: Optional[PlacementEngine] = None,
                 cloud_consumers: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 max_retries: int = 2,
                 speculative_factor: float = 0.0,
                 heartbeat_timeout_s: float = 30.0,
                 clock: Optional[Clock] = None):
        self.pilot_edge = pilot_edge
        self.pilot_cloud = pilot_cloud_processing
        self.pilot_broker = pilot_cloud_broker or pilot_cloud_processing
        # an auto-advance SimClock here means the pipeline is destined for
        # run(scheduler=SimExecutor(...)); ThreadedExecutor re-checks and
        # rejects it at run time (threads can't coordinate on a clock that
        # fast-forwards under them).
        self._clock = as_clock(clock)
        self.metrics = metrics or MetricsRegistry(clock=self._clock)
        self.broker = broker or Broker(metrics=self.metrics,
                                       clock=self._clock)
        self.params = parameter_service or ParameterService(
            metrics=self.metrics)
        self.n_edge_devices = (n_edge_devices
                               or pilot_edge.resource.n_workers)
        # paper baseline: one partition per edge device
        self.n_partitions = n_partitions or self.n_edge_devices
        self.topic_name = topic_name
        self.wan_shaper = wan_shaper
        self.context = dict(function_context or {})
        self._fns: Dict[str, Optional[ProcessFn]] = {
            "produce": produce_function_handler,
            "process_edge": process_edge_function_handler,
            "process_cloud": process_cloud_function_handler,
        }
        self._fn_lock = threading.Lock()
        self.placement_engine = placement_engine or PlacementEngine()
        self.placement = placement
        # keep Kafka:Dask partition ratio constant (paper: "we keep the
        # ratio of partitions constant between Kafka and Dask")
        self.cloud_consumers = cloud_consumers or self.n_partitions
        self._runtime_kw = dict(max_retries=max_retries,
                                speculative_factor=speculative_factor,
                                heartbeat_timeout_s=heartbeat_timeout_s,
                                clock=self._clock)
        self._topic: Optional[Topic] = None
        self._group: Optional[ConsumerGroup] = None

    # -- dynamism ------------------------------------------------------------

    def replace_function(self, stage: str, fn: ProcessFn) -> None:
        """Hot-swap a stage payload at runtime (paper §II-D). No pilot
        re-allocation; in-flight messages finish under the old function."""
        if stage not in self._fns:
            raise KeyError(stage)
        with self._fn_lock:
            self._fns[stage] = fn
        self.metrics.event("function_replaced", stage=stage,
                           fn=getattr(fn, "__name__", repr(fn)))

    def _fn(self, stage: str) -> Optional[ProcessFn]:
        with self._fn_lock:
            return self._fns[stage]

    def current_lag(self) -> int:
        """Broker lag of the live run's consumer group — the natural
        ``lag_fn`` for an :class:`~repro.core.elastic.AutoScaler` watching
        this pipeline (0 when no run is active)."""
        g = self._group
        return g.lag() if g is not None else 0

    # -- placement ------------------------------------------------------------

    def _choose_cloud_pilot(self, candidates: List[Pilot]) -> Pilot:
        if self.placement != "auto" or not candidates:
            return self.pilot_cloud
        profile = TaskProfile(
            flops=float(self.context.get("task_flops", 1e9)),
            input_bytes=float(self.context.get("message_bytes", 1e6)),
            input_tier="edge",
            preferred_tiers=tuple(self.context.get("preferred_tiers", ())))
        return self.placement_engine.place(profile, candidates).pilot

    # -- task bodies (cooperative; interpreted by the strategy) ---------------

    def _producer_body(self, ctx: TaskContext, state: _RunState,
                       device_idx: int, count: int):
        """One edge device: generate → [process_edge] → broker, ``count``
        times. ``Service("produce")`` charges the strategy's per-message
        generation + edge-stage cost (zero unless a service model is set)."""
        topic = state.topic
        for _ in range(count):
            if state.stop.is_set():
                return
            produce = self._fn("produce")
            data = produce(ctx)
            pe = self._fn("process_edge")
            if pe is not None:
                data = pe(ctx, data=data)
            yield Service("produce", data)
            if state.stop.is_set():
                return
            topic.produce(data, partition=device_idx % self.n_partitions)
            ctx.heartbeat()

    def _consumer_body(self, ctx: TaskContext, state: _RunState, cid: str):
        """One cloud consumer: join the group, then poll → dedup →
        process → commit until the run stops or goes idle. The broker is
        at-least-once across rebalances; dedup by msg_id gives
        exactly-once *effect* at the application layer."""
        group = state.group
        group.join(cid)
        clock = ctx.clock
        idle_deadline = clock.now() + state.timeout_s
        while not state.stop.is_set():
            msg = yield Poll(group, cid, timeout_s=0.2,
                             wake_at=idle_deadline)
            if msg is None:
                if (state.n_processed >= state.n_messages
                        or clock.now() >= idle_deadline):
                    return
                continue
            idle_deadline = clock.now() + state.timeout_s
            with state.lock:
                dup = msg.msg_id in state.seen_ids
                state.seen_ids.add(msg.msg_id)     # reserve
            if dup:
                group.commit(msg)
                self.metrics.incr("pipeline.duplicates_dropped")
                continue
            inflight_key = (cid, ctx.attempt)
            state.inflight[inflight_key] = msg.msg_id
            try:
                data = msg.value()
                yield Service("process_cloud", data)
                fn = self._fn("process_cloud")
                out = fn(ctx, data=data)
            except BaseException:
                # release the dedup reservation so the redelivery (from
                # this task's retry or a rebalance) is processed, then let
                # the strategy's retry machinery handle the failure.
                with state.lock:
                    state.seen_ids.discard(msg.msg_id)
                state.inflight.pop(inflight_key, None)
                raise
            self.metrics.stamp(msg.msg_id, "processed", bytes=msg.nbytes)
            group.commit(msg)
            state.inflight.pop(inflight_key, None)
            with state.lock:
                state.n_processed += 1
                if state.collect:
                    state.results.append(out)
                if (state.n_processed >= state.n_messages
                        and state.t_done is None):
                    state.t_done = clock.now()
                    state.stop.set()
            state.processed_sem.release()
            ctx.heartbeat()

    # -- run -------------------------------------------------------------------

    def _setup_run(self, n_messages: int, timeout_s: float,
                   collect_results: bool) -> _RunState:
        """Create the per-run topic/group/state (called by the strategy)."""
        # run-counter suffix, not a wall-time suffix: virtual runs restart
        # the clock at 0 and must not collide on topic names
        topic = self.broker.create_topic(
            f"{self.topic_name}-{next(_run_ids)}",
            n_partitions=self.n_partitions, shaper=self.wan_shaper)
        group = ConsumerGroup(topic, group_id="cloud-processing")
        # paper: messages split across devices, one partition per device
        per_device = ([n_messages // self.n_edge_devices]
                      * self.n_edge_devices)
        for i in range(n_messages % self.n_edge_devices):
            per_device[i] += 1
        self._topic = topic
        self._group = group
        return _RunState(topic=topic, group=group, per_device=per_device,
                         n_messages=n_messages, timeout_s=timeout_s,
                         collect=collect_results)

    def _finish(self, state: _RunState, wall_s: float) -> PipelineResult:
        self._group = None        # current_lag() reads 0 between runs
        n_prod = int(self.metrics.counter(
            f"topic.{state.topic.name}.msgs_in"))
        return PipelineResult(results=state.results, metrics=self.metrics,
                              n_produced=n_prod,
                              n_processed=state.n_processed, wall_s=wall_s)

    def run(self, n_messages: Optional[int] = None,
            timeout_s: float = 600.0,
            collect_results: bool = True,
            scheduler=None, placement: Optional[str] = None,
            latency_budget: Optional[float] = None,
            wan_budget: Optional[float] = None,
            hybrid_reduce: Optional[List[int]] = None):
        """Drive ``n_messages`` end-to-end (default 512 — what the paper
        sends per run).

        ``scheduler`` selects the execution strategy:
        :class:`~repro.core.executor.ThreadedExecutor` (default — real
        threads) or :class:`~repro.core.executor.SimExecutor`
        (single-threaded virtual time, bit-reproducible metrics).

        ``placement='advise'`` does not execute this pipeline at all:
        instead the :class:`~repro.cost.advisor.PlacementAdvisor` emulates
        a pipeline of this shape (devices/consumers; workload from
        ``function_context['model']`` / ``['n_points']``; straggler
        speculation from this pipeline's ``speculative_factor``) under
        its own ``SimExecutor`` across placements × WAN bands and returns
        the ranked :class:`~repro.cost.advisor.AdvisorReport` — the
        paper's "evaluate task placement based on multiple factors" knob,
        multi-objectively: ``latency_budget`` caps predicted p95 latency
        (seconds), ``wan_budget`` caps advisory WAN megabytes (cells over
        budget are flagged infeasible and ranked last, never dropped),
        and ``hybrid_reduce`` sweeps the hybrid placement's edge
        pre-aggregation factor.  An explicit ``n_messages`` sets the
        per-cell advisory fidelity (default 32 — the whole grid in a few
        hundred ms); ``timeout_s``/``collect_results`` do not apply and
        ``scheduler`` is rejected.
        """
        if placement == "advise":
            if scheduler is not None:
                raise ValueError(
                    "placement='advise' runs its own SimExecutor grid; "
                    "scheduler= does not apply")
            model = self.context.get("model")
            if model is None:
                raise ValueError(
                    "placement='advise' needs function_context['model'] "
                    "to name a calibrated workload (e.g. 'kmeans') — "
                    "advising for a guessed model would silently rank "
                    "the wrong trade-off")
            # imported lazily: the advisor rides the sim/scenarios stack,
            # which imports this module
            from repro.cost.advisor import PlacementAdvisor
            kw = {} if n_messages is None else {"n_messages": n_messages}
            return PlacementAdvisor.from_pipeline(self, **kw).advise(
                model, latency_budget=latency_budget,
                wan_budget=wan_budget, hybrid_reduce=hybrid_reduce)
        if (latency_budget is not None or wan_budget is not None
                or hybrid_reduce is not None):
            raise ValueError(
                "latency_budget/wan_budget/hybrid_reduce are advisory "
                "knobs — they only apply with placement='advise'")
        if placement is not None and placement != self.placement:
            raise ValueError(
                f"unsupported run-time placement {placement!r} "
                f"(constructor placement is {self.placement!r}; "
                f"run-time only supports 'advise')")
        n_messages = 512 if n_messages is None else n_messages
        strategy = scheduler if scheduler is not None else ThreadedExecutor()
        return strategy.run(self, n_messages=n_messages,
                            timeout_s=timeout_s,
                            collect_results=collect_results)
