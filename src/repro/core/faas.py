"""FaaS API + EdgeToCloudPipeline (paper §II-C, Listings 1 & 2).

The application provides up to three plain Python functions::

    def produce_edge(context) -> data                  # sensing / generation
    def process_edge(context, data=None) -> data       # pre-aggregation
    def process_cloud(context, data=None) -> result    # analytics / training

and instantiates::

    EdgeToCloudPipeline(
        pilot_cloud_processing=..., pilot_cloud_broker=..., pilot_edge=...,
        produce_function_handler=produce_edge,
        process_edge_function_handler=process_edge,      # optional
        process_cloud_function_handler=process_cloud,
        function_context={...},
    ).run(n_messages=512)

The framework then (step 2 of Fig 1) packages the functions into tasks,
binds them to pilots (placement), creates the broker topic (one partition
per edge device, the paper's baseline layout), and manages the dataflow
edge → [process_edge] → broker → cloud. All hops stamp the shared
MetricsRegistry; results are collected from the cloud stage.

Dynamism (paper §II-D): ``replace_function(stage, fn)`` hot-swaps a stage's
payload at runtime *without* re-allocating pilots (e.g. exchanging low- vs
high-fidelity models), and pilots can be resized through the PilotManager
while the pipeline runs.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.broker import Broker, ConsumerGroup, Topic, WanShaper
from repro.core.monitoring import MetricsRegistry
from repro.core.params_service import ParameterService
from repro.core.pilot import Pilot
from repro.core.placement import PlacementEngine, TaskProfile
from repro.core.runtime import TaskContext, TaskRuntime
from repro.sim.clock import Clock, as_clock

ProduceFn = Callable[[TaskContext], Any]
ProcessFn = Callable[..., Any]

_run_ids = itertools.count()


@dataclass
class PipelineResult:
    """What ``run`` returns: results + the linked metrics for Fig 2/3."""
    results: List[Any]
    metrics: MetricsRegistry
    n_produced: int
    n_processed: int
    wall_s: float

    def throughput(self):
        return self.metrics.throughput("processed")

    def latency(self):
        return self.metrics.summary("produced", "processed")

    def per_hop(self):
        return self.metrics.per_hop_latency()


class EdgeToCloudPipeline:
    """Listing 2's object. Parameter names follow the paper's API."""

    def __init__(self, *,
                 pilot_cloud_processing: Pilot,
                 pilot_edge: Pilot,
                 pilot_cloud_broker: Optional[Pilot] = None,
                 produce_function_handler: ProduceFn,
                 process_cloud_function_handler: ProcessFn,
                 process_edge_function_handler: Optional[ProcessFn] = None,
                 function_context: Optional[dict] = None,
                 n_edge_devices: Optional[int] = None,
                 n_partitions: Optional[int] = None,
                 topic_name: str = "edge-to-cloud",
                 wan_shaper: Optional[WanShaper] = None,
                 broker: Optional[Broker] = None,
                 parameter_service: Optional[ParameterService] = None,
                 placement: str = "explicit",
                 placement_engine: Optional[PlacementEngine] = None,
                 cloud_consumers: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 max_retries: int = 2,
                 speculative_factor: float = 0.0,
                 heartbeat_timeout_s: float = 30.0,
                 clock: Optional[Clock] = None):
        self.pilot_edge = pilot_edge
        self.pilot_cloud = pilot_cloud_processing
        self.pilot_broker = pilot_cloud_broker or pilot_cloud_processing
        self._clock = as_clock(clock)
        if getattr(self._clock, "auto_advance", False):
            # the threaded run loop cannot coordinate on fast-forward time:
            # concurrent waiters would race the shared clock past the run
            # deadline while work is still in flight. Use a manually-driven
            # SimClock here, or the single-threaded DES harness
            # (repro.sim.scenarios) for fully virtual pipeline runs.
            raise ValueError(
                "EdgeToCloudPipeline needs a wall clock or a manually "
                "driven SimClock(auto_advance=False); for auto-advance "
                "virtual time use repro.sim.scenarios.run_scenario")
        self.metrics = metrics or MetricsRegistry(clock=self._clock)
        self.broker = broker or Broker(metrics=self.metrics,
                                       clock=self._clock)
        self.params = parameter_service or ParameterService(
            metrics=self.metrics)
        self.n_edge_devices = (n_edge_devices
                               or pilot_edge.resource.n_workers)
        # paper baseline: one partition per edge device
        self.n_partitions = n_partitions or self.n_edge_devices
        self.topic_name = topic_name
        self.wan_shaper = wan_shaper
        self.context = dict(function_context or {})
        self._fns: Dict[str, Optional[ProcessFn]] = {
            "produce": produce_function_handler,
            "process_edge": process_edge_function_handler,
            "process_cloud": process_cloud_function_handler,
        }
        self._fn_lock = threading.Lock()
        self.placement_engine = placement_engine or PlacementEngine()
        self.placement = placement
        # keep Kafka:Dask partition ratio constant (paper: "we keep the
        # ratio of partitions constant between Kafka and Dask")
        self.cloud_consumers = cloud_consumers or self.n_partitions
        self._runtime_kw = dict(max_retries=max_retries,
                                speculative_factor=speculative_factor,
                                heartbeat_timeout_s=heartbeat_timeout_s,
                                clock=self._clock)
        self._topic: Optional[Topic] = None
        self._stop = threading.Event()

    # -- dynamism ------------------------------------------------------------

    def replace_function(self, stage: str, fn: ProcessFn) -> None:
        """Hot-swap a stage payload at runtime (paper §II-D). No pilot
        re-allocation; in-flight messages finish under the old function."""
        if stage not in self._fns:
            raise KeyError(stage)
        with self._fn_lock:
            self._fns[stage] = fn
        self.metrics.event("function_replaced", stage=stage,
                           fn=getattr(fn, "__name__", repr(fn)))

    def _fn(self, stage: str) -> Optional[ProcessFn]:
        with self._fn_lock:
            return self._fns[stage]

    # -- placement ------------------------------------------------------------

    def _choose_cloud_pilot(self, candidates: List[Pilot]) -> Pilot:
        if self.placement != "auto" or not candidates:
            return self.pilot_cloud
        profile = TaskProfile(
            flops=float(self.context.get("task_flops", 1e9)),
            input_bytes=float(self.context.get("message_bytes", 1e6)),
            input_tier="edge",
            preferred_tiers=tuple(self.context.get("preferred_tiers", ())))
        return self.placement_engine.place(profile, candidates).pilot

    # -- run -------------------------------------------------------------------

    def run(self, n_messages: int = 512,
            timeout_s: float = 600.0,
            collect_results: bool = True) -> PipelineResult:
        """Drive ``n_messages`` end-to-end (the paper sends 512 per run)."""
        t0 = self._clock.now()
        self._stop.clear()
        # run-counter suffix, not a wall-time suffix: virtual runs restart
        # the clock at 0 and must not collide on topic names
        topic = self.broker.create_topic(
            f"{self.topic_name}-{next(_run_ids)}",
            n_partitions=self.n_partitions, shaper=self.wan_shaper)
        self._topic = topic

        edge_rt = TaskRuntime(self.pilot_edge, self.metrics,
                              **self._runtime_kw)
        cloud_rt = TaskRuntime(self.pilot_cloud, self.metrics,
                               **self._runtime_kw)
        group = ConsumerGroup(topic, group_id="cloud-processing")
        results: List[Any] = []
        results_lock = threading.Lock()
        processed = threading.Semaphore(0)
        n_processed = [0]
        seen_ids: set = set()   # idempotent processing: the broker is
        # at-least-once across rebalances; dedup by msg_id gives
        # exactly-once *effect* at the application layer.

        # --- edge producers: one per edge device, pinned to its partition ---
        per_device = [n_messages // self.n_edge_devices] * self.n_edge_devices
        for i in range(n_messages % self.n_edge_devices):
            per_device[i] += 1

        def edge_producer(ctx: TaskContext, device_idx: int, count: int):
            for _ in range(count):
                if self._stop.is_set():
                    return
                produce = self._fn("produce")
                data = produce(ctx)
                pe = self._fn("process_edge")
                if pe is not None:
                    data = pe(ctx, data=data)
                topic.produce(
                    data, partition=device_idx % self.n_partitions)
                ctx.heartbeat()

        producer_futs = [
            edge_rt.submit(edge_producer, i, per_device[i])
            for i in range(self.n_edge_devices)]

        # --- cloud consumers ---
        def cloud_consumer(ctx: TaskContext, consumer_idx: int):
            cid = f"consumer-{consumer_idx}"
            group.join(cid)
            idle_deadline = self._clock.now() + timeout_s
            while not self._stop.is_set():
                msg = group.poll(cid, timeout_s=0.2)
                if msg is None:
                    if (n_processed[0] >= n_messages
                            or self._clock.now() > idle_deadline):
                        return
                    continue
                idle_deadline = self._clock.now() + timeout_s
                with results_lock:
                    dup = msg.msg_id in seen_ids
                    seen_ids.add(msg.msg_id)     # reserve
                if dup:
                    group.commit(msg)
                    self.metrics.incr("pipeline.duplicates_dropped")
                    continue
                try:
                    data = msg.value()
                    fn = self._fn("process_cloud")
                    out = fn(ctx, data=data)
                except BaseException:
                    # release the dedup reservation so the redelivery (from
                    # this task's retry) is processed, then let the runtime's
                    # retry machinery handle the task failure.
                    with results_lock:
                        seen_ids.discard(msg.msg_id)
                    raise
                self.metrics.stamp(msg.msg_id, "processed",
                                   bytes=msg.nbytes)
                group.commit(msg)
                with results_lock:
                    n_processed[0] += 1
                    if collect_results:
                        results.append(out)
                processed.release()
                ctx.heartbeat()

        consumer_futs = [
            cloud_rt.submit(cloud_consumer, i)
            for i in range(self.cloud_consumers)]

        # --- wait for completion ---
        # the semaphore wait is real (worker threads are real) but the
        # deadline is measured on the injected clock; with a virtual clock
        # the real wait must stay short so deadline advances (driven from
        # another thread) are observed promptly
        deadline = self._clock.now() + timeout_s
        remaining = n_messages
        while remaining > 0:
            wait_s = min(deadline - self._clock.now(), timeout_s)
            if self._clock.virtual:
                wait_s = min(wait_s, 0.05)
            if processed.acquire(timeout=max(wait_s, 0.01)):
                remaining -= 1
            elif self._clock.now() >= deadline:
                break
        self._stop.set()
        wall = self._clock.now() - t0       # before any shutdown nudging
        for f in producer_futs + consumer_futs:
            # with a manual virtual clock, workers may be parked inside
            # clock.sleep waiting for time the external driver will never
            # provide once the run is over — tick the clock while joining
            # so their poll loops observe _stop and exit
            for _ in range(1000):           # ~10 s real bound per future
                if self._clock.virtual:
                    self._clock.advance(0.01)
                try:
                    f.result(timeout=0.01)
                    break
                except TimeoutError:
                    continue
                except Exception:   # noqa: BLE001 — task errors already counted
                    break
        edge_rt.shutdown(wait=False)
        cloud_rt.shutdown(wait=False)
        n_prod = int(self.metrics.counter(
            f"topic.{topic.name}.msgs_in"))
        return PipelineResult(results=results, metrics=self.metrics,
                              n_produced=n_prod,
                              n_processed=n_processed[0], wall_s=wall)
