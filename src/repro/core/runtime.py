"""Per-pilot task runtime — the framework's Dask analog (paper §II-B 2.2).

The paper executes tasks "using a managed Dask cluster on the specified
location". Inside a pilot we run an executor with:

* futures-based submission (``TaskRuntime.submit`` → :class:`TaskFuture`),
* heartbeat-based failure detection (a task that stops heartbeating past
  ``heartbeat_timeout_s`` is marked lost and retried),
* bounded retries with exponential backoff,
* **straggler mitigation** by speculative re-execution: if a task runs longer
  than ``speculative_factor ×`` the trailing median runtime, a duplicate
  attempt is launched and the first result wins (classic MapReduce-style
  backup tasks — this is the between-pilot survival of Dask work stealing,
  see DESIGN.md §2),
* a context object passed to every task (the paper's "further information on
  the resource topology and shared state are via a context object").

SPMD note: *within* a mesh pilot a task is one jitted program — the runtime's
unit is the whole task, not a shard. Mesh pilots therefore run tasks serially
(capacity 1) while edge pilots run ``n_workers`` concurrent Python tasks.
"""
from __future__ import annotations

import inspect
import itertools
import statistics
import threading
import traceback
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.monitoring import MetricsRegistry
from repro.core.pilot import Pilot
from repro.sim.clock import Clock, as_clock

_task_ids = itertools.count()


class TaskFailed(RuntimeError):
    pass


@dataclass
class TaskContext:
    """Paper's context object: topology + shared state + heartbeat hook.
    ``clock`` is the runtime's injected clock — long-running tasks should
    wait through it (``ctx.clock.sleep``) so emulated runs stay virtual."""
    pilot_id: str
    tier: str
    task_id: str
    attempt: int
    shared: dict = field(default_factory=dict)
    clock: Optional[Clock] = None
    _heartbeat: Optional[Callable[[], None]] = None

    def heartbeat(self) -> None:
        """Long-running tasks call this to stay alive past the timeout."""
        if self._heartbeat is not None:
            self._heartbeat()


@dataclass
class _Attempt:
    attempt_id: int
    started: float
    last_beat: float
    done: bool = False
    speculative: bool = False


class TaskFuture:
    def __init__(self, task_id: str):
        self.task_id = task_id
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None
        self.attempts = 0
        self.speculated = False

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(self.task_id)
        if self._error is not None:
            raise self._error
        return self._result

    def _complete(self, result: Any) -> bool:
        """First completion wins (speculative duplicates race here)."""
        if self._event.is_set():
            return False
        self._result = result
        self._event.set()
        return True

    def _fail(self, err: BaseException) -> bool:
        if self._event.is_set():
            return False
        self._error = err
        self._event.set()
        return True


class TaskRuntime:
    """Executor bound to one pilot."""

    def __init__(self, pilot: Pilot, metrics: Optional[MetricsRegistry] = None,
                 *, max_retries: int = 2,
                 heartbeat_timeout_s: float = 30.0,
                 speculative_factor: float = 0.0,
                 monitor_interval_s: float = 0.05,
                 clock: Optional[Clock] = None,
                 interpreter: Optional[Callable[["TaskContext", Any],
                                                Any]] = None):
        self.pilot = pilot
        self._clock = as_clock(clock)
        # cooperative task bodies: a submitted generator function is driven
        # to completion on the worker thread, its yielded effects resolved
        # by ``interpreter`` (numbers are always interpreted as clock
        # sleeps). The same bodies run as DES actors under SimExecutor.
        self.interpreter = interpreter
        self.metrics = metrics or MetricsRegistry(clock=self._clock)
        self.max_retries = max_retries
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.speculative_factor = speculative_factor
        self._pool = ThreadPoolExecutor(
            max_workers=max(pilot.capacity, 1) * 2,   # headroom for backups
            thread_name_prefix=f"{pilot.pilot_id}-worker")
        self._lock = threading.Lock()
        self._durations: List[float] = []
        self._inflight: Dict[str, dict] = {}
        self._shared: dict = {}
        self._shutdown = threading.Event()
        self._monitor = threading.Thread(
            target=self._monitor_loop, args=(monitor_interval_s,),
            daemon=True)
        self._monitor.start()

    # -- public API ----------------------------------------------------------

    def submit(self, fn: Callable[..., Any], *args,
               **kwargs) -> TaskFuture:
        self.pilot.require_active()
        task_id = f"{self.pilot.pilot_id}-task-{next(_task_ids)}"
        fut = TaskFuture(task_id)
        rec = {"fn": fn, "args": args, "kwargs": kwargs, "future": fut,
               "attempts": {}, "retries_left": self.max_retries}
        with self._lock:
            self._inflight[task_id] = rec
        self._launch_attempt(task_id, rec)
        self.metrics.incr("runtime.submitted")
        return fut

    def map(self, fn: Callable, items) -> List[TaskFuture]:
        return [self.submit(fn, x) for x in items]

    def shutdown(self, wait: bool = True) -> None:
        self._shutdown.set()
        self._pool.shutdown(wait=wait, cancel_futures=not wait)

    @property
    def shared(self) -> dict:
        return self._shared

    # -- attempt machinery ----------------------------------------------------

    def _launch_attempt(self, task_id: str, rec: dict,
                        speculative: bool = False) -> None:
        fut: TaskFuture = rec["future"]
        attempt_no = fut.attempts
        fut.attempts += 1
        now = self._clock.now()
        att = _Attempt(attempt_id=attempt_no, started=now, last_beat=now,
                       speculative=speculative)
        with self._lock:
            rec["attempts"][attempt_no] = att
        if speculative:
            fut.speculated = True
            self.metrics.incr("runtime.speculative_launches")

        def run():
            ctx = TaskContext(
                pilot_id=self.pilot.pilot_id, tier=self.pilot.tier,
                task_id=task_id, attempt=attempt_no, shared=self._shared,
                clock=self._clock,
                _heartbeat=lambda: self._beat(att))
            try:
                result = rec["fn"](ctx, *rec["args"], **rec["kwargs"])
                if inspect.isgenerator(result):
                    result = self._drive(ctx, result)
            except BaseException as e:  # noqa: BLE001 — retried below
                att.done = True
                self._on_attempt_error(task_id, rec, e)
                return
            att.done = True
            dur = self._clock.now() - att.started
            with self._lock:
                self._durations.append(dur)
                if len(self._durations) > 256:
                    del self._durations[:128]
            if fut._complete(result):
                self.metrics.incr("runtime.completed")
                if fut.speculated:
                    # first-completion-wins accounting, resolved per
                    # *launch* so wins + losses + cancelled == launches
                    # even when the monitor speculated more than once: a
                    # winning backup scores one win, every other backup
                    # launched for this task lost its race
                    n_spec = self._n_speculative(rec)
                    if att.speculative:
                        self.metrics.incr("runtime.speculative_wins")
                        n_spec -= 1
                    if n_spec > 0:
                        self.metrics.incr("runtime.speculative_losses",
                                          n_spec)
                with self._lock:
                    self._inflight.pop(task_id, None)

        self._pool.submit(run)

    def _drive(self, ctx: TaskContext, gen) -> Any:
        """Blocking interpretation of a cooperative task body: numbers are
        clock sleeps, everything else goes through ``self.interpreter``
        (the thread-strategy counterpart of a DES actor step)."""
        try:
            eff = next(gen)
            while True:
                if eff is None:
                    val = None
                elif isinstance(eff, (int, float)):
                    self._clock.sleep(max(float(eff), 0.0))
                    val = None
                elif self.interpreter is not None:
                    val = self.interpreter(ctx, eff)
                else:
                    raise TypeError(f"task {ctx.task_id} yielded {eff!r} "
                                    f"but the runtime has no interpreter")
                eff = gen.send(val)
        except StopIteration as s:
            return getattr(s, "value", None)

    def _beat(self, att: _Attempt) -> None:
        att.last_beat = self._clock.now()

    def _n_speculative(self, rec: dict) -> int:
        with self._lock:
            return sum(1 for a in rec["attempts"].values()
                       if a.speculative)

    def _on_attempt_error(self, task_id: str, rec: dict,
                          err: BaseException) -> None:
        fut: TaskFuture = rec["future"]
        self.metrics.incr("runtime.task_errors")
        self.metrics.event("task_error", task_id=task_id,
                           error=repr(err)[:200])
        with self._lock:
            retries = rec["retries_left"]
            rec["retries_left"] = retries - 1
        if retries > 0 and not fut.done():
            self.metrics.incr("runtime.retries")
            delay = 0.01 * (2 ** (self.max_retries - retries))
            self._clock.sleep(delay)
            if not fut.done():
                self._launch_attempt(task_id, rec)
        else:
            if fut._fail(TaskFailed(
                    f"{task_id} failed after {fut.attempts} attempts: "
                    f"{err!r}")):
                if fut.speculated:
                    # the task never completed: its backups' races were
                    # never decided — cancelled, keeping the invariant
                    # wins + losses + cancelled == launches (tasks still
                    # in flight at process shutdown are real threads and
                    # stay unaccounted; the DES has no such escape hatch)
                    n_spec = self._n_speculative(rec)
                    if n_spec > 0:
                        self.metrics.incr("runtime.speculative_cancelled",
                                          n_spec)
                with self._lock:
                    self._inflight.pop(task_id, None)

    # -- monitor: heartbeat timeouts + stragglers ------------------------------

    def _median_duration(self) -> Optional[float]:
        with self._lock:
            if len(self._durations) < 3:
                return None
            return statistics.median(self._durations)

    def _monitor_loop(self, interval: float) -> None:
        # the monitor thread wakes on a *real* cadence (it must stay live
        # while virtual time is driven from outside) but reads *clock*
        # time, so heartbeat loss / stragglers trigger on virtual advances
        while not self._shutdown.wait(interval):
            now = self._clock.now()
            median = self._median_duration()
            with self._lock:
                snapshot = list(self._inflight.items())
            for task_id, rec in snapshot:
                fut: TaskFuture = rec["future"]
                if fut.done():
                    continue
                running = [a for a in rec["attempts"].values()
                           if not a.done]
                # heartbeat failure detection
                for att in running:
                    if now - att.last_beat > self.heartbeat_timeout_s:
                        att.done = True   # declare lost
                        self._on_attempt_error(
                            task_id, rec,
                            TimeoutError(
                                f"heartbeat lost (attempt {att.attempt_id})"))
                # straggler speculation
                if (self.speculative_factor > 0 and median is not None
                        and len(running) == 1):
                    att = running[0]
                    if (now - att.started
                            > self.speculative_factor * median):
                        self._launch_attempt(task_id, rec, speculative=True)
